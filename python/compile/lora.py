"""Ablation F.2 / Table 16: LoRA fine-tuning of NBL-linearized layers.

Runs the whole NBL pipeline in JAX (capture -> closed-form LMMSE -> CCA
ranking), substitutes the m best attention layers, then LoRA-refines ONLY
the substituted linear layers on calibration text (rank-8 adapters,
causal-LM objective). Writes artifacts/lora_ablation.json with val loss
before/after — the paper's finding to reproduce: LoRA adds only marginal
gains over NBL alone.

Run: cd python && python -m compile.lora  (or `make lora`)
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .aot import ART
from .configs import MAIN, TRAIN
from .kernels import ref
from .model import capture_attn_io, load_weights
from .train import cross_entropy, load_corpus_bytes, make_batcher


def lmmse_fit(X, Y, ridge=1e-6):
    mx, my = X.mean(0), Y.mean(0)
    Xc, Yc = X - mx, Y - my
    cxx = Xc.T @ Xc / (len(X) - 1) + ridge * np.eye(X.shape[1], dtype=np.float32)
    cxy = Xc.T @ Yc / (len(X) - 1)
    W = np.linalg.solve(cxx, cxy)
    b = my - mx @ W
    return jnp.asarray(W), jnp.asarray(b)


def cca_bound(X, Yp):
    def isqrt(C):
        w, V = np.linalg.eigh(C)
        w = np.maximum(w, 1e-9)
        return (V * (w ** -0.5)) @ V.T

    Xc = X - X.mean(0)
    Yc = Yp - Yp.mean(0)
    n = len(X) - 1
    cxx, cyy = Xc.T @ Xc / n, Yc.T @ Yc / n
    cyx = Yc.T @ Xc / n
    cw = isqrt(cyy) @ cyx @ isqrt(cxx)
    rho = np.clip(np.linalg.svd(cw, compute_uv=False), 0, 1)
    return float(np.sum(1 - rho**2))


def forward_mixed(params, linear, lora, ids, cfg):
    """Forward with per-layer substitution; LoRA adapters (A, B) rank-r
    added to the substituted linear maps: W_eff = W + A @ B."""
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              head_dim=cfg.head_dim, theta=cfg.rope_theta, eps=cfg.norm_eps)
    x = params["emb"][ids]
    for li, lp in enumerate(params["layers"]):
        if li in linear:
            W, b = linear[li]
            if lora is not None and li in lora:
                A, B = lora[li]
                W = W + A @ B
            x = ref.linear_block(x, W, b)
        else:
            x, _, _ = ref.attn_prefill(
                x, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"], lp["wo"], **kw)
        x = ref.mlp_block(x, lp["mlp_norm"], lp["w1"], lp["w3"], lp["w2"],
                          eps=cfg.norm_eps)
    return ref.head(x, params["final_norm"], params["w_head"], eps=cfg.norm_eps)


def main(m=2, rank=8, steps=150, lr=1e-3):
    cfg = MAIN
    params = load_weights(cfg, os.path.join(ART, "weights_main.bin"))
    train = load_corpus_bytes(os.path.join(ART, "corpora", "tinyc4_train.txt"))
    val = load_corpus_bytes(os.path.join(ART, "corpora", "tinyc4_val.txt"))

    # ---- capture + NBL fit (paper Alg. 1/2, python replica)
    rng = np.random.default_rng(7)
    Xs = [[] for _ in range(cfg.n_layers)]
    Ys = [[] for _ in range(cfg.n_layers)]
    for _ in range(16):
        s = rng.integers(0, len(train) - 129)
        ids = jnp.asarray(train[s : s + 128].astype(np.int32))[None]
        for li, (x, y) in enumerate(capture_attn_io(params, ids, cfg)):
            Xs[li].append(np.asarray(x).reshape(-1, cfg.d_model))
            Ys[li].append(np.asarray(y).reshape(-1, cfg.d_model))
    bounds, fits = [], []
    for li in range(cfg.n_layers):
        X = np.concatenate(Xs[li])
        Y = np.concatenate(Ys[li])
        bounds.append(cca_bound(X, X + Y))
        fits.append(lmmse_fit(X, Y))
    order = np.argsort(bounds)[:m]
    linear = {int(li): fits[li] for li in order}
    print(f"bounds: {[round(b,3) for b in bounds]}; linearized layers {sorted(linear)}")

    # ---- eval helper
    batcher = make_batcher(val, TRAIN.batch_size, TRAIN.seq_len, 99)

    @jax.jit
    def val_loss(lora_flat):
        lora = unflatten(lora_flat)
        tot = 0.0
        for k in range(4):
            ids, tgt = val_batches[k]
            tot += cross_entropy(forward_mixed(params, linear, lora, ids, cfg), tgt)
        return tot / 4

    val_batches = [batcher() for _ in range(4)]

    def unflatten(flat):
        if flat is None:
            return None
        return {li: (flat[f"{li}_A"], flat[f"{li}_B"]) for li in linear}

    base = float(val_loss(None))
    # baseline model loss (no substitution) for context
    @jax.jit
    def plain_loss():
        tot = 0.0
        for k in range(4):
            ids, tgt = val_batches[k]
            tot += cross_entropy(forward_mixed(params, {}, None, ids, cfg), tgt)
        return tot / 4

    plain = float(plain_loss())

    # ---- LoRA refinement of the substituted layers only
    d = cfg.d_model
    lora_flat = {}
    for li in linear:
        lora_flat[f"{li}_A"] = jnp.asarray(
            rng.standard_normal((d, rank), dtype=np.float32) * 0.01)
        lora_flat[f"{li}_B"] = jnp.zeros((rank, d), jnp.float32)

    tb = make_batcher(train, TRAIN.batch_size, TRAIN.seq_len, 123)

    def loss_fn(flat, ids, tgt):
        return cross_entropy(forward_mixed(params, linear, unflatten(flat), ids, cfg), tgt)

    @jax.jit
    def step(flat, ids, tgt):
        l, g = jax.value_and_grad(loss_fn)(flat, ids, tgt)
        return {k: v - lr * g[k] for k, v in flat.items()}, l

    for i in range(steps):
        ids, tgt = tb()
        lora_flat, l = step(lora_flat, ids, tgt)
        if i % 30 == 0:
            print(f"lora step {i}: train loss {float(l):.4f}", flush=True)

    tuned = float(val_loss(lora_flat))
    out = {
        "m": m,
        "rank": rank,
        "steps": steps,
        "baseline_val_loss": plain,
        "nbl_val_loss": base,
        "nbl_lora_val_loss": tuned,
        "nbl_val_ppl": float(np.exp(base)),
        "nbl_lora_val_ppl": float(np.exp(tuned)),
        "baseline_val_ppl": float(np.exp(plain)),
        "bounds": bounds,
        "linearized_layers": sorted(int(x) for x in linear),
    }
    path = os.path.join(ART, "lora_ablation.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"wrote {path}")
    # paper's finding: improvements are marginal
    gain = base - tuned
    print(f"LoRA gain over NBL alone: {gain:.4f} nats "
          f"({'marginal' if gain < 0.1 else 'significant'})")


if __name__ == "__main__":
    main()
