"""AOT pipeline: corpora -> train -> lower HLO grid -> goldens -> manifest.

Run as ``python -m compile.aot`` from python/ (the Makefile `artifacts`
target). Idempotent: each stage is skipped when its outputs already exist
(delete artifacts/ to force a rebuild).

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpora
from .configs import GRID, MAIN, MODELS, TRAIN, manifest_dict
from .kernels import ref
from .kernels.attention import attn_prefill_pallas
from .kernels.gram import gram_pallas
from .kernels.linear_block import linear_block_pallas
from .kernels.swiglu import mlp_block_pallas
from .model import capture_attn_io, forward, init_params, load_weights, save_weights
from .train import load_corpus_bytes, train_lm

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32scalar():
    return jax.ShapeDtypeStruct((), jnp.int32)


def i32vec(n):
    return jax.ShapeDtypeStruct((n,), jnp.int32)


# ---------------------------------------------------------------------------
# op definitions — the (name, fn, example_args) grid


def build_ops():
    """Yield (filename_stem, fn, example_args) for every executable.

    All models share (D, H, Hkv, dh, F, V, Tmax) so the grid serves every
    model; only n_layers differs and that lives in Rust's layer loop.
    """
    cfg = MAIN
    D, F, V, Tmax = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_ctx
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              head_dim=cfg.head_dim, theta=cfg.rope_theta, eps=cfg.norm_eps)
    dq, dkv, hkv, dh = cfg.d_q, cfg.d_kv, cfg.n_kv_heads, cfg.head_dim
    ops = []

    def attn_fn(x, nw, wq, wk, wv, wo):
        return ref.attn_prefill(x, nw, wq, wk, wv, wo, **kw)

    def attn_pallas_fn(x, nw, wq, wk, wv, wo):
        return attn_prefill_pallas(x, nw, wq, wk, wv, wo, **kw)

    def cached_fn(x, nw, wq, wk, wv, wo, kc, vc, pos):
        return ref.attn_cached(x, nw, wq, wk, wv, wo, kc, vc, pos, **kw)

    def prefill_chunk_fn(x, nw, wq, wk, wv, wo, kc, vc, pos):
        return ref.attn_prefill_chunk(x, nw, wq, wk, wv, wo, kc, vc, pos, **kw)

    def cached_rows_fn(x, nw, wq, wk, wv, wo, kc, vc, pos):
        return ref.attn_cached_rows(x, nw, wq, wk, wv, wo, kc, vc, pos, **kw)

    def mlp_fn(x, nw, w1, w3, w2):
        return (ref.mlp_block(x, nw, w1, w3, w2, eps=cfg.norm_eps),)

    def mlp_pallas_fn(x, nw, w1, w3, w2):
        return (mlp_block_pallas(x, nw, w1, w3, w2, eps=cfg.norm_eps),)

    def linear_fn(x, w, b):
        return (ref.linear_block(x, w, b),)

    def linear_pallas_fn(x, w, b):
        return (linear_block_pallas(x, w, b),)

    def head_fn(x, nw, wh):
        return (ref.head(x, nw, wh, eps=cfg.norm_eps),)

    def gram_fn(x, y):
        return ref.gram(x, y)

    def gram_pallas_fn(x, y):
        return gram_pallas(x, y)

    attn_w = (f32(D), f32(D, dq), f32(D, dkv), f32(D, dkv), f32(dq, D))
    for B in GRID.batches:
        for T in GRID.prefill_lens:
            ops.append((f"attn_prefill_b{B}_t{T}", attn_fn, (f32(B, T, D), *attn_w)))
            ops.append((
                f"cache_init_b{B}_t{T}",
                lambda k, v: ref.cache_init(k, v, Tmax),
                (f32(B, T, hkv, dh), f32(B, T, hkv, dh)),
            ))
            # chunked prefill: the cache-appending chunk op reuses the
            # prefill grid widths as chunk sizes (DESIGN.md §Chunked
            # prefill); the first chunk of an admission runs the fresh
            # attn_prefill + cache_init pair, later chunks consume the
            # prior KV through this op
            ops.append((
                f"attn_prefill_chunk_b{B}_t{T}", prefill_chunk_fn,
                (f32(B, T, D), *attn_w, f32(B, Tmax, hkv, dh),
                 f32(B, Tmax, hkv, dh), i32scalar()),
            ))
        for S in GRID.cached_lens:
            ops.append((
                f"attn_cached_b{B}_s{S}", cached_fn,
                (f32(B, S, D), *attn_w, f32(B, Tmax, hkv, dh),
                 f32(B, Tmax, hkv, dh), i32scalar()),
            ))
        # continuous-batching decode: per-row positions. s=1 is the plain
        # iteration; the wider widths are the speculative verify ops (one
        # call checks W draft tokens per occupied row — DESIGN.md
        # §Speculative iterations).
        for S in GRID.cached_lens:
            ops.append((
                f"attn_cached_rows_b{B}_s{S}", cached_rows_fn,
                (f32(B, S, D), *attn_w, f32(B, Tmax, hkv, dh),
                 f32(B, Tmax, hkv, dh), i32vec(B)),
            ))
        for T in GRID.pointwise_lens:
            ops.append((f"linear_block_b{B}_t{T}", linear_fn,
                        (f32(B, T, D), f32(D, D), f32(D))))
            ops.append((f"mlp_b{B}_t{T}", mlp_fn,
                        (f32(B, T, D), f32(D), f32(D, F), f32(D, F), f32(F, D))))
            ops.append((f"head_b{B}_t{T}", head_fn,
                        (f32(B, T, D), f32(D), f32(D, V))))
    # pallas parity variants (small shapes; see DESIGN.md §Perf)
    for B, T in GRID.pallas_shapes:
        ops.append((f"attn_prefill_pallas_b{B}_t{T}", attn_pallas_fn,
                    (f32(B, T, D), *attn_w)))
        ops.append((f"linear_block_pallas_b{B}_t{T}", linear_pallas_fn,
                    (f32(B, T, D), f32(D, D), f32(D))))
        ops.append((f"mlp_pallas_b{B}_t{T}", mlp_pallas_fn,
                    (f32(B, T, D), f32(D), f32(D, F), f32(D, F), f32(F, D))))
    # calibration gram: pallas is the default executable, jnp as fallback
    N, Dg = GRID.gram_n, GRID.gram_d
    ops.append((f"gram_n{N}_d{Dg}", gram_pallas_fn, (f32(N, Dg), f32(N, Dg))))
    ops.append((f"gram_jnp_n{N}_d{Dg}", gram_fn, (f32(N, Dg), f32(N, Dg))))
    return ops


def lower_all(out_dir: str, force=False):
    os.makedirs(out_dir, exist_ok=True)
    index = {}
    for name, fn, args in build_ops():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        index[name] = os.path.relpath(path, ART)
        if os.path.exists(path) and not force:
            continue
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*args))
        with open(path + ".tmp", "w") as f:
            f.write(text)
        os.replace(path + ".tmp", path)
        print(f"  lowered {name} ({len(text)//1024} KiB, {time.time()-t0:.1f}s)",
              flush=True)
    return index


# ---------------------------------------------------------------------------
# training stage


def train_all():
    os.makedirs(ART, exist_ok=True)
    corpus_dir = os.path.join(ART, "corpora")
    if not os.path.exists(os.path.join(corpus_dir, "tinyc4_train.txt")):
        print("generating corpora ...", flush=True)
        corpora.write_all(corpus_dir)

    c4 = load_corpus_bytes(os.path.join(corpus_dir, "tinyc4_train.txt"))
    wiki = load_corpus_bytes(os.path.join(corpus_dir, "tinywiki_train.txt"))
    mix = np.concatenate([c4, wiki])

    def wpath(name):
        return (os.path.join(ART, f"weights_{name}.bin"),
                os.path.join(ART, f"weights_{name}.json"))

    params = {}
    # all models see the c4+wiki mix: the eval tasks draw on both grammars
    # and the calibration ablation (F.1) swaps corpora
    jobs = [
        ("main", MODELS["main"], TRAIN.steps, mix, None),
        ("alt", MODELS["alt"], TRAIN.alt_steps, mix, None),
        # the draft is distilled from `main` (EAGLE-style: the draft must
        # mirror the verifier's distribution for high acceptance)
        ("draft", MODELS["draft"], TRAIN.draft_steps, mix, "main"),
        ("distill", MODELS["distill"], TRAIN.distill_steps, mix, "main"),
    ]
    for name, cfg, steps, data, teacher_name in jobs:
        bin_path, json_path = wpath(name)
        if os.path.exists(bin_path):
            print(f"[{name}] cached weights found, skipping train", flush=True)
            continue
        teacher = teacher_cfg = None
        if teacher_name is not None:
            tb, _ = wpath(teacher_name)
            teacher_cfg = MODELS[teacher_name]
            teacher = params.get(teacher_name) or load_weights(teacher_cfg, tb)
        p = train_lm(cfg, TRAIN, data, steps,
                     os.path.join(ART, f"train_log_{name}.json"),
                     teacher=teacher, teacher_cfg=teacher_cfg)
        save_weights(p, cfg, bin_path, json_path)
        params[name] = p


# ---------------------------------------------------------------------------
# goldens for rust parity tests


def write_goldens(path: str):
    """Fixed-prompt logits + per-layer attention I/O stats for the Rust
    integration tests (executor parity + calibration-capture parity)."""
    corpus = load_corpus_bytes(os.path.join(ART, "corpora", "tinyc4_val.txt"))
    prompt = corpus[:32].astype(np.int32)[None, :]  # [1,32]
    goldens = {"prompt": prompt[0].tolist()}
    for name in ("main", "alt", "distill", "draft"):
        cfg = MODELS[name]
        params = load_weights(cfg, os.path.join(ART, f"weights_{name}.bin"))
        ids = jnp.asarray(prompt)
        logits = np.asarray(forward(params, ids, cfg))[0]  # [32,V]
        caps = capture_attn_io(params, ids, cfg)
        goldens[name] = {
            "logits_last": logits[-1].tolist(),
            "logits_mean": float(logits.mean()),
            "logits_std": float(logits.std()),
            "argmax_per_pos": logits.argmax(-1).tolist(),
            "attn_io": [
                {
                    "x_mean": float(np.asarray(x).mean()),
                    "x_std": float(np.asarray(x).std()),
                    "y_mean": float(np.asarray(y).mean()),
                    "y_std": float(np.asarray(y).std()),
                }
                for x, y in caps
            ],
        }
    with open(path, "w") as f:
        json.dump(goldens, f)
    print(f"wrote goldens to {path}", flush=True)


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-train", action="store_true")
    ap.add_argument("--force-lower", action="store_true")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    if not args.skip_train:
        train_all()
    print("lowering HLO grid ...", flush=True)
    hlo_index = lower_all(os.path.join(ART, "hlo"), force=args.force_lower)
    goldens_path = os.path.join(ART, "goldens.json")
    if not os.path.exists(goldens_path) and not args.skip_train:
        write_goldens(goldens_path)

    manifest = manifest_dict()
    manifest["hlo"] = hlo_index
    manifest["weights"] = {
        name: {"bin": f"weights_{name}.bin", "manifest": f"weights_{name}.json"}
        for name in MODELS
    }
    manifest["corpora"] = {
        f"{name}_{split}": f"corpora/{name}_{split}.txt"
        for name, _, _, _ in corpora.CORPORA
        for split in ("train", "val")
    }
    manifest["goldens"] = "goldens.json"
    with open(os.path.join(ART, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("manifest written; artifacts complete.", flush=True)


if __name__ == "__main__":
    main()
