"""L2: the JAX model — parameter pytree, full forward, op-level functions.

Two lowering paths share the same math:
- ``ref``-backed (plain jnp): used for training and as the default AOT
  lowering (fastest on the CPU PJRT backend that serves requests);
- Pallas-backed: the L1 kernels, lowered as parity variants and validated
  by pytest + a Rust integration test.

The parameter layout here defines the on-disk ``weights_{model}.bin``
format consumed by ``rust/src/model/weights.rs`` — keep the two in sync
(order: emb, per-layer [attn_norm, wq, wk, wv, wo, mlp_norm, w1, w3, w2],
final_norm, w_head; all f32 little-endian, row-major).
"""

import json
import struct

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref


# ---------------------------------------------------------------------------
# parameters

LAYER_TENSORS = ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w1", "w3", "w2")


def layer_shapes(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "attn_norm": (d,),
        "wq": (d, cfg.d_q),
        "wk": (d, cfg.d_kv),
        "wv": (d, cfg.d_kv),
        "wo": (cfg.d_q, d),
        "mlp_norm": (d,),
        "w1": (d, f),
        "w3": (d, f),
        "w2": (f, d),
    }


def init_params(cfg: ModelConfig):
    rng = np.random.default_rng(cfg.seed)
    d = cfg.d_model

    def dense(shape, fan_in):
        return jnp.asarray(
            rng.standard_normal(shape, dtype=np.float32) / np.sqrt(fan_in)
        )

    shapes = layer_shapes(cfg)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                name: (
                    jnp.ones(shape, jnp.float32)
                    if name.endswith("norm")
                    else dense(shape, shape[0])
                )
                for name, shape in shapes.items()
            }
        )
    return {
        "emb": dense((cfg.vocab, d), d),
        "layers": layers,
        "final_norm": jnp.ones((d,), jnp.float32),
        "w_head": dense((d, cfg.vocab), d),
    }


# ---------------------------------------------------------------------------
# forward (training / golden path, pure jnp)


def forward(params, ids, cfg: ModelConfig):
    """ids [B,T] int32 -> logits [B,T,V]. Full causal forward."""
    kw = dict(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        theta=cfg.rope_theta,
        eps=cfg.norm_eps,
    )
    x = params["emb"][ids]
    for lp in params["layers"]:
        x, _, _ = ref.attn_prefill(
            x, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"], lp["wo"], **kw
        )
        x = ref.mlp_block(x, lp["mlp_norm"], lp["w1"], lp["w3"], lp["w2"],
                          eps=cfg.norm_eps)
    return ref.head(x, params["final_norm"], params["w_head"], eps=cfg.norm_eps)


def capture_attn_io(params, ids, cfg: ModelConfig):
    """Per-layer (X = attn-block input, Y = attn delta) for golden parity
    with the Rust calibration capture path."""
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              head_dim=cfg.head_dim, theta=cfg.rope_theta, eps=cfg.norm_eps)
    x = params["emb"][ids]
    captures = []
    for lp in params["layers"]:
        y, _, _ = ref.attn_prefill(
            x, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"], lp["wo"], **kw
        )
        captures.append((x, y - x))  # (input, attention delta)
        x = y
        x = ref.mlp_block(x, lp["mlp_norm"], lp["w1"], lp["w3"], lp["w2"],
                          eps=cfg.norm_eps)
    return captures


# ---------------------------------------------------------------------------
# serialization (consumed by rust/src/model/weights.rs)


def flatten_named(params, cfg: ModelConfig):
    """Canonical (name, array) list defining the .bin layout."""
    out = [("emb", params["emb"])]
    for i, lp in enumerate(params["layers"]):
        for name in LAYER_TENSORS:
            out.append((f"layers.{i}.{name}", lp[name]))
    out.append(("final_norm", params["final_norm"]))
    out.append(("w_head", params["w_head"]))
    return out

def save_weights(params, cfg: ModelConfig, bin_path: str, json_path: str):
    tensors = []
    offset = 0
    with open(bin_path, "wb") as f:
        for name, arr in flatten_named(params, cfg):
            a = np.asarray(arr, dtype=np.float32)
            raw = a.tobytes()  # row-major
            f.write(raw)
            tensors.append(
                {
                    "name": name,
                    "shape": list(a.shape),
                    "offset_bytes": offset,
                    "size_bytes": len(raw),
                }
            )
            offset += len(raw)
    manifest = {
        "model": cfg.name,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "max_ctx": cfg.max_ctx,
            "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
        },
        "total_bytes": offset,
        "tensors": tensors,
    }
    with open(json_path, "w") as f:
        json.dump(manifest, f, indent=1)


def load_weights(cfg: ModelConfig, bin_path: str):
    """Inverse of save_weights (used by tests and the LoRA ablation)."""
    data = np.fromfile(bin_path, dtype=np.float32)
    pos = 0

    def take(shape):
        nonlocal pos
        n = int(np.prod(shape))
        arr = jnp.asarray(data[pos : pos + n].reshape(shape))
        pos += n
        return arr

    params = {"emb": take((cfg.vocab, cfg.d_model))}
    shapes = layer_shapes(cfg)
    params["layers"] = [
        {name: take(shapes[name]) for name in LAYER_TENSORS}
        for _ in range(cfg.n_layers)
    ]
    params["final_norm"] = take((cfg.d_model,))
    params["w_head"] = take((cfg.d_model, cfg.vocab))
    assert pos == data.size
    return params
