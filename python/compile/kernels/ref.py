"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package has its semantics defined here; pytest
(+hypothesis) asserts allclose between the kernel and these functions. The
trainer also runs on these ops (training speed on CPU matters more than
exercising interpret-mode Pallas during the build), so trained weights are
by construction compatible with both lowering paths.

Shapes follow DESIGN.md:
  x        [B, T, D]     residual stream
  wq       [D, H*dh]     query projection
  wk, wv   [D, Hkv*dh]   grouped key/value projections
  wo       [H*dh, D]     output projection
  kcache   [B, Tmax, Hkv, dh]  (keys stored post-RoPE)
"""

import jax
import jax.numpy as jnp


def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_angles(positions, head_dim, theta=10000.0):
    """positions [T] (int) -> (cos, sin) each [T, head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., T, n_heads, head_dim]; cos/sin [T, head_dim//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _proj_qkv(xn, wq, wk, wv, n_heads, n_kv_heads, head_dim):
    B, T, _ = xn.shape
    q = (xn @ wq).reshape(B, T, n_heads, head_dim)
    k = (xn @ wk).reshape(B, T, n_kv_heads, head_dim)
    v = (xn @ wv).reshape(B, T, n_kv_heads, head_dim)
    return q, k, v


def _sdpa(q, k, v, mask, n_heads, n_kv_heads):
    """q [B,Tq,H,dh]; k,v [B,Tk,Hkv,dh]; mask [Tq,Tk] bool (True=visible)."""
    group = n_heads // n_kv_heads
    kr = jnp.repeat(k, group, axis=2)  # [B,Tk,H,dh]
    vr = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # [B,H,Tq,Tk]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    return out.reshape(q.shape[0], q.shape[1], -1)


def attn_prefill(x, normw, wq, wk, wv, wo, *, n_heads, n_kv_heads,
                 head_dim, theta=10000.0, eps=1e-5):
    """Fresh causal self-attention block. Returns (y, k_roped, v)."""
    B, T, D = x.shape
    xn = rms_norm(x, normw, eps)
    q, k, v = _proj_qkv(xn, wq, wk, wv, n_heads, n_kv_heads, head_dim)
    cos, sin = rope_angles(jnp.arange(T), head_dim, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    out = _sdpa(q, k, v, mask, n_heads, n_kv_heads)
    y = x + out @ wo
    return y, k, v


def cache_init(k, v, max_ctx):
    """Zero-pad prefill K/V [B,T,Hkv,dh] into cache layout [B,Tmax,Hkv,dh]."""
    B, T, Hkv, dh = k.shape
    pad = [(0, 0), (0, max_ctx - T), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


def attn_cached(x, normw, wq, wk, wv, wo, kcache, vcache, pos, *,
                n_heads, n_kv_heads, head_dim, theta=10000.0, eps=1e-5):
    """S new tokens attend over a device-resident cache.

    x [B,S,D]; caches [B,Tmax,Hkv,dh]; pos scalar int32 = number of tokens
    already cached (shared by the batch group — see DESIGN.md).
    Returns (y, kcache', vcache').
    """
    B, S, D = x.shape
    Tmax = kcache.shape[1]
    xn = rms_norm(x, normw, eps)
    q, k, v = _proj_qkv(xn, wq, wk, wv, n_heads, n_kv_heads, head_dim)
    positions = pos + jnp.arange(S)
    cos, sin = rope_angles(positions, head_dim, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kcache = jax.lax.dynamic_update_slice(kcache, k, (0, pos, 0, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v, (0, pos, 0, 0))
    # query i (absolute pos+i) sees cache slot j iff j <= pos+i
    mask = jnp.arange(Tmax)[None, :] <= (pos + jnp.arange(S))[:, None]
    out = _sdpa(q, kcache, vcache, mask, n_heads, n_kv_heads)
    y = x + out @ wo
    return y, kcache, vcache


def attn_prefill_chunk(x, normw, wq, wk, wv, wo, kcache, vcache, pos, *,
                       n_heads, n_kv_heads, head_dim, theta=10000.0, eps=1e-5):
    """Cache-appending prefill chunk (chunked prefill, DESIGN.md §Chunked
    prefill): T new prompt tokens attend causally over the cache built by
    earlier chunks plus themselves, and append their K/V at [pos, pos+T).

    Semantically identical to ``attn_cached`` — a prefill chunk IS a
    wide cached step — but lowered as its own op family at the *prefill*
    grid widths (``attn_prefill_b{B}_t{T}`` chunk sizes), so the serving
    scheduler can split a long admission into grid-width chunks and
    interleave them with decode iterations. Kept as a separate name so
    artifact staleness is detectable per family (ci/check_artifacts.py).
    """
    return attn_cached(x, normw, wq, wk, wv, wo, kcache, vcache, pos,
                       n_heads=n_heads, n_kv_heads=n_kv_heads,
                       head_dim=head_dim, theta=theta, eps=eps)


def rope_angles_rows(positions, head_dim, theta=10000.0):
    """positions [B,S] (int) -> (cos, sin) each [B,S,head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope_rows(x, cos, sin):
    """x [B,S,H,dh]; cos/sin [B,S,head_dim//2] (per-row positions)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _sdpa_rows(q, k, v, mask, n_heads, n_kv_heads):
    """_sdpa with a per-row mask [B,Tq,Tk] (rows are independent requests)."""
    group = n_heads // n_kv_heads
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    return out.reshape(q.shape[0], q.shape[1], -1)


def attn_cached_rows(x, normw, wq, wk, wv, wo, kcache, vcache, pos, *,
                     n_heads, n_kv_heads, head_dim, theta=10000.0, eps=1e-5):
    """Continuous-batching decode: every batch row owns its cache segment.

    x [B,S,D]; caches [B,Tmax,Hkv,dh]; pos [B] int32 = tokens already
    cached *per row*. Rows are independent requests at independent
    positions (the dynamic decode group of DESIGN.md); the caller ignores
    the outputs of free rows (which pass pos=0 and a pad token).
    Returns (y, kcache', vcache').

    Semantically this is `attn_cached` vmapped over the batch with a
    per-row scalar pos — RoPE, cache write slot and causal mask all use
    the row's own position.
    """
    B, S, D = x.shape
    Tmax = kcache.shape[1]
    xn = rms_norm(x, normw, eps)
    q, k, v = _proj_qkv(xn, wq, wk, wv, n_heads, n_kv_heads, head_dim)
    positions = pos[:, None] + jnp.arange(S)[None, :]  # [B,S]
    cos, sin = rope_angles_rows(positions, head_dim, theta)
    q = apply_rope_rows(q, cos, sin)
    k = apply_rope_rows(k, cos, sin)
    # scatter the S new K/V per row into that row's slots [pos_b, pos_b+S)
    onehot = (jnp.arange(Tmax)[None, :, None]
              == positions[:, None, :]).astype(x.dtype)      # [B,Tmax,S]
    written = onehot.sum(-1)[..., None, None]                # [B,Tmax,1,1]
    kcache = kcache * (1.0 - written) + jnp.einsum("bts,bshd->bthd", onehot, k)
    vcache = vcache * (1.0 - written) + jnp.einsum("bts,bshd->bthd", onehot, v)
    # row b, query i (absolute pos_b+i) sees cache slot j iff j <= pos_b+i
    mask = jnp.arange(Tmax)[None, None, :] <= positions[:, :, None]
    out = _sdpa_rows(q, kcache, vcache, mask, n_heads, n_kv_heads)
    y = x + out @ wo
    return y, kcache, vcache


def linear_block(x, w, b):
    """The NBL substitution: y = x + x @ W + b (residual kept, Prop 3.1).

    W absorbs the whole norm+attention sub-block input->output map; it is
    fitted on (X = residual-stream input, Y = attention-block delta).
    """
    return x + x @ w + b


def mlp_block(x, normw, w1, w3, w2, eps=1e-5):
    """Pre-norm SwiGLU MLP block with residual."""
    xn = rms_norm(x, normw, eps)
    h = jax.nn.silu(xn @ w1) * (xn @ w3)
    return x + h @ w2


def head(x, normw, wout, eps=1e-5):
    """Final RMSNorm + LM head. x [B,T,D] -> logits [B,T,V]."""
    return rms_norm(x, normw, eps) @ wout


def gram(x, y):
    """Calibration accumulation: (X^T X, X^T Y, sum X, sum Y).

    x, y [N, D]; the Rust side streams chunks of N rows through this and
    combines into covariance/cross-covariance (stats::covariance).
    """
    return x.T @ x, x.T @ y, jnp.sum(x, axis=0), jnp.sum(y, axis=0)
