"""Pallas flash-attention kernel (causal, grouped-query).

This is the paper's O(n^2 d) hot spot — the cost NBL removes when a layer
is linearized. The kernel follows the standard flash/online-softmax
structure, re-thought for TPU per DESIGN.md §Hardware-Adaptation:

- the grid is (batch, q_head, q_tile); each step holds one q tile of
  ``block_q`` rows plus the full K/V stripe for its kv-head in VMEM
  (T<=512, dh=32 -> 64 KiB per stripe, comfortably VMEM-resident), and
  streams kv tiles of ``block_k`` rows through the MXU with a running
  (max, denominator, accumulator) triple;
- grouped-query attention is expressed in the BlockSpec index maps
  (q head h reads kv head h // group), not by materializing repeated K/V
  as the jnp reference does — that repeat is pure HBM waste on TPU;
- the causal mask is applied per kv tile from absolute indices.

Lowered with ``interpret=True`` everywhere (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against kernels.ref by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, causal):
    # q_ref [1,1,block_q,dh]; k_ref/v_ref [1,1,T,dh]; o_ref like q_ref.
    iq = pl.program_id(2)
    t_kv = k_ref.shape[2]
    dh = q_ref.shape[3]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    q = q_ref[0, 0] * scale                       # [bq, dh]
    k_all = k_ref[0, 0]                           # [T, dh]
    v_all = v_ref[0, 0]

    q_pos = iq * block_q + jnp.arange(block_q)    # absolute q indices
    n_kv = t_kv // block_k

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_all, (j * block_k, 0), (block_k, dh))
        v = jax.lax.dynamic_slice(v_all, (j * block_k, 0), (block_k, dh))
        s = q @ k.T                               # [bq, bk]
        if causal:
            k_pos = j * block_k + jnp.arange(block_k)
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    o_ref[0, 0] = acc / l


def flash_attention(q, k, v, *, causal=True, block_q=64, block_k=64):
    """q [B,H,T,dh]; k,v [B,Hkv,T,dh] -> o [B,H,T,dh]."""
    B, H, T, dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, T)
    assert T % block_q == 0 and T % block_k == 0

    grid = (B, H, T // block_q)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, T, dh), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, T, dh), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def attn_prefill_pallas(x, normw, wq, wk, wv, wo, *, n_heads, n_kv_heads,
                        head_dim, theta=10000.0, eps=1e-5,
                        block_q=64, block_k=64):
    """Full attention block with the SDPA inner loop on the Pallas kernel.

    Matches kernels.ref.attn_prefill bit-for-bit structure: RMSNorm, QKV
    projections and RoPE are plain XLA ops (single fused matmuls), the
    quadratic part runs in the flash kernel. Returns (y, k_roped, v).
    """
    from . import ref

    B, T, D = x.shape
    xn = ref.rms_norm(x, normw, eps)
    q, k, v = ref._proj_qkv(xn, wq, wk, wv, n_heads, n_kv_heads, head_dim)
    cos, sin = ref.rope_angles(jnp.arange(T), head_dim, theta)
    q = ref.apply_rope(q, cos, sin)
    k = ref.apply_rope(k, cos, sin)
    # [B,T,H,dh] -> [B,H,T,dh] kernel layout
    o = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        block_q=block_q, block_k=block_k,
    )
    out = o.transpose(0, 2, 1, 3).reshape(B, T, n_heads * head_dim)
    y = x + out @ wo
    return y, k, v
