"""Pallas kernel for the NBL replacement path: y = x + x @ W + b.

This is the O(n d) block that replaces a linearized attention layer —
the *other* half of the paper's trade. On TPU it is a pure MXU workload:
one [block_t, D] x [D, D] matmul per grid step with W held in VMEM
(D=256 -> 256 KiB, resident across the whole grid), no softmax/VPU work
and no KV traffic. The speed-up the paper reports is exactly this
kernel's roofline vs. the flash kernel's.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_block_kernel(x_ref, w_ref, b_ref, o_ref):
    # x_ref [1, block_t, D]; w_ref [D, D]; b_ref [1, D]; o_ref like x_ref.
    x = x_ref[0]
    o_ref[0] = x + x @ w_ref[...] + b_ref[0][None, :]


def linear_block_pallas(x, w, b, *, block_t=64):
    """x [B,T,D]; w [D,D]; b [D] -> x + x@W + b."""
    B, T, D = x.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    grid = (B, T // block_t)
    return pl.pallas_call(
        functools.partial(_linear_block_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, D), lambda b_, i: (b_, i, 0)),
            pl.BlockSpec((D, D), lambda b_, i: (0, 0)),
            pl.BlockSpec((1, D), lambda b_, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, D), lambda b_, i: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, D))
