"""Pallas kernel for calibration Gram accumulation.

Computes (X^T X, X^T Y, sum X, sum Y) over a [N, D] activation chunk —
the O(s*t*d^2) term of the paper's calibration cost (App. D.1). The grid
walks N in tiles and accumulates into D x D output blocks that every grid
step maps to the same block (the TPU analogue of split-K reduction: the
accumulator lives in VMEM for the whole pass instead of round-tripping
partial sums through HBM).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, y_ref, gxx_ref, gxy_ref, sx_ref, sy_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gxx_ref[...] = jnp.zeros_like(gxx_ref)
        gxy_ref[...] = jnp.zeros_like(gxy_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sy_ref[...] = jnp.zeros_like(sy_ref)

    x = x_ref[...]                                 # [block_n, D]
    y = y_ref[...]
    gxx_ref[...] += x.T @ x
    gxy_ref[...] += x.T @ y
    sx_ref[...] += jnp.sum(x, axis=0, keepdims=True)
    sy_ref[...] += jnp.sum(y, axis=0, keepdims=True)


def gram_pallas(x, y, *, block_n=256):
    """x, y [N,D] -> (X^T X [D,D], X^T Y [D,D], sum X [D], sum Y [D])."""
    N, D = x.shape
    block_n = min(block_n, N)
    assert N % block_n == 0
    grid = (N // block_n,)
    gxx, gxy, sx, sy = pl.pallas_call(
        functools.partial(_gram_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((D, D), lambda i: (0, 0)),
            pl.BlockSpec((D, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
        ],
        interpret=True,
    )(x, y)
    return gxx, gxy, sx[0], sy[0]
