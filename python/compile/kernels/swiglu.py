"""Pallas kernel for the fused pre-norm SwiGLU MLP block.

Fuses RMSNorm -> (x@W1, x@W3) -> silu gate -> @W2 -> residual add in one
VMEM round-trip per tile: on TPU the naive lowering writes the [T, F]
gate activations back to HBM twice; keeping the tile resident halves the
block's HBM traffic. W1/W3/W2 stay VMEM-resident across the grid
(D=256, F=512 -> 3 * 512 KiB).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, nw_ref, w1_ref, w3_ref, w2_ref, o_ref, *, eps):
    x = x_ref[0]                                   # [block_t, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * nw_ref[0][None, :]
    g = xn @ w1_ref[...]
    h = (g * jax.nn.sigmoid(g)) * (xn @ w3_ref[...])   # silu(g) * up
    o_ref[0] = x + h @ w2_ref[...]


def mlp_block_pallas(x, normw, w1, w3, w2, *, eps=1e-5, block_t=64):
    """x [B,T,D] -> x + swiglu(rmsnorm(x)) — matches ref.mlp_block."""
    B, T, D = x.shape
    F = w1.shape[1]
    block_t = min(block_t, T)
    assert T % block_t == 0
    grid = (B, T // block_t)
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, D), lambda b_, i: (b_, i, 0)),
            pl.BlockSpec((1, D), lambda b_, i: (0, 0)),
            pl.BlockSpec((D, F), lambda b_, i: (0, 0)),
            pl.BlockSpec((D, F), lambda b_, i: (0, 0)),
            pl.BlockSpec((F, D), lambda b_, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, D), lambda b_, i: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=True,
    )(x, normw.reshape(1, D), w1, w3, w2)
