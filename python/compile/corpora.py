"""Synthetic calibration / training corpora.

The paper calibrates on C4 and WikiText-2 (256 samples each) and uses the
calibration-set choice as an ablation axis (App. F.1). We have no network
access, so we generate two deterministic synthetic corpora with clearly
*different* statistics (DESIGN.md §2):

- ``tiny-c4``   — templated prose from a small PCFG: subject/verb/object
                  sentences, relative clauses, numbers, quotes.
- ``tiny-wiki`` — structured encyclopedia-style text: `== headings ==`,
                  definition sentences, bulleted lists, infobox-ish
                  `key: value` lines.

Both are plain ASCII so the byte-level tokenizer (vocab 256) covers them.
The grammars are intentionally learnable by a ~6M-param model in a few
hundred steps, while still having enough entropy that compression damage
shows up in perplexity and task accuracy.

The Rust side re-reads the generated .txt files; generation happens only
here (build time) so both languages see byte-identical data.
"""

import random

NOUNS = [
    "robot", "garden", "river", "engine", "signal", "cache", "kernel",
    "matrix", "tensor", "packet", "planet", "crystal", "circuit", "library",
    "model", "window", "market", "forest", "valley", "beacon",
]
ADJS = [
    "small", "bright", "hidden", "rapid", "quiet", "linear", "sparse",
    "dense", "ancient", "modern", "stable", "fragile", "deep", "shallow",
]
VERBS_T = [
    "moves", "computes", "stores", "routes", "compresses", "observes",
    "updates", "encodes", "decodes", "balances", "measures", "predicts",
]
ADVS = ["quickly", "slowly", "carefully", "rarely", "often", "silently"]
PLACES = ["the north field", "the old town", "the data hall", "the lab",
          "the harbor", "the archive"]
NAMES = ["arin", "bela", "cato", "dara", "evin", "fara", "goran", "hale"]

WIKI_TOPICS = [
    "linear estimator", "canonical analysis", "block cipher", "query cache",
    "token router", "systolic array", "prefix tree", "ring buffer",
    "hash table", "state machine", "packet filter", "page allocator",
]
WIKI_FIELDS = ["type", "origin", "status", "class", "order", "family"]
WIKI_VALUES = ["primary", "secondary", "derived", "classical", "modern",
               "composite", "atomic", "stable", "deprecated"]


def _c4_sentence(rng: random.Random) -> str:
    r = rng.random()
    n1, n2 = rng.choice(NOUNS), rng.choice(NOUNS)
    a1, a2 = rng.choice(ADJS), rng.choice(ADJS)
    v = rng.choice(VERBS_T)
    if r < 0.35:
        return f"the {a1} {n1} {v} the {n2} {rng.choice(ADVS)}."
    if r < 0.6:
        return (f"{rng.choice(NAMES)} said that the {n1} near {rng.choice(PLACES)}"
                f" {v} every {a2} {n2}.")
    if r < 0.8:
        k = rng.randint(2, 99)
        return f"there are {k} {a1} {n1}s in {rng.choice(PLACES)}."
    return (f"when the {n1} {v} the {n2}, the {a1} {rng.choice(NOUNS)}"
            f" {rng.choice(VERBS_T)} {rng.choice(ADVS)}.")


def gen_tiny_c4(n_chars: int, seed: int) -> str:
    rng = random.Random(seed)
    parts = []
    total = 0
    while total < n_chars:
        para = " ".join(_c4_sentence(rng) for _ in range(rng.randint(3, 7)))
        parts.append(para)
        total += len(para) + 1
    return "\n".join(parts)[:n_chars]


def _wiki_article(rng: random.Random) -> str:
    topic = rng.choice(WIKI_TOPICS)
    lines = [f"== {topic} =="]
    lines.append(
        f"a {topic} is a {rng.choice(ADJS)} {rng.choice(NOUNS)} that "
        f"{rng.choice(VERBS_T)} {rng.choice(['data', 'state', 'tokens', 'blocks'])}."
    )
    for _ in range(rng.randint(2, 4)):
        lines.append(f"{rng.choice(WIKI_FIELDS)}: {rng.choice(WIKI_VALUES)}")
    lines.append("properties:")
    for _ in range(rng.randint(2, 5)):
        lines.append(f"* {rng.choice(ADJS)} {rng.choice(NOUNS)}"
                     f" ({rng.randint(1, 9)})")
    return "\n".join(lines)


def gen_tiny_wiki(n_chars: int, seed: int) -> str:
    rng = random.Random(seed)
    parts = []
    total = 0
    while total < n_chars:
        art = _wiki_article(rng)
        parts.append(art)
        total += len(art) + 2
    return "\n\n".join(parts)[:n_chars]


# (name, generator, train_seed, val_seed)
CORPORA = [
    ("tinyc4", gen_tiny_c4, 11, 12),
    ("tinywiki", gen_tiny_wiki, 21, 22),
]

TRAIN_CHARS = 400_000
VAL_CHARS = 40_000


def write_all(out_dir: str) -> dict:
    """Generate every corpus split into out_dir; returns file index."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    index = {}
    for name, gen, s_tr, s_va in CORPORA:
        for split, seed, chars in (
            ("train", s_tr, TRAIN_CHARS),
            ("val", s_va, VAL_CHARS),
        ):
            text = gen(chars, seed)
            assert all(ord(c) < 256 for c in text)
            path = os.path.join(out_dir, f"{name}_{split}.txt")
            with open(path, "w") as f:
                f.write(text)
            index[f"{name}_{split}"] = {"path": path, "chars": len(text)}
    return index
