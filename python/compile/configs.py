"""Model and AOT-grid configuration shared by the whole build pipeline.

The repo trains several tiny Llama-style models at build time (see
DESIGN.md §2 for why these stand in for the paper's 7B/8B/70B models):

- ``main``    — the primary 8-layer model (paper's Mistral-7B slot)
- ``alt``     — a 10-layer variant, different seed (Llama-3.1-8B slot)
- ``distill`` — 8 layers, distilled from ``main`` via logit matching
                (DeepSeek-R1-Distill slot)
- ``draft``   — 2-layer draft model for speculative decoding (EAGLE slot)

All variants share (vocab, d_model, heads, head_dim, ff) so one AOT
executable grid serves every model: the executables take weights as
runtime arguments, only (batch, seqlen) are baked in.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Sized for the single-core CPU build environment (DESIGN.md §2):
    same architecture family as the paper's models, scaled down so that
    build-time training + calibration + the full bench grid fit the
    session budget. All NBL math is dimension-generic."""

    name: str
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 6
    n_heads: int = 4
    n_kv_heads: int = 2       # grouped-query attention
    head_dim: int = 32
    d_ff: int = 256           # SwiGLU hidden size
    max_ctx: int = 512        # Tmax: KV-cache capacity
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    seed: int = 0

    @property
    def d_q(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = (
            d * self.d_q + 2 * d * self.d_kv + self.d_q * d  # wq wk wv wo
            + 3 * d * f                                       # w1 w3 w2
            + 2 * d                                           # two norms
        )
        return v * d + self.n_layers * per_layer + d + d * v  # emb + final norm + head


MAIN = ModelConfig(name="main", n_layers=6, seed=1001)
ALT = ModelConfig(name="alt", n_layers=8, seed=2002)
DISTILL = ModelConfig(name="distill", n_layers=6, seed=3003)
DRAFT = ModelConfig(name="draft", n_layers=2, seed=4004)

MODELS = {m.name: m for m in (MAIN, ALT, DISTILL, DRAFT)}


@dataclass(frozen=True)
class AotGrid:
    """Static shape grid lowered by aot.py.

    Every (op, batch, seqlen) pair becomes one HLO-text artifact; weights
    are runtime arguments so executables are shared across layers/models.
    """

    batches: tuple = (1, 8)
    prefill_lens: tuple = (32, 128, 512)          # attn_prefill / cache_init
    cached_lens: tuple = (1, 4)                   # attn_cached: decode / spec-verify
    pointwise_lens: tuple = (1, 4, 32, 128, 512)  # linear_block / mlp / head
    gram_n: int = 4096                            # calibration chunk rows
    gram_d: int = 128
    # pallas-lowered parity variants (small shapes; jnp lowering is the
    # default serving path — see DESIGN.md §Perf for the rationale)
    pallas_shapes: tuple = ((1, 32), (1, 128))


GRID = AotGrid()


@dataclass
class TrainConfig:
    steps: int = 400
    batch_size: int = 8
    seq_len: int = 128
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    log_every: int = 20
    distill_steps: int = 250
    draft_steps: int = 250
    alt_steps: int = 350


TRAIN = TrainConfig()


def manifest_dict():
    return {
        "models": {k: asdict(v) for k, v in MODELS.items()},
        "grid": {
            "batches": list(GRID.batches),
            "prefill_lens": list(GRID.prefill_lens),
            "cached_lens": list(GRID.cached_lens),
            "pointwise_lens": list(GRID.pointwise_lens),
            "gram_n": GRID.gram_n,
            "gram_d": GRID.gram_d,
            "pallas_shapes": [list(s) for s in GRID.pallas_shapes],
        },
    }
