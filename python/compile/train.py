"""Build-time trainer for the tiny model zoo (main / alt / distill / draft).

Hand-rolled AdamW (optax is not available in this environment) + cosine
schedule with warmup. ``distill`` is trained with a KL term against the
``main`` teacher's logits (the DeepSeek-R1-Distill analogue, DESIGN.md §2).

This is the end-to-end training driver required by the brief: it trains a
real (small) transformer for a few hundred steps on the synthetic corpus
and logs the loss curve to artifacts/train_log_{model}.json, which
EXPERIMENTS.md records.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, TrainConfig
from .model import forward, init_params


def load_corpus_bytes(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        return np.frombuffer(f.read(), dtype=np.uint8)


def make_batcher(data: np.ndarray, batch_size: int, seq_len: int, seed: int):
    rng = np.random.default_rng(seed)

    def next_batch():
        starts = rng.integers(0, len(data) - seq_len - 1, size=batch_size)
        windows = np.stack([data[s : s + seq_len + 1] for s in starts])
        return (
            jnp.asarray(windows[:, :-1], jnp.int32),
            jnp.asarray(windows[:, 1:], jnp.int32),
        )

    return next_batch


def cross_entropy(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def kl_to_teacher(student_logits, teacher_logits, tau=1.0):
    pt = jax.nn.softmax(teacher_logits / tau, axis=-1)
    ls = jax.nn.log_softmax(student_logits / tau, axis=-1)
    lt = jax.nn.log_softmax(teacher_logits / tau, axis=-1)
    return jnp.mean(jnp.sum(pt * (lt - ls), axis=-1))


# ---------------------------------------------------------------------------
# AdamW


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, wd, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * wd * p

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def lr_schedule(step, base_lr, warmup, total):
    w = jnp.minimum(1.0, (step + 1) / warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    return base_lr * w * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


# ---------------------------------------------------------------------------
# training loops


def train_lm(cfg: ModelConfig, tc: TrainConfig, data: np.ndarray, steps: int,
             log_path: str, teacher=None, teacher_cfg=None):
    params = init_params(cfg)
    opt = adamw_init(params)
    batcher = make_batcher(data, tc.batch_size, tc.seq_len, cfg.seed + 7)

    if teacher is None:
        def loss_fn(p, ids, targets):
            return cross_entropy(forward(p, ids, cfg), targets)
    else:
        @jax.jit
        def teacher_logits(ids):
            return forward(teacher, ids, teacher_cfg)

        def loss_fn(p, ids, targets):
            logits = forward(p, ids, cfg)
            ce = cross_entropy(logits, targets)
            kl = kl_to_teacher(logits, teacher_logits(ids))
            return 0.5 * ce + 0.5 * kl

    @jax.jit
    def step_fn(p, o, ids, targets, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, ids, targets)
        p, o = adamw_update(p, grads, o, lr, tc.weight_decay)
        return p, o, loss

    log = {"model": cfg.name, "steps": [], "loss": [], "lr": [],
           "params": sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))}
    t0 = time.time()
    for step in range(steps):
        ids, targets = batcher()
        lr = lr_schedule(step, tc.lr, tc.warmup, steps)
        params, opt, loss = step_fn(params, opt, ids, targets, lr)
        if step % tc.log_every == 0 or step == steps - 1:
            log["steps"].append(step)
            log["loss"].append(float(loss))
            log["lr"].append(float(lr))
            print(f"[{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                  f"lr {float(lr):.2e} ({time.time()-t0:.0f}s)", flush=True)
    log["wall_seconds"] = time.time() - t0
    with open(log_path, "w") as f:
        json.dump(log, f, indent=1)
    return params
