"""Corpus generators: determinism, charset, distribution separation."""

import collections

from compile.corpora import gen_tiny_c4, gen_tiny_wiki


def test_deterministic():
    assert gen_tiny_c4(5000, 11) == gen_tiny_c4(5000, 11)
    assert gen_tiny_wiki(5000, 21) == gen_tiny_wiki(5000, 21)


def test_seed_changes_text():
    assert gen_tiny_c4(5000, 11) != gen_tiny_c4(5000, 12)


def test_ascii_only():
    for text in (gen_tiny_c4(20000, 1), gen_tiny_wiki(20000, 1)):
        assert all(ord(c) < 256 for c in text)
        assert all(ord(c) >= 9 for c in text)  # printable + \n


def test_exact_length():
    assert len(gen_tiny_c4(12345, 3)) == 12345
    assert len(gen_tiny_wiki(12345, 3)) == 12345


def test_distributions_differ():
    """tiny-wiki must be statistically distinct from tiny-c4 (the whole
    point of the calibration-dependency ablation, paper App. F.1)."""
    c4 = gen_tiny_c4(50000, 1)
    wiki = gen_tiny_wiki(50000, 1)
    # wiki has structural markers c4 never emits
    assert "==" in wiki and "==" not in c4
    assert "* " in wiki
    # unigram distributions measurably different (L1 distance)
    def dist(text):
        c = collections.Counter(text)
        total = sum(c.values())
        return {ch: n / total for ch, n in c.items()}
    d1, d2 = dist(c4), dist(wiki)
    l1 = sum(abs(d1.get(ch, 0) - d2.get(ch, 0)) for ch in set(d1) | set(d2))
    assert l1 > 0.1
