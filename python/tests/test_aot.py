"""AOT pipeline: op grid well-formedness + HLO text round-trip properties."""

import jax
import jax.numpy as jnp

from compile.aot import build_ops, to_hlo_text
from compile.configs import GRID, MAIN, MODELS


def test_grid_covers_design():
    names = {name for name, _, _ in build_ops()}
    for b in GRID.batches:
        for t in GRID.prefill_lens:
            assert f"attn_prefill_b{b}_t{t}" in names
            assert f"cache_init_b{b}_t{t}" in names
            # chunked prefill: cache-appending chunk at every prefill width
            assert f"attn_prefill_chunk_b{b}_t{t}" in names
        for s in GRID.cached_lens:
            assert f"attn_cached_b{b}_s{s}" in names
            # continuous-batching decode + speculative verify widths
            assert f"attn_cached_rows_b{b}_s{s}" in names
        for t in GRID.pointwise_lens:
            for op in ("linear_block", "mlp", "head"):
                assert f"{op}_b{b}_t{t}" in names
    assert f"gram_n{GRID.gram_n}_d{GRID.gram_d}" in names


def test_cached_widths_have_pointwise_ops():
    """Every cached/verify width needs the pointwise ops at the same
    width: Engine::decode_rows_batched runs mlp/linear_block/head at
    t{sw} alongside attn_cached_rows s{sw}. The two grid axes are
    independently editable, so the subset invariant is asserted here
    before artifact drift can strand the Rust fast path."""
    assert set(GRID.cached_lens) <= set(GRID.pointwise_lens)


def test_prefill_widths_have_pointwise_ops():
    """Chunked prefill runs mlp/linear_block/head at the chunk width
    (Engine::prefill_chunk), so every prefill width must also be a
    pointwise width — same drift guard as the cached-widths invariant."""
    assert set(GRID.prefill_lens) <= set(GRID.pointwise_lens)


def test_no_duplicate_names():
    names = [name for name, _, _ in build_ops()]
    assert len(names) == len(set(names))


def test_models_share_op_dims():
    """The whole grid is shared across models; anything dimension-bearing
    must agree (only n_layers/seed may differ)."""
    for m in MODELS.values():
        for attr in ("vocab", "d_model", "n_heads", "n_kv_heads",
                     "head_dim", "d_ff", "max_ctx"):
            assert getattr(m, attr) == getattr(MAIN, attr), attr


def test_hlo_text_is_parseable_entry():
    """Lower the smallest op and sanity-check the HLO text structure the
    Rust loader (HloModuleProto::from_text) expects."""
    ops = {name: (fn, args) for name, fn, args in build_ops()}
    fn, args = ops["linear_block_b1_t1"]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple (rust side unwraps with to_tuple*)
    assert "tuple" in text


def test_lowered_shapes_in_hlo():
    ops = {name: (fn, args) for name, fn, args in build_ops()}
    fn, args = ops["attn_prefill_b1_t32"]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    d, dq, dkv = MAIN.d_model, MAIN.d_q, MAIN.d_kv
    assert f"f32[1,32,{d}]" in text           # x / y
    assert f"f32[{d},{dq}]" in text            # wq
    assert f"f32[{d},{dkv}]" in text           # wk/wv
