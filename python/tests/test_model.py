"""L2 correctness: model assembly, cached-decode consistency, serialization."""

import os
import tempfile

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.configs import ModelConfig
from compile.kernels import ref
from compile.model import (
    capture_attn_io, flatten_named, forward, init_params, load_weights,
    save_weights,
)

TINY = ModelConfig(name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                   n_kv_heads=1, head_dim=16, d_ff=64, max_ctx=64, seed=7)


def test_forward_shapes_and_finite():
    params = init_params(TINY)
    ids = jnp.asarray(np.random.default_rng(0).integers(0, TINY.vocab, (2, 16)))
    logits = forward(params, ids, TINY)
    assert logits.shape == (2, 16, TINY.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    """Changing a future token must not change past logits."""
    params = init_params(TINY)
    rng = np.random.default_rng(1)
    ids = rng.integers(0, TINY.vocab, (1, 16))
    ids2 = ids.copy()
    ids2[0, -1] = (ids2[0, -1] + 1) % TINY.vocab
    l1 = np.asarray(forward(params, jnp.asarray(ids), TINY))
    l2 = np.asarray(forward(params, jnp.asarray(ids2), TINY))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(t0=st.sampled_from([4, 8, 12]), extra=st.sampled_from([1, 3]),
       seed=st.integers(0, 1000))
def test_cached_decode_matches_prefill(t0, extra, seed):
    """prefill(t0) + cached steps == prefill(t0+extra) — the invariant the
    Rust decode path relies on."""
    params = init_params(TINY)
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, TINY.vocab, (1, t0 + extra)))
    kw = dict(n_heads=TINY.n_heads, n_kv_heads=TINY.n_kv_heads,
              head_dim=TINY.head_dim, theta=TINY.rope_theta, eps=TINY.norm_eps)

    full = forward(params, ids, TINY)

    # layerwise: prefill first t0, then decode one token at a time
    x = params["emb"][ids[:, :t0]]
    caches = []
    for lp in params["layers"]:
        y, k, v = ref.attn_prefill(x, lp["attn_norm"], lp["wq"], lp["wk"],
                                   lp["wv"], lp["wo"], **kw)
        kc, vc = ref.cache_init(k, v, TINY.max_ctx)
        caches.append([kc, vc])
        x = ref.mlp_block(y, lp["mlp_norm"], lp["w1"], lp["w3"], lp["w2"])
    for step in range(extra):
        pos = t0 + step
        x = params["emb"][ids[:, pos : pos + 1]]
        for li, lp in enumerate(params["layers"]):
            y, kc, vc = ref.attn_cached(
                x, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                caches[li][0], caches[li][1], pos, **kw)
            caches[li] = [kc, vc]
            x = ref.mlp_block(y, lp["mlp_norm"], lp["w1"], lp["w3"], lp["w2"])
    last = ref.head(x, params["final_norm"], params["w_head"])
    np.testing.assert_allclose(last[:, 0], full[:, -1], rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), S=st.sampled_from([1, 4]))
def test_attn_cached_rows_matches_per_row_cached(seed, S):
    """attn_cached_rows == attn_cached applied row by row with that row's
    scalar pos — the invariant the continuous-batching decode group relies
    on (rows at different positions share one executable call). S=1 is the
    plain decode iteration, S>1 the speculative verify width: one call
    must check S tokens per row at per-row positions."""
    rng = np.random.default_rng(seed)
    B, D = 3, TINY.d_model
    kw = dict(n_heads=TINY.n_heads, n_kv_heads=TINY.n_kv_heads,
              head_dim=TINY.head_dim, theta=TINY.rope_theta, eps=TINY.norm_eps)
    w = (
        jnp.asarray(rng.standard_normal(D).astype(np.float32)),
        jnp.asarray(rng.standard_normal((D, TINY.n_heads * TINY.head_dim)).astype(np.float32) * 0.08),
        jnp.asarray(rng.standard_normal((D, TINY.n_kv_heads * TINY.head_dim)).astype(np.float32) * 0.08),
        jnp.asarray(rng.standard_normal((D, TINY.n_kv_heads * TINY.head_dim)).astype(np.float32) * 0.08),
        jnp.asarray(rng.standard_normal((TINY.n_heads * TINY.head_dim, D)).astype(np.float32) * 0.08),
    )
    x = jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal(
        (B, TINY.max_ctx, TINY.n_kv_heads, TINY.head_dim)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal(
        (B, TINY.max_ctx, TINY.n_kv_heads, TINY.head_dim)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, TINY.max_ctx - S, B), dtype=jnp.int32)

    y, kc2, vc2 = ref.attn_cached_rows(x, *w, kc, vc, pos, **kw)
    for b in range(B):
        yb, kb, vb = ref.attn_cached(x[b:b + 1], *w, kc[b:b + 1],
                                     vc[b:b + 1], int(pos[b]), **kw)
        np.testing.assert_allclose(y[b:b + 1], yb, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(kc2[b:b + 1], kb, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(vc2[b:b + 1], vb, rtol=2e-4, atol=2e-4)


def test_weights_round_trip():
    params = init_params(TINY)
    with tempfile.TemporaryDirectory() as d:
        bin_path = os.path.join(d, "w.bin")
        json_path = os.path.join(d, "w.json")
        save_weights(params, TINY, bin_path, json_path)
        loaded = load_weights(TINY, bin_path)
        for (n1, a1), (n2, a2) in zip(flatten_named(params, TINY),
                                      flatten_named(loaded, TINY)):
            assert n1 == n2
            np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_capture_attn_io_shapes():
    params = init_params(TINY)
    ids = jnp.asarray(np.random.default_rng(2).integers(0, TINY.vocab, (1, 8)))
    caps = capture_attn_io(params, ids, TINY)
    assert len(caps) == TINY.n_layers
    for x, y in caps:
        assert x.shape == (1, 8, TINY.d_model)
        assert y.shape == (1, 8, TINY.d_model)
    # Y is the attention *delta*: adding it back must reproduce the stream
    # (checked implicitly by test_cached_decode; here check nonzero)
    assert float(jnp.abs(caps[0][1]).max()) > 0


def test_linear_block_is_exact_for_linear_target():
    """If Y really is affine in X, LMMSE recovers it exactly and the
    substituted block is a perfect replacement (NMSE bound ~ 0)."""
    rng = np.random.default_rng(5)
    d = 16
    X = rng.standard_normal((500, d)).astype(np.float32)
    Wt = rng.standard_normal((d, d)).astype(np.float32) * 0.3
    bt = rng.standard_normal(d).astype(np.float32)
    Y = X @ Wt + bt
    # closed-form LMMSE (the math rust/src/nbl implements)
    mx, my = X.mean(0), Y.mean(0)
    Xc, Yc = X - mx, Y - my
    W = np.linalg.solve(Xc.T @ Xc, Xc.T @ Yc)
    b = my - mx @ W
    got = ref.linear_block(jnp.asarray(X[None]), jnp.asarray(W), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got)[0], X + Y, rtol=1e-3, atol=1e-3)
