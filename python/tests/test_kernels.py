"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels.ref).

Hypothesis sweeps shapes (and the GQA head grouping) — the CORE
correctness signal for the kernel layer. All kernels run interpret=True.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attn_prefill_pallas, flash_attention
from compile.kernels.gram import gram_pallas
from compile.kernels.linear_block import linear_block_pallas
from compile.kernels.swiglu import mlp_block_pallas

SET = settings(max_examples=12, deadline=None)


def rnd(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def attn_weights(rng, d, h, hkv, dh):
    return (
        rnd(rng, d),                       # norm
        rnd(rng, d, h * dh, scale=0.08),   # wq
        rnd(rng, d, hkv * dh, scale=0.08),
        rnd(rng, d, hkv * dh, scale=0.08),
        rnd(rng, h * dh, d, scale=0.08),
    )


# ---------------------------------------------------------------------------
# flash attention


@SET
@given(
    b=st.sampled_from([1, 2]),
    t=st.sampled_from([8, 16, 32, 64]),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 10_000),
)
def test_flash_attention_matches_sdpa(b, t, hkv, group, dh, seed):
    h = hkv * group
    rng = np.random.default_rng(seed)
    q = rnd(rng, b, t, h, dh)
    k = rnd(rng, b, t, hkv, dh)
    v = rnd(rng, b, t, hkv, dh)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    want = ref._sdpa(q, k, v, mask, h, hkv).reshape(b, t, h, dh)
    got = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        block_q=min(16, t), block_k=min(16, t),
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@SET
@given(
    t=st.sampled_from([16, 64]),
    bq=st.sampled_from([8, 16]),
    bk=st.sampled_from([4, 16]),
    seed=st.integers(0, 10_000),
)
def test_flash_attention_block_size_invariance(t, bq, bk, seed):
    """The online-softmax result must not depend on tiling choices."""
    rng = np.random.default_rng(seed)
    q = rnd(rng, 1, t, 2, 16)
    k = rnd(rng, 1, t, 2, 16)
    v = rnd(rng, 1, t, 2, 16)
    args = (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    base = flash_attention(*args, block_q=t, block_k=t)
    tiled = flash_attention(*args, block_q=bq, block_k=bk)
    np.testing.assert_allclose(tiled, base, rtol=2e-5, atol=2e-5)


def test_flash_attention_rejects_ragged_tiles():
    q = jnp.zeros((1, 2, 24, 16))
    with pytest.raises(AssertionError):
        flash_attention(q, q[:, :2], q[:, :2], block_q=16, block_k=16)


@SET
@given(
    b=st.sampled_from([1, 2]),
    t=st.sampled_from([16, 32]),
    seed=st.integers(0, 10_000),
)
def test_attn_prefill_pallas_matches_ref(b, t, seed):
    d, h, hkv, dh = 64, 4, 2, 16
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, t, d)
    w = attn_weights(rng, d, h, hkv, dh)
    kw = dict(n_heads=h, n_kv_heads=hkv, head_dim=dh)
    y0, k0, v0 = ref.attn_prefill(x, *w, **kw)
    y1, k1, v1 = attn_prefill_pallas(x, *w, block_q=16, block_k=16, **kw)
    np.testing.assert_allclose(y1, y0, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(k1, k0, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(v1, v0, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# chunked prefill (cache-appending chunk op vs one whole prefill)


@SET
@given(
    t=st.sampled_from([17, 24, 48, 64]),
    chunk=st.sampled_from([8, 16]),
    hkv=st.sampled_from([1, 2]),
    seed=st.integers(0, 10_000),
)
def test_attn_prefill_chunk_matches_whole_prefill(t, chunk, hkv, seed):
    """Chunked prefill must be a refactoring of whole prefill: the first
    chunk runs attn_prefill + cache_init, later chunks append through
    attn_prefill_chunk, and the concatenated outputs + final caches must
    equal one whole-prompt attn_prefill (+ cache_init). Lengths not
    divisible by the chunk size exercise the ragged tail."""
    d, dh = 64, 16
    h = hkv * 2
    max_ctx = 128
    rng = np.random.default_rng(seed)
    x = rnd(rng, 1, t, d)
    w = attn_weights(rng, d, h, hkv, dh)
    kw = dict(n_heads=h, n_kv_heads=hkv, head_dim=dh)

    y_want, k_want, v_want = ref.attn_prefill(x, *w, **kw)
    kc_want, vc_want = ref.cache_init(k_want, v_want, max_ctx)

    ys = []
    kc = vc = None
    pos = 0
    while pos < t:
        n = min(chunk, t - pos)
        xc = x[:, pos:pos + n]
        if pos == 0:
            y, k, v = ref.attn_prefill(xc, *w, **kw)
            kc, vc = ref.cache_init(k, v, max_ctx)
        else:
            y, kc, vc = ref.attn_prefill_chunk(
                xc, *w, kc, vc, jnp.int32(pos), **kw)
        ys.append(y)
        pos += n
    y_got = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_got, y_want, rtol=3e-5, atol=3e-5)
    # cache rows [0, t) must match; rows beyond t are never visible
    np.testing.assert_allclose(kc[:, :t], kc_want[:, :t], rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(vc[:, :t], vc_want[:, :t], rtol=3e-5, atol=3e-5)


def test_attn_prefill_chunk_is_attn_cached_at_chunk_width():
    """The chunk op is attn_cached at a prefill width — one name per
    family keeps artifact staleness detectable, not new math."""
    rng = np.random.default_rng(7)
    d, h, hkv, dh, max_ctx = 64, 4, 2, 16, 64
    x = rnd(rng, 1, 8, d)
    w = attn_weights(rng, d, h, hkv, dh)
    kw = dict(n_heads=h, n_kv_heads=hkv, head_dim=dh)
    kc = rnd(rng, 1, max_ctx, hkv, dh)
    vc = rnd(rng, 1, max_ctx, hkv, dh)
    pos = jnp.int32(16)
    got = ref.attn_prefill_chunk(x, *w, kc, vc, pos, **kw)
    want = ref.attn_cached(x, *w, kc, vc, pos, **kw)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(g, wv, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# linear block (the NBL substitution path)


@SET
@given(
    b=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([1, 4, 32, 64]),
    d=st.sampled_from([32, 128]),
    seed=st.integers(0, 10_000),
)
def test_linear_block_matches_ref(b, t, d, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, t, d)
    w = rnd(rng, d, d, scale=0.1)
    bias = rnd(rng, d)
    got = linear_block_pallas(x, w, bias, block_t=min(32, t))
    np.testing.assert_allclose(got, ref.linear_block(x, w, bias),
                               rtol=2e-5, atol=2e-5)


def test_linear_block_identity_weight_is_doubling():
    """x + xI + 0 == 2x — a closed-form sanity anchor."""
    x = jnp.arange(2 * 4 * 8, dtype=jnp.float32).reshape(2, 4, 8)
    got = linear_block_pallas(x, jnp.eye(8), jnp.zeros(8), block_t=4)
    np.testing.assert_allclose(got, 2 * x, rtol=1e-6)


# ---------------------------------------------------------------------------
# swiglu mlp


@SET
@given(
    b=st.sampled_from([1, 2]),
    t=st.sampled_from([4, 32, 64]),
    d=st.sampled_from([32, 128]),
    seed=st.integers(0, 10_000),
)
def test_mlp_block_matches_ref(b, t, d, seed):
    rng = np.random.default_rng(seed)
    f = 2 * d
    x = rnd(rng, b, t, d)
    nw = rnd(rng, d)
    w1, w3 = rnd(rng, d, f, scale=0.1), rnd(rng, d, f, scale=0.1)
    w2 = rnd(rng, f, d, scale=0.1)
    got = mlp_block_pallas(x, nw, w1, w3, w2, block_t=min(32, t))
    np.testing.assert_allclose(got, ref.mlp_block(x, nw, w1, w3, w2),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# gram accumulation (calibration)


@SET
@given(
    n=st.sampled_from([64, 256, 1024]),
    d=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 10_000),
)
def test_gram_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x, y = rnd(rng, n, d), rnd(rng, n, d)
    got = gram_pallas(x, y, block_n=min(64, n))
    want = ref.gram(x, y)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-3)


def test_gram_accumulation_equals_single_shot():
    """Chunked accumulation (what Rust streams) == one-shot gram."""
    rng = np.random.default_rng(3)
    x, y = rnd(rng, 512, 32), rnd(rng, 512, 32)
    whole = gram_pallas(x, y, block_n=64)
    parts = [gram_pallas(x[i : i + 128], y[i : i + 128], block_n=64)
             for i in range(0, 512, 128)]
    summed = [sum(p[j] for p in parts) for j in range(4)]
    for g, w in zip(summed, whole):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-3)
