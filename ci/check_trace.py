#!/usr/bin/env python3
"""Flight-recorder artifact gate: validate a Chrome-trace JSON export.

The serve_bench `--trace` arm writes the recorder's Perfetto/Chrome
trace (DESIGN.md §Observability). A malformed export fails OPEN in the
viewer — Perfetto silently drops unbalanced or mis-ordered events and
renders whatever is left, so a recorder regression would look like "the
server did less work", not like an error. This gate checks the
invariants the exporter guarantees by construction:

  1. the file is JSON with a non-empty `traceEvents` array;
  2. every event carries name/ph/ts/pid/tid, ph is B, E, or i, and ts
     is a non-negative number;
  3. ts is globally non-decreasing (the exporter sorts with a
     same-microsecond class tie-break);
  4. per (pid, tid) lane, B/E events form a valid LIFO stack with
     matching names, and every stack is empty at end-of-trace (the
     complete-span ring emits both edges of a span or neither);
  5. (--require) every named span family actually occurred — the mixed
     trace workload must exercise admission, chunked prefill, decode,
     speculation, and preemption, or a scheduler hook has regressed;
  6. (--worker-lanes N) the iteration-loop spans (cat == "worker")
     occupy exactly N distinct (pid, tid) lanes — an N-replica server
     exports one worker lane per replica, and a replica whose spans
     collapse onto tid 0 (the pre-ISSUE-10 bug) or leak onto a request
     lane fails here. Each lane is LIFO-balanced by check 4 already.

Run from the repo root:
  python ci/check_trace.py rust/reports/serve_trace.json \
      --require submit,queue,admit_warm,admit_chunked,prefill_chunk \
      --worker-lanes 1
"""

import argparse
import json
import numbers
import sys

PHASES = {"B", "E", "i"}
REQUIRED_FIELDS = ("name", "ph", "ts", "pid", "tid")


def check_events(events, require, worker_lanes=0):
    errors = []
    if not isinstance(events, list) or not events:
        return ["traceEvents is empty or not an array"]

    last_ts = None
    stacks = {}  # (pid, tid) -> [name, ...]
    seen = set()
    worker = set()  # distinct (pid, tid) lanes carrying cat == "worker"
    spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [f for f in REQUIRED_FIELDS if f not in ev]
        if missing:
            errors.append(f"event {i}: missing field(s) {missing}")
            continue
        name, ph, ts = ev["name"], ev["ph"], ev["ts"]
        if ph not in PHASES:
            errors.append(f"event {i} ({name}): bad ph {ph!r}")
            continue
        if not isinstance(ts, numbers.Real) or ts < 0:
            errors.append(f"event {i} ({name}): bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(
                f"event {i} ({name}): ts {ts} decreases from {last_ts} — "
                "the exporter's sort has regressed"
            )
        last_ts = ts

        lane = (ev["pid"], ev["tid"])
        if ev.get("cat") == "worker":
            worker.add(lane)
        stack = stacks.setdefault(lane, [])
        if ph == "B":
            stack.append(name)
            spans += 1
            seen.add(name)
        elif ph == "E":
            if not stack:
                errors.append(f"event {i} ({name}): E with no open span on lane {lane}")
            elif stack[-1] != name:
                errors.append(
                    f"event {i}: E({name}) closes B({stack[-1]}) on lane {lane} — "
                    "spans must nest"
                )
                stack.pop()
            else:
                stack.pop()
        else:  # instant
            seen.add(name)

    for lane, stack in sorted(stacks.items()):
        if stack:
            errors.append(f"lane {lane}: {len(stack)} unclosed span(s) {stack}")

    missing = sorted(set(require) - seen)
    if missing:
        errors.append(
            f"required span kind(s) never occurred: {missing} "
            f"(trace has {sorted(seen)})"
        )
    if worker_lanes and len(worker) != worker_lanes:
        errors.append(
            f"expected exactly {worker_lanes} worker lane(s), found "
            f"{len(worker)}: {sorted(worker)} — per-replica tids have "
            "regressed"
        )
    return errors, spans, seen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON file (serve_bench --trace output)")
    ap.add_argument(
        "--require",
        default="",
        help="comma-separated span/instant names that must appear at least once",
    )
    ap.add_argument(
        "--worker-lanes",
        type=int,
        default=0,
        help="require exactly N distinct worker (pid, tid) lanes; 0 = don't check",
    )
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"TRACE INVALID: cannot load {args.trace}: {e}")
        sys.exit(1)

    require = [r for r in args.require.split(",") if r]
    result = check_events(data.get("traceEvents"), require, args.worker_lanes)
    if isinstance(result, list):  # early-out error shape
        errors, spans, seen = result, 0, set()
    else:
        errors, spans, seen = result

    if errors:
        print(f"TRACE INVALID: {len(errors)} problem(s) in {args.trace}")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    n = len(data["traceEvents"])
    print(
        f"trace OK: {n} events, {spans} complete spans, "
        f"{len(seen)} distinct kinds ({', '.join(sorted(seen))})"
    )


if __name__ == "__main__":
    main()
