#!/usr/bin/env python3
"""Streaming-protocol artifact gate: replay a captured JSONL session.

The serve_bench `--stream-capture` arm records every line a streaming
client received (plus the `{"cancel": id}` frames it sent, at their
send positions) against a live server. A framing regression would not
crash that client — it tolerates whatever arrives — so this gate
replays the capture offline and enforces the invariants the front end
guarantees by construction (DESIGN.md §Streaming front end):

  1. every line is a JSON object: token/done/error frames carry a
     "frame" key; lines without one must be a client cancel frame or a
     legacy one-shot reply (mixed sessions are part of the protocol);
  2. per request id, token frame indices are dense and strictly
     increasing from 0 — no gaps, no reordering, no duplicates;
  3. per request id, EXACTLY one terminal frame (done or error), and no
     frame of any kind follows it;
  4. a done terminal's "tokens" array matches the token frames streamed
     before it one for one (the parity rung of the fallback ladder);
  5. a cancel frame is acknowledged: once `{"cancel": id}` appears, the
     stream for that id still ends in exactly one terminal frame, and
     that terminal is an error (the typed cancelled response).

Run from the repo root:
  python ci/check_stream.py rust/reports/stream_capture.jsonl --require-cancel
"""

import argparse
import json
import sys

TERMINALS = {"done", "error"}


def check_lines(lines, require_cancel):
    errors = []
    token_counts = {}  # id -> token frames seen so far
    terminals = {}  # id -> terminal frame kind
    cancelled = set()  # ids with a client cancel frame on record
    streams = set()

    for i, raw in enumerate(lines):
        where = f"line {i + 1}"
        try:
            j = json.loads(raw)
        except json.JSONDecodeError as e:
            errors.append(f"{where}: invalid JSON ({e})")
            continue
        if not isinstance(j, dict):
            errors.append(f"{where}: not a JSON object")
            continue

        if "frame" not in j:
            if "cancel" in j:
                rid = j["cancel"]
                if not isinstance(rid, int):
                    errors.append(f"{where}: cancel id must be an integer, got {rid!r}")
                    continue
                cancelled.add(rid)
            # else: a legacy one-shot reply interleaved in the session —
            # in protocol, nothing to check beyond being a JSON object
            continue

        frame, rid = j["frame"], j.get("id")
        if not isinstance(rid, int):
            errors.append(f"{where}: {frame} frame without an integer id")
            continue
        if rid in terminals:
            errors.append(
                f"{where}: {frame} frame for id {rid} AFTER its terminal "
                f"{terminals[rid]} frame — the terminal must be last"
            )
            continue
        streams.add(rid)

        if frame == "token":
            missing = [k for k in ("index", "token", "text") if k not in j]
            if missing:
                errors.append(f"{where}: token frame missing {missing}")
                continue
            expect = token_counts.get(rid, 0)
            if j["index"] != expect:
                errors.append(
                    f"{where}: id {rid} token index {j['index']} — expected "
                    f"{expect} (indices must be dense and strictly increasing)"
                )
            token_counts[rid] = token_counts.get(rid, 0) + 1
        elif frame in TERMINALS:
            terminals[rid] = frame
            if frame == "done":
                toks = j.get("tokens")
                if not isinstance(toks, list):
                    errors.append(f"{where}: done frame for id {rid} without a tokens array")
                elif len(toks) != token_counts.get(rid, 0):
                    errors.append(
                        f"{where}: id {rid} done frame carries {len(toks)} tokens "
                        f"but {token_counts.get(rid, 0)} were streamed — parity broken"
                    )
                if rid in cancelled:
                    errors.append(
                        f"{where}: id {rid} was cancelled but finished with a done "
                        "frame — cancellation must surface as the typed error"
                    )
        else:
            errors.append(f"{where}: unknown frame kind {frame!r}")

    for rid in sorted(streams - set(terminals)):
        errors.append(f"id {rid}: stream never reached a terminal frame")
    for rid in sorted(cancelled - streams):
        errors.append(f"id {rid}: cancel frame for a request that never streamed")
    if not streams:
        errors.append("capture contains no streamed requests at all")
    if require_cancel and not (cancelled & streams):
        errors.append("capture exercises no cancelled stream (--require-cancel)")
    return errors, streams, cancelled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("capture", help="JSONL capture (serve_bench --stream-capture output)")
    ap.add_argument(
        "--require-cancel",
        action="store_true",
        help="fail unless at least one streamed request was cancelled",
    )
    args = ap.parse_args()

    try:
        with open(args.capture) as f:
            lines = [ln for ln in (l.strip() for l in f) if ln]
    except OSError as e:
        print(f"STREAM INVALID: cannot read {args.capture}: {e}")
        sys.exit(1)
    if not lines:
        print(f"STREAM INVALID: {args.capture} is empty")
        sys.exit(1)

    errors, streams, cancelled = check_lines(lines, args.require_cancel)
    if errors:
        print(f"STREAM INVALID: {len(errors)} problem(s) in {args.capture}")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    print(
        f"stream OK: {len(lines)} lines, {len(streams)} streamed request(s), "
        f"{len(cancelled & streams)} cancelled"
    )


if __name__ == "__main__":
    main()
