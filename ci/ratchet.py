#!/usr/bin/env python3
"""Floor-ratchet proposer for the perf-smoke trajectory.

ci/collect_bench.py gates each run against committed floors in
ci/bench_baseline.json but never moves them; this tool closes the loop.
Point it at a directory of accumulated BENCH_<sha>.json artifacts
(downloaded from the workflow's bench-json uploads) and it proposes
tightened floors:

  - for every metric listed in the baseline, gather its value across
    all runs that report it;
  - with at least --min-runs observations, the proposed floor is
    min(observed) * SAFETY (0.9) — even the worst run of the window
    clears the new floor with 10% headroom, so runner noise alone
    cannot false-fail;
  - a proposal is only surfaced when it RAISES a positive baseline, or
    PROMOTES a record-only metric (baseline <= 0) that now has enough
    positive observations to gate on.

Advisory by default (prints a table, exits 0). Pass --write to apply
the proposals to ci/bench_baseline.json in place; min_ratio and the
schema/note fields are preserved, only baselines move.

The promotion rule itself lives in propose() and is unit-tested by
`python ci/ratchet.py --self-test` (run as a blocking CI step): in
particular, record-only higher-is-better SLO/goodput keys
(serve_bench_burst.slo_attainment, serve_bench_burst.goodput_tok_s)
must graduate to floors once observed, while *_ms latency keys must
never be promoted.

Usage:  python ci/ratchet.py [--bench-dir .] [--min-runs 3] [--write | --self-test]
"""

import argparse
import glob
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA = "nbl-bench/v1"
SAFETY = 0.9  # proposed floor = worst observed run * SAFETY


def load_runs(bench_dir):
    """Load every BENCH_*.json trajectory artifact under bench_dir."""
    runs = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        try:
            with open(path) as f:
                j = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(j, dict) or j.get("schema") != SCHEMA:
            continue
        runs.append((os.path.basename(path), j.get("benches", {})))
    return runs


def lookup(benches, dotted):
    bench, _, metric = dotted.partition(".")
    b = benches.get(bench)
    if b is None:
        return None
    return b.get("metrics", {}).get(metric)


def propose(dotted, base, obs, min_runs):
    """The promotion rule, isolated so --self-test can pin it down.

    Returns (new_floor, kind) — kind "raise" or "promote" — or None when
    the committed gate should stand:
      - fewer than min_runs observations: not enough trajectory;
      - base > 0: only a RAISE (proposed > base) is surfaced, floors
        never move down;
      - base <= 0 (record-only): PROMOTE to a floor once the window is
        all positive — except *_ms latency keys, which are
        lower-is-better and would fail CI on improvement under a
        `current >= floor` gate, so they stay record-only forever.
    """
    obs = [float(v) for v in obs if isinstance(v, (int, float))]
    if len(obs) < min_runs:
        return None
    proposed = min(obs) * SAFETY
    if base > 0.0 and proposed > base:
        return proposed, "raise"
    if base <= 0.0 and proposed > 0.0 and not dotted.endswith("_ms"):
        return proposed, "promote"
    return None


def self_test():
    """Unit-test the promotion rule; exits nonzero on the first failure."""
    cases = [
        # (name, dotted, base, obs, min_runs, expected)
        ("raise a positive floor", "b.tok_s", 10.0, [20.0, 18.0, 25.0], 3,
         (18.0 * SAFETY, "raise")),
        ("never lower a floor", "b.tok_s", 10.0, [9.0, 9.5, 9.2], 3, None),
        ("worst-of-window rules", "b.tok_s", 10.0, [100.0, 100.0, 10.0], 3, None),
        ("promote record-only throughput", "b.goodput_tok_s", 0.0,
         [50.0, 40.0, 60.0], 3, (40.0 * SAFETY, "promote")),
        ("promote record-only SLO ratio", "serve_bench_burst.slo_attainment", 0.0,
         [1.0, 0.9, 0.95], 3, (0.9 * SAFETY, "promote")),
        ("never promote latency keys", "b.p95_ttft_ms", 0.0,
         [5.0, 6.0, 7.0], 3, None),
        ("never promote a zero window", "b.goodput_tok_s", 0.0,
         [0.0, 0.0, 0.0], 3, None),
        ("respect min-runs", "b.tok_s", 10.0, [20.0, 21.0], 3, None),
        ("ignore non-numeric observations", "b.tok_s", 10.0,
         [20.0, None, "n/a", 18.0], 3, None),
        # ISSUE 10: the multi-replica scaling floor must ratchet upward
        # as real multi-core trajectory accumulates (the committed 1.0
        # baseline only asserts "no slower than one replica") ...
        ("raise the replica scaling floor",
         "serve_bench_replicas.replica_scaling_ratio", 1.0,
         [1.8, 1.6, 2.1], 3, (1.6 * SAFETY, "raise")),
        # ... and its record-only companion throughputs graduate to
        # floors like any other higher-is-better tok_s key
        ("promote record-only replica throughput",
         "serve_bench_replicas.tok_s_single", 0.0,
         [40.0, 35.0, 42.0], 3, (35.0 * SAFETY, "promote")),
    ]
    failures = 0
    for name, dotted, base, obs, min_runs, expected in cases:
        got = propose(dotted, base, obs, min_runs)
        ok = (got == expected) if expected is None else (
            got is not None
            and got[1] == expected[1]
            and abs(got[0] - expected[0]) < 1e-9
        )
        print(f"  {'ok' if ok else 'FAIL'}: {name} -> {got}")
        failures += 0 if ok else 1
    if failures:
        print(f"ratchet self-test: {failures} case(s) FAILED")
        raise SystemExit(1)
    print(f"ratchet self-test OK ({len(cases)} cases)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default=".", help="dir holding BENCH_<sha>.json files")
    ap.add_argument("--baseline", default=os.path.join(REPO, "ci", "bench_baseline.json"))
    ap.add_argument("--min-runs", type=int, default=3)
    ap.add_argument("--write", action="store_true", help="apply proposals to the baseline file")
    ap.add_argument("--self-test", action="store_true", help="unit-test the promotion rule and exit")
    args = ap.parse_args()

    if args.self_test:
        self_test()
        return

    runs = load_runs(args.bench_dir)
    print(f"{len(runs)} trajectory run(s) under {args.bench_dir}")
    with open(args.baseline) as f:
        baseline = json.load(f)
    metrics = baseline.get("metrics", {})

    proposals = []  # (dotted, old_base, new_base, n_obs, kind)
    for dotted, gate in sorted(metrics.items()):
        base = float(gate.get("baseline", 0.0))
        obs = [lookup(benches, dotted) for _, benches in runs]
        obs = [float(v) for v in obs if isinstance(v, (int, float))]
        result = propose(dotted, base, obs, args.min_runs)
        if result is not None:
            proposals.append((dotted, base, result[0], len(obs), result[1]))

    if not proposals:
        print(
            f"no ratchet proposals (need >= {args.min_runs} observations per "
            f"metric, and a tighter floor than the committed one)"
        )
        return
    print(f"{len(proposals)} proposal(s) (floor = worst-of-window * {SAFETY}):")
    for dotted, old, new, n, kind in proposals:
        print(f"  [{kind:7s}] {dotted}: {old:.2f} -> {new:.2f}  ({n} runs)")

    if args.write:
        for dotted, _, new, _, _ in proposals:
            metrics[dotted]["baseline"] = round(new, 3)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1)
            f.write("\n")
        print(f"wrote {args.baseline}")
    else:
        print("(advisory run: pass --write to apply)")


if __name__ == "__main__":
    main()
