#!/usr/bin/env python3
"""Floor-ratchet proposer for the perf-smoke trajectory.

ci/collect_bench.py gates each run against committed floors in
ci/bench_baseline.json but never moves them; this tool closes the loop.
Point it at a directory of accumulated BENCH_<sha>.json artifacts
(downloaded from the workflow's bench-json uploads) and it proposes
tightened floors:

  - for every metric listed in the baseline, gather its value across
    all runs that report it;
  - with at least --min-runs observations, the proposed floor is
    min(observed) * SAFETY (0.9) — even the worst run of the window
    clears the new floor with 10% headroom, so runner noise alone
    cannot false-fail;
  - a proposal is only surfaced when it RAISES a positive baseline, or
    PROMOTES a record-only metric (baseline <= 0) that now has enough
    positive observations to gate on.

Advisory by default (prints a table, exits 0). Pass --write to apply
the proposals to ci/bench_baseline.json in place; min_ratio and the
schema/note fields are preserved, only baselines move.

Usage:  python ci/ratchet.py [--bench-dir .] [--min-runs 3] [--write]
"""

import argparse
import glob
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA = "nbl-bench/v1"
SAFETY = 0.9  # proposed floor = worst observed run * SAFETY


def load_runs(bench_dir):
    """Load every BENCH_*.json trajectory artifact under bench_dir."""
    runs = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        try:
            with open(path) as f:
                j = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(j, dict) or j.get("schema") != SCHEMA:
            continue
        runs.append((os.path.basename(path), j.get("benches", {})))
    return runs


def lookup(benches, dotted):
    bench, _, metric = dotted.partition(".")
    b = benches.get(bench)
    if b is None:
        return None
    return b.get("metrics", {}).get(metric)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default=".", help="dir holding BENCH_<sha>.json files")
    ap.add_argument("--baseline", default=os.path.join(REPO, "ci", "bench_baseline.json"))
    ap.add_argument("--min-runs", type=int, default=3)
    ap.add_argument("--write", action="store_true", help="apply proposals to the baseline file")
    args = ap.parse_args()

    runs = load_runs(args.bench_dir)
    print(f"{len(runs)} trajectory run(s) under {args.bench_dir}")
    with open(args.baseline) as f:
        baseline = json.load(f)
    metrics = baseline.get("metrics", {})

    proposals = []  # (dotted, old_base, new_base, n_obs, kind)
    for dotted, gate in sorted(metrics.items()):
        base = float(gate.get("baseline", 0.0))
        obs = []
        for _, benches in runs:
            v = lookup(benches, dotted)
            if isinstance(v, (int, float)):
                obs.append(float(v))
        if len(obs) < args.min_runs:
            continue
        proposed = min(obs) * SAFETY
        if base > 0.0 and proposed > base:
            proposals.append((dotted, base, proposed, len(obs), "raise"))
        elif base <= 0.0 and proposed > 0.0 and not dotted.endswith("_ms"):
            # latency percentiles (*_ms) are lower-is-better: a floor gate
            # (current >= floor) would fail CI on improvement, so they stay
            # record-only trajectory keys forever
            proposals.append((dotted, base, proposed, len(obs), "promote"))

    if not proposals:
        print(
            f"no ratchet proposals (need >= {args.min_runs} observations per "
            f"metric, and a tighter floor than the committed one)"
        )
        return
    print(f"{len(proposals)} proposal(s) (floor = worst-of-window * {SAFETY}):")
    for dotted, old, new, n, kind in proposals:
        print(f"  [{kind:7s}] {dotted}: {old:.2f} -> {new:.2f}  ({n} runs)")

    if args.write:
        for dotted, _, new, _, _ in proposals:
            metrics[dotted]["baseline"] = round(new, 3)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=1)
            f.write("\n")
        print(f"wrote {args.baseline}")
    else:
        print("(advisory run: pass --write to apply)")


if __name__ == "__main__":
    main()
