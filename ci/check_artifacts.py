#!/usr/bin/env python3
"""Artifact-staleness gate: fail CI loudly when the AOT grid no longer
covers an op name the Rust engine can request at runtime.

The Rust executor degrades gracefully when an op is missing — per-row
scalar decode instead of `attn_cached_rows_b{B}_s{W}`, whole-prompt
prefill instead of `attn_prefill_chunk_b{B}_t{T}` — which is right for
a serving box with old artifacts but WRONG for CI: a silently slower
fallback would pass every correctness test while the perf trajectory
quietly decays. This script cross-references the op names the engine
formats (engine.rs bucket math, mirrored here) against:

  1. the grid axes in python/compile/configs.py (always), and
  2. artifacts/manifest.json + the HLO files on disk (when present —
     pass --manifest-required to fail if artifacts were never built).

Run from the repo root:  python ci/check_artifacts.py [--manifest-required]
"""

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "python"))

from compile.configs import GRID  # noqa: E402


def required_ops():
    """Every op name the Rust engine's bucket selection can format.

    Mirrors rust/src/executor/engine.rs: prefill (attn_prefill +
    cache_init + the chunked-prefill family), decode (attn_cached and
    the per-row attn_cached_rows family at every verify width), the
    pointwise ops at both grids' widths, and the calibration gram pair.
    """
    ops = set()
    for b in GRID.batches:
        for t in GRID.prefill_lens:
            ops.add(f"attn_prefill_b{b}_t{t}")
            ops.add(f"cache_init_b{b}_t{t}")
            ops.add(f"attn_prefill_chunk_b{b}_t{t}")
        for s in GRID.cached_lens:
            ops.add(f"attn_cached_b{b}_s{s}")
            ops.add(f"attn_cached_rows_b{b}_s{s}")
        for t in GRID.pointwise_lens:
            ops.add(f"linear_block_b{b}_t{t}")
            ops.add(f"mlp_b{b}_t{t}")
            ops.add(f"head_b{b}_t{t}")
    ops.add(f"gram_n{GRID.gram_n}_d{GRID.gram_d}")
    ops.add(f"gram_jnp_n{GRID.gram_n}_d{GRID.gram_d}")
    return ops


def check_grid():
    """Grid-axis invariants the engine's fast paths depend on."""
    errors = []
    if not set(GRID.cached_lens) <= set(GRID.pointwise_lens):
        errors.append(
            "cached_lens not a subset of pointwise_lens: decode_rows_batched "
            "needs mlp/linear_block/head at every verify width"
        )
    if not set(GRID.prefill_lens) <= set(GRID.pointwise_lens):
        errors.append(
            "prefill_lens not a subset of pointwise_lens: prefill_chunk "
            "needs mlp/linear_block/head at every chunk width"
        )
    return errors


def check_manifest(required):
    """Cross-reference manifest.json + HLO files against `required`."""
    manifest_path = os.path.join(REPO, "artifacts", "manifest.json")
    if not os.path.exists(manifest_path):
        return None  # caller decides whether that is fatal
    with open(manifest_path) as f:
        manifest = json.load(f)
    hlo = manifest.get("hlo", {})
    errors = []
    missing = sorted(required - set(hlo))
    if missing:
        errors.append(
            f"{len(missing)} required op(s) missing from manifest.json "
            f"(stale artifacts — run `python -m compile.aot`): {missing}"
        )
    for op in sorted(required & set(hlo)):
        path = os.path.join(REPO, "artifacts", hlo[op])
        if not os.path.exists(path):
            errors.append(f"manifest lists {op} but {path} does not exist")
    # the manifest's recorded grid must match the committed configs, or
    # Rust bucket selection and the artifact set disagree
    mgrid = manifest.get("grid", {})
    for axis in ("batches", "prefill_lens", "cached_lens", "pointwise_lens"):
        want = list(getattr(GRID, axis))
        got = mgrid.get(axis)
        if got != want:
            errors.append(f"manifest grid.{axis} = {got}, configs say {want}")
    return errors


def rust_stats_keys():
    """The /stats endpoint's JSON keys, parsed straight from
    rust/src/server/api.rs `stats_to_json`.

    This is a deliberately independent second parser: nbl-lint extracts
    the same key set with its own Rust scanner (`--dump-gauges`), and CI
    diffs the two. If either scanner rots against the source (a
    refactor moves the function, the key literal style changes), the
    sets diverge and the gauge gate fails loudly instead of silently
    checking nothing.
    """
    path = os.path.join(REPO, "rust", "src", "server", "api.rs")
    keys, depth, body_started, in_fn = [], 0, False, False
    with open(path) as f:
        for line in f:
            if not in_fn:
                if re.search(r"\bfn\s+stats_to_json\b", line):
                    in_fn = True
                else:
                    continue
            keys += re.findall(r'\(\s*"([A-Za-z0-9_.]+)"\s*,', line)
            depth += line.count("{") - line.count("}")
            if "{" in line:
                body_started = True
            if body_started and depth <= 0:
                break
    return sorted(set(keys))


# The observability keys the stats endpoint contracts to expose
# (mirrored by REQUIRED_OBSERVABILITY_KEYS in
# rust/nbl-lint/src/gauges.rs — keep in sync): TTFT attribution
# percentiles, flight-recorder ring counters, timing-retention
# counters, and per-iteration phase gauges.
REQUIRED_OBSERVABILITY_KEYS = frozenset(
    [f"{agg}_{phase}_ms" for agg in ("mean", "p50", "p95", "p99")
     for phase in ("queue", "prefill", "stall", "park")]
    + ["timings_retained", "timings_dropped", "timings_capacity"]
    + ["trace_events", "trace_dropped", "trace_capacity"]
    + [f"phase_{p}_ms" for p in ("intake", "admission", "chunked", "observe", "decode")]
    # streaming front end (DESIGN.md §Streaming front end): request
    # teardown counters, fair-queue occupancy, and deadline SLOs
    + ["cancelled", "expired", "shed", "tenants_active"]
    + ["goodput_tok_s", "slo_attainment"]
)


def check_gauges(dump_path):
    """Diff nbl-lint's gauge dump against this script's own parse."""
    with open(dump_path) as f:
        dump = json.load(f)
    if dump.get("schema") != "nbl-gauges/v1":
        return [f"unexpected gauge dump schema: {dump.get('schema')!r}"]
    lint_keys = sorted(set(dump.get("stats_keys", [])))
    py_keys = rust_stats_keys()
    errors = []
    if not lint_keys:
        errors.append("nbl-lint gauge dump lists no stats keys")
    if not py_keys:
        errors.append("python parse of stats_to_json found no keys")
    if lint_keys != py_keys:
        only_lint = sorted(set(lint_keys) - set(py_keys))
        only_py = sorted(set(py_keys) - set(lint_keys))
        errors.append(
            "gauge scanners disagree on stats_to_json keys "
            f"(nbl-lint only: {only_lint}; python only: {only_py}) — "
            "one of the two parsers has rotted against api.rs"
        )
    missing_obs = sorted(REQUIRED_OBSERVABILITY_KEYS - set(py_keys))
    if missing_obs:
        errors.append(
            "stats_to_json dropped required observability key(s) "
            f"{missing_obs} (TTFT attribution / trace / retention / phase "
            "surface, DESIGN.md §Observability)"
        )
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--manifest-required",
        action="store_true",
        help="fail if artifacts/manifest.json has not been built",
    )
    ap.add_argument(
        "--gauges",
        metavar="DUMP_JSON",
        help="cross-check an `nbl-lint --dump-gauges` capture against an "
        "independent parse of stats_to_json",
    )
    args = ap.parse_args()

    required = required_ops()
    errors = check_grid()
    manifest_errors = check_manifest(required)
    if manifest_errors is None:
        msg = "artifacts/manifest.json not found — manifest check skipped"
        if args.manifest_required:
            errors.append(msg + " (--manifest-required)")
        else:
            print(f"note: {msg}")
    else:
        errors.extend(manifest_errors)
    if args.gauges:
        errors.extend(check_gauges(args.gauges))

    if errors:
        print(f"ARTIFACT STALENESS: {len(errors)} problem(s)")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    print(f"artifact grid OK: {len(required)} engine-requestable ops covered")


if __name__ == "__main__":
    main()
