#!/usr/bin/env python3
"""Perf-trajectory collector for CI's perf-smoke job.

Merges the per-bench JSON files the benches emit (schema nbl-bench/v1:
reports/serve_bench_<mode>.json from examples/serve_bench.rs and
reports/bench_kv.json from benches/bench_kv.rs) into one
BENCH_<sha>.json uploaded as a workflow artifact, then gates on the
committed baseline (ci/bench_baseline.json): any metric listed there
with a positive baseline must stay above min_ratio * baseline — the
">20% throughput regression fails CI" ratchet.

The baseline is a floor, not a record: raise it as the trajectory of
uploaded BENCH_*.json artifacts accumulates (runner-to-runner noise
means floors should sit well under the typical run).

Usage:  python ci/collect_bench.py --sha <sha> \
            [--reports-dir rust/reports] [--out BENCH_<sha>.json]
"""

import argparse
import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA = "nbl-bench/v1"


def load_reports(reports_dir):
    benches = {}
    for path in sorted(glob.glob(os.path.join(reports_dir, "*.json"))):
        try:
            with open(path) as f:
                j = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if not isinstance(j, dict) or j.get("schema") != SCHEMA:
            continue
        name = os.path.splitext(os.path.basename(path))[0]
        benches[name] = j
    return benches


def lookup(benches, dotted):
    """Resolve "bench_name.metric" into the merged bench dict."""
    bench, _, metric = dotted.partition(".")
    b = benches.get(bench)
    if b is None:
        return None
    return b.get("metrics", {}).get(metric)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sha", default="local")
    ap.add_argument("--reports-dir", default=os.path.join(REPO, "rust", "reports"))
    ap.add_argument("--baseline", default=os.path.join(REPO, "ci", "bench_baseline.json"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    benches = load_reports(args.reports_dir)
    if not benches:
        print(f"no {SCHEMA} reports found under {args.reports_dir}")
        sys.exit(1)

    out_path = args.out or f"BENCH_{args.sha}.json"
    merged = {"schema": SCHEMA, "sha": args.sha, "benches": benches}
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
    print(f"wrote {out_path} ({len(benches)} bench(es): {sorted(benches)})")

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = []
    for dotted, gate in sorted(baseline.get("metrics", {}).items()):
        base = float(gate.get("baseline", 0.0))
        min_ratio = float(gate.get("min_ratio", 0.8))
        current = lookup(benches, dotted)
        if base <= 0.0:
            continue  # record-only metric, not yet ratcheted
        if current is None:
            failures.append(f"{dotted}: baseline {base} but metric missing from reports")
            continue
        floor = base * min_ratio
        status = "OK" if current >= floor else "REGRESSION"
        print(f"  {dotted}: {current:.2f} vs floor {floor:.2f} (baseline {base}) {status}")
        if current < floor:
            failures.append(
                f"{dotted}: {current:.2f} < {floor:.2f} "
                f"({min_ratio:.0%} of baseline {base})"
            )
    if failures:
        print(f"PERF REGRESSION: {len(failures)} metric(s) under the committed floor")
        for f_ in failures:
            print(f"  - {f_}")
        sys.exit(1)
    print("perf gate OK")


if __name__ == "__main__":
    main()
