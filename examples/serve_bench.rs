//! END-TO-END serving driver (the brief's required E2E example): bring up
//! the full stack — engine + scheduler + worker + TCP front-end — under an
//! NBL-compressed model, fire a MIXED-PROMPT-LENGTH workload of real
//! requests over TCP, and report latency/throughput. Results are recorded
//! in EXPERIMENTS.md.
//!
//! The workload interleaves four prompt lengths, the worst case for the
//! old exact-length grouping (batches degenerate towards size 1) and the
//! case continuous batching exists for. `--mode grouped` runs the legacy
//! baseline for comparison; `--mode spec` runs continuous batching with
//! self-speculative draft-and-verify iterations (the draft is the SAME
//! weights under an NBL-heavier plan — paper §5 composition, served).
//!
//!     cargo run --release --example serve_bench \
//!         [-- --m 2 --requests 24 --max-tokens 48 \
//!              --mode spec --spec-width 4 --draft-m 4]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::nbl::criteria::Criterion;
use nbl::server::service::{BatchMode, Server, ServerConfig, SpecConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::util::cli::Args;
use nbl::util::timer::Timer;
use nbl::util::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let m = args.get_usize("m", 2)?;
    let n_requests = args.get_usize("requests", 24)?;
    let max_tokens = args.get_usize("max-tokens", 48)?;
    let spec_width = args.get_usize("spec-width", 4)?;
    let (mode, spec_on) = match args.get_or("mode", "continuous") {
        "grouped" => (BatchMode::ExactLength, false),
        "spec" => (BatchMode::Continuous, true),
        _ => (BatchMode::Continuous, false),
    };
    let cfg = ExpConfig::from_env();

    // --- build the NBL-compressed engine
    let wb = Workbench::new("main", cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_layers = wb.engine.config().n_layers;
    let plan = if m == 0 {
        nbl::nbl::plan::ModelPlan::baseline(n_layers)
    } else {
        wb.report
            .plan_attn_nbl(m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    println!("serving plan: {} [{}]", plan.kind.label(), plan.describe());
    let engine = Arc::new(wb.engine.with_plan(plan).map_err(|e| anyhow::anyhow!("{e}"))?);

    // --- self-speculation: the draft is an NBL-heavier plan over the
    // same Arc-shared weights (no second checkpoint)
    let spec = if spec_on {
        let draft_m = args.get_usize("draft-m", (m + 2).min(n_layers - 1))?;
        let draft_plan = wb
            .report
            .plan_attn_nbl(draft_m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "draft plan:   {} [{}], verify width {spec_width}",
            draft_plan.kind.label(),
            draft_plan.describe()
        );
        Some(SpecConfig { draft_plan, width: spec_width })
    } else {
        None
    };

    // --- full stack: server worker + TCP front-end
    let server_cfg = ServerConfig { mode, spec, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, server_cfg));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, "127.0.0.1:0").map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("listening on {} (mode: {mode:?})", front.addr);

    // --- client load: 4 concurrent connections, MIXED-length prompts
    // from the corpus (16/32/48/64 bytes interleaved)
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let len = 16 + (i % 4) * 16;
            let start = (i * 997) % (wb.calib.tokens.len() - 128);
            let bytes: Vec<u8> = wb.calib.tokens[start..start + len]
                .iter()
                .map(|&t| t as u8)
                .collect();
            String::from_utf8_lossy(&bytes).replace(['"', '\\', '\n'], " ")
        })
        .collect();

    let t_all = Timer::start();
    let mut client_threads = Vec::new();
    for (c, chunk) in prompts.chunks(n_requests.div_ceil(4)).enumerate() {
        let chunk: Vec<String> = chunk.to_vec();
        let addr = front.addr;
        client_threads.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut latencies = Vec::new();
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            for (i, p) in chunk.iter().enumerate() {
                let id = c * 1000 + i;
                let t = Timer::start();
                writeln!(
                    writer,
                    r#"{{"id": {id}, "prompt": "{p}", "max_tokens": {max_tokens}}}"#
                )?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                latencies.push(t.elapsed_s());
                let j = nbl::util::json::Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
                if j.opt("error").is_some() {
                    anyhow::bail!("server error: {line}");
                }
            }
            Ok(latencies)
        }));
    }
    let mut latencies = Vec::new();
    for t in client_threads {
        latencies.extend(t.join().unwrap()?);
    }
    let wall = t_all.elapsed_s();
    front.shutdown();

    // --- report
    let s = metrics.summary();
    let g = metrics.gauges();
    println!("\n=== serve_bench results (Attn NBL-{m}, {mode:?}, mixed lengths) ===");
    println!("requests                 {}", s.requests);
    println!("generated tokens         {}", s.generated_tokens);
    println!("wall time                {wall:.2} s");
    println!("request throughput       {:.2} req/s", s.requests as f64 / wall);
    println!("token throughput         {:.1} tok/s", s.generated_tokens as f64 / wall);
    println!("mean TTFT                {:.1} ms", s.mean_ttft_s * 1e3);
    println!("p90 TTFT                 {:.1} ms", s.p90_ttft_s * 1e3);
    println!("prefill speed            {:.0} tok/s", s.mean_prefill_tok_s);
    println!("median decode speed      {:.0} tok/s", s.median_decode_tok_s);
    println!("mean e2e latency         {:.1} ms", mean(&latencies) * 1e3);
    println!("p90 e2e latency          {:.1} ms", percentile(&latencies, 90.0) * 1e3);
    if mode == BatchMode::Continuous {
        println!("decode iterations        {}", g.iterations);
        println!("mean rows/iteration      {:.2}", g.mean_rows_per_iteration());
        println!("batch occupancy          {:.1}%", g.mean_occupancy() * 100.0);
        println!("slot reuses              {}", g.slot_reuses);
    }
    if spec_on {
        println!("spec rounds              {}", g.spec_rounds);
        println!("acceptance rate          {:.1}%", g.acceptance_rate() * 100.0);
        println!(
            "tokens/target-iteration  {:.2} per row",
            g.tokens_per_row_iteration()
        );
        if args.get("draft-m").is_none() {
            // the default self-speculative draft must pay for itself on
            // the synthetic workload; a user-supplied draft plan is
            // exploratory, so its numbers are reported, not asserted
            assert!(
                g.tokens_per_row_iteration() > 1.0,
                "speculation must commit > 1 token per row per target pass, \
                 got {:.2}",
                g.tokens_per_row_iteration()
            );
        } else if g.tokens_per_row_iteration() <= 1.0 {
            println!("WARNING: this draft plan never beat plain decoding");
        }
    }
    assert_eq!(s.requests, n_requests, "all requests must be served");
    println!("\nserve_bench OK");
    Ok(())
}
