//! END-TO-END serving driver (the brief's required E2E example): bring up
//! the full stack — engine + scheduler + worker + TCP front-end — under an
//! NBL-compressed model, fire a MIXED-PROMPT-LENGTH workload of real
//! requests over TCP, and report latency/throughput. Results are recorded
//! in EXPERIMENTS.md and, for CI's perf-smoke job, emitted as bench JSON
//! (reports/serve_bench_<mode>.json, schema nbl-bench/v1 — see
//! ci/collect_bench.py).
//!
//! The workload interleaves four short prompt lengths and (every
//! `--long-every`-th request) one max-context 512-token prompt — the
//! head-of-line case chunked prefill exists for: without chunking, every
//! in-flight decode and every queued short stalls behind the whole long
//! prefill. `--mode grouped` runs the legacy exact-length baseline;
//! `--mode spec` runs continuous batching with self-speculative
//! draft-and-verify iterations (the draft is the SAME weights under an
//! NBL-heavier plan — paper §5 composition, served). `--ttft-compare`
//! re-runs the continuous workload with chunking disabled and asserts
//! the p50 TTFT of short requests dropped (the ISSUE 4 acceptance
//! criterion, machine-checked).
//!
//! `--prefix-share` runs the ISSUE 5 shared-prefix arm instead: every
//! request repeats one long system-prompt prefix with a distinct short
//! suffix, served cold (prefix cache off) and warm (cache on, primed by
//! one request). Emits hit rate and warm-vs-cold p50 TTFT, and asserts
//! warm strictly beats cold — the multiplicative win prefix reuse adds
//! on top of batching/speculation/chunking.
//!
//! `--paged-compare` runs the ISSUE 6 block-pool arm: the same
//! shared-prefix workload served twice under an IDENTICAL two-slot KV
//! byte budget — contiguous slot-granular admission vs the paged block
//! pool (`--block-tokens`, default 64). Asserts the paged run holds
//! strictly more concurrent rows (peak_rows) AND that its warm prefix
//! adoptions are zero-copy block splices (the per-layer snapshot
//! expansion counter stays 0 while the splice counter advances). Emits
//! the concurrency ratio that ci/bench_baseline.json floors.
//!
//! `--trace <path>` runs the ISSUE 8 flight-recorder arm instead: one
//! mixed workload with speculation, chunked prefill, warm prefix
//! admissions, AND a paged budget tight enough to preempt — every span
//! family in a single Chrome-trace JSON (open it in Perfetto or
//! chrome://tracing), written to `path` and validated by
//! ci/check_trace.py. `--trace-events` sizes the ring (default 65536).
//!
//! `--burst` runs the ISSUE 9 fairness arm: a bulk tenant dumps its
//! whole batch at t=0 while an interactive tenant's short requests
//! arrive on a deterministic pseudo-Poisson trickle, served twice on
//! two decode slots — FIFO vs weighted-fair (live tenant at 4x DRR
//! weight). Asserts weighted-fair strictly cuts the interactive p95
//! TTFT, and emits the SLO attainment (% live requests with TTFT <=
//! `--slo-ms`) plus goodput that ci/bench_baseline.json floors.
//!
//! `--stream-capture <path>` runs the ISSUE 9 streaming arm: live
//! streamed sessions (one-shot parity replay + a mid-decode cancel) in
//! both plain and self-speculative modes, every received JSONL line
//! captured verbatim to `path` for ci/check_stream.py's frame-order
//! replay.
//!
//! `--replicas N` serves the workload through N data-parallel engine
//! replicas behind the prefix-affinity dispatcher (ISSUE 10); plain
//! continuous/spec loads and `--prefix-share` both honour it.
//! `--replicas-compare` runs the ISSUE 10 scaling arm instead: the
//! SAME decode-dominated workload served at 1 replica and at N
//! (default: available parallelism), under ONE shared KV byte ceiling,
//! asserting token-count parity and emitting `replica_scaling_ratio`
//! (multi over single decode throughput) for ci/bench_baseline.json.
//!
//! `--arrivals poisson --seed S` paces the measured load on a
//! deterministic seedable pseudo-Poisson schedule (`--mean-gap-ms`,
//! default 30) instead of firing every request as fast as its
//! connection allows — bursty like real traffic, bit-identical for any
//! given seed.
//!
//!     cargo run --release --example serve_bench \
//!         [-- --m 2 --requests 24 --max-tokens 48 \
//!              --mode spec --spec-width 4 --draft-m 4 \
//!              --chunk 128 --long-every 6 \
//!              --ttft-compare | --prefix-share | --paged-compare]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::server::metrics::{MetricsSummary, RequestTiming, SchedulerGauges};
use nbl::server::service::{BatchMode, Server, ServerConfig, SpecConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::util::cli::Args;
use nbl::util::json::Json;
use nbl::util::timer::Timer;
use nbl::util::{mean, percentile};

/// Prompts below this many tokens count as "short" when slicing TTFT —
/// the workload's short lengths are 16..64, the long prompt is 512.
const SHORT_PROMPT_MAX: usize = 100;

struct LoadResult {
    wall_s: f64,
    latencies: Vec<f64>,
    /// Server-reported per-request TTFT (ms), measured load only —
    /// priming requests are excluded by construction.
    ttfts_ms: Vec<f64>,
    summary: MetricsSummary,
    gauges: SchedulerGauges,
    timings: Vec<RequestTiming>,
    /// Chrome-trace JSON fetched over TCP (`{"trace": true}`) before
    /// shutdown — `Some` only when the load ran with `fetch_trace`.
    trace_json: Option<String>,
}

impl LoadResult {
    /// p50 TTFT (ms) over the short requests — the number a long prompt
    /// at the head of the line inflates, and chunked prefill lowers.
    fn p50_short_ttft_ms(&self) -> f64 {
        let shorts: Vec<f64> = self
            .timings
            .iter()
            .filter(|t| t.prompt_tokens < SHORT_PROMPT_MAX)
            .map(|t| t.ttft_s * 1e3)
            .collect();
        percentile(&shorts, 50.0)
    }
}

/// Serve `prompts` through a fresh server + TCP front-end: 4 concurrent
/// client connections, requests round-robin-chunked across them.
/// `prime` prompts are served FIRST on a dedicated connection (the
/// prefix-share arm warms the prompt cache with them) and excluded
/// from the measured load's latency/TTFT vectors. A non-empty
/// `arrivals_ms` paces the measured load: request `i` (in global
/// workload order) is not submitted before `arrivals_ms[i]`
/// milliseconds after the load clock starts.
fn run_load(
    engine: &Arc<Engine>,
    cfg: ServerConfig,
    prime: &[String],
    prompts: &[String],
    max_tokens: usize,
    fetch_trace: bool,
    arrivals_ms: &[f64],
) -> anyhow::Result<LoadResult> {
    let server = Arc::new(Server::new(engine.clone(), cfg));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, "127.0.0.1:0").map_err(|e| anyhow::anyhow!("{e}"))?;

    if !prime.is_empty() {
        let stream = TcpStream::connect(front.addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        for (i, p) in prime.iter().enumerate() {
            let id = 900_000 + i;
            // two tokens, not one: a request that finishes on its
            // prefill token never enters the decode group, and in spec
            // mode would publish no snapshots
            writeln!(writer, r#"{{"id": {id}, "prompt": "{p}", "max_tokens": 2}}"#)?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
            if j.opt("error").is_some() {
                anyhow::bail!("priming error: {line}");
            }
        }
    }

    type ConnResult = anyhow::Result<(Vec<f64>, Vec<f64>)>;
    let t_all = Timer::start();
    let load_start = std::time::Instant::now();
    let mut client_threads = Vec::new();
    let per_conn = prompts.len().div_ceil(4).max(1);
    for (c, chunk) in prompts.chunks(per_conn).enumerate() {
        let chunk: Vec<String> = chunk.to_vec();
        let addr = front.addr;
        // this connection serves global requests [base, base+len): its
        // slice of the (sorted) arrival schedule paces it independently
        let base = c * per_conn;
        let sched: Vec<f64> = if arrivals_ms.is_empty() {
            Vec::new()
        } else {
            arrivals_ms[base..(base + chunk.len()).min(arrivals_ms.len())].to_vec()
        };
        client_threads.push(std::thread::spawn(move || -> ConnResult {
            let mut latencies = Vec::new();
            let mut ttfts = Vec::new();
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            for (i, p) in chunk.iter().enumerate() {
                if let Some(&at_ms) = sched.get(i) {
                    let elapsed_ms = load_start.elapsed().as_secs_f64() * 1e3;
                    if at_ms > elapsed_ms {
                        std::thread::sleep(std::time::Duration::from_micros(
                            ((at_ms - elapsed_ms) * 1e3) as u64,
                        ));
                    }
                }
                let id = c * 1000 + i;
                let t = Timer::start();
                writeln!(
                    writer,
                    r#"{{"id": {id}, "prompt": "{p}", "max_tokens": {max_tokens}}}"#
                )?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                latencies.push(t.elapsed_s());
                let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
                if j.opt("error").is_some() {
                    anyhow::bail!("server error: {line}");
                }
                let ttft = j
                    .get("ttft_ms")
                    .and_then(|v| v.as_f64())
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                ttfts.push(ttft);
            }
            Ok((latencies, ttfts))
        }));
    }
    let mut latencies = Vec::new();
    let mut ttfts_ms = Vec::new();
    for t in client_threads {
        let (lat, ttft) = t.join().unwrap()?;
        latencies.extend(lat);
        ttfts_ms.extend(ttft);
    }
    let wall_s = t_all.elapsed_s();
    // the flight recorder lives in the server the front-end owns, so the
    // export must happen while the front-end is still up
    let trace_json = if fetch_trace {
        let stream = TcpStream::connect(front.addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        writeln!(writer, r#"{{"trace": true}}"#)?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Some(line.trim().to_string())
    } else {
        None
    };
    front.shutdown();
    Ok(LoadResult {
        wall_s,
        latencies,
        ttfts_ms,
        summary: metrics.summary(),
        gauges: metrics.gauges(),
        timings: metrics.timings(),
        trace_json,
    })
}

/// JSON-safe one-byte-per-token text sliced out of the calibration
/// corpus: the byte tokenizer must see EXACTLY `len` tokens (a
/// multi-byte replacement char would push a prompt past its grid
/// bucket).
fn corpus_text(tokens: &[u32], start: usize, len: usize) -> String {
    tokens[start..start + len]
        .iter()
        .map(|&t| {
            let b = t as u8;
            if b.is_ascii_alphanumeric() || b == b' ' {
                b as char
            } else {
                ' '
            }
        })
        .collect()
}

/// The ISSUE 6 paged-vs-contiguous arm: one shared-prefix short-suffix
/// workload served twice under an IDENTICAL KV byte budget sized at
/// exactly TWO worst-case contiguous slots. Contiguous admission can
/// never hold more than two rows; block-granular admission charges each
/// row only the blocks its context actually spans (and its shared
/// prefix blocks charge NOTHING), so the paged run must reach a
/// strictly higher peak concurrency. The warm adoptions must also be
/// zero-copy: the per-layer snapshot expansion counter stays 0 while
/// the block-splice counter advances — counter-verified, not inferred.
fn run_paged_compare(
    engine: &Arc<Engine>,
    wb: &Workbench,
    n_requests: usize,
    max_tokens: usize,
    block_tokens: usize,
    m: usize,
) -> anyhow::Result<()> {
    let max_ctx = engine.config().max_ctx;
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    let budget = 2 * per_slot;
    // the shared prefix sits on the default whole-prompt snap boundary
    // (128), leaving room for the suffix + decode inside max_ctx
    let share = 128.min(max_ctx.saturating_sub(64));
    let suffix_len = 16usize;
    let shared = corpus_text(&wb.calib.tokens, 0, share);
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let start = (share + 1 + i * 131) % (wb.calib.tokens.len() - suffix_len - 1);
            format!("{shared}{}", corpus_text(&wb.calib.tokens, start, suffix_len))
        })
        .collect();
    println!(
        "paged-compare workload: {} requests, {share}-token shared prefix + \
         {suffix_len}-token suffixes, {block_tokens}-token blocks, \
         budget = 2 contiguous slots ({budget} bytes)"
    );

    let contiguous_cfg = ServerConfig {
        kv_capacity_bytes: budget,
        prefill_chunk: 0,
        ..ServerConfig::default()
    };
    let paged_cfg = ServerConfig {
        kv_capacity_bytes: budget,
        kv_block_tokens: block_tokens,
        prefill_chunk: 0,
        prefix_cache_bytes: 64 << 20,
        ..ServerConfig::default()
    };
    let cont = run_load(engine, contiguous_cfg, &[], &prompts, max_tokens, false, &[])?;
    let prime = vec![prompts[0].clone()];
    let paged = run_load(engine, paged_cfg, &prime, &prompts, max_tokens, false, &[])?;

    let cg = &cont.gauges;
    let pg = &paged.gauges;
    let ratio = pg.peak_rows as f64 / cg.peak_rows.max(1) as f64;
    let paged_tok_s = paged.summary.generated_tokens as f64 / paged.wall_s;
    let cont_tok_s = cont.summary.generated_tokens as f64 / cont.wall_s;
    println!("\n=== serve_bench results (Attn NBL-{m}, paged-compare arm) ===");
    println!("requests (per run)       {}", prompts.len());
    println!("peak rows contiguous     {}", cg.peak_rows);
    println!("peak rows paged          {}", pg.peak_rows);
    println!("concurrency ratio        {ratio:.2}x");
    println!("contiguous tok/s         {cont_tok_s:.1}");
    println!("paged tok/s              {paged_tok_s:.1}");
    println!(
        "blocks free/used/shared  {} / {} / {} of {}",
        pg.blocks_free, pg.blocks_used, pg.blocks_shared, pg.blocks_capacity
    );
    println!("fragmentation            {:.3}", pg.paged_fragmentation());
    println!("paged splices            {} ({} tokens)", pg.paged_splices, pg.paged_splice_tokens);
    println!("cow copies               {}", pg.cow_copies);
    println!("preemptions              {}", pg.preemptions);
    println!("snapshot expand copies   {}", pg.prefix_expand_copies);
    println!("prefix publish skips     {}", pg.prefix_publish_skips);

    // the ISSUE 6 acceptance criteria, machine-checked
    assert!(
        pg.peak_rows > cg.peak_rows,
        "paged admission must hold strictly more concurrent rows under the \
         same {budget}-byte budget: paged {} vs contiguous {}",
        pg.peak_rows,
        cg.peak_rows
    );
    assert!(
        pg.paged_splices > 0,
        "the primed prefix must be adopted as block splices: {pg:?}"
    );
    assert_eq!(
        pg.prefix_expand_copies, 0,
        "paged adoption must run ZERO per-layer snapshot expansion copies: {pg:?}"
    );

    let metrics_json = Json::obj(vec![
        ("tok_s", Json::Num(paged_tok_s)),
        ("tok_s_contiguous", Json::Num(cont_tok_s)),
        ("req_s", Json::Num(prompts.len() as f64 / paged.wall_s)),
        ("concurrency_ratio", Json::Num(ratio)),
        ("peak_rows_paged", Json::Num(pg.peak_rows as f64)),
        ("peak_rows_contiguous", Json::Num(cg.peak_rows as f64)),
        ("p50_ttft_ms", Json::Num(paged.summary.p50_ttft_s * 1e3)),
        ("p95_ttft_ms", Json::Num(paged.summary.p95_ttft_s * 1e3)),
        ("p99_ttft_ms", Json::Num(paged.summary.p99_ttft_s * 1e3)),
        ("p50_itl_ms", Json::Num(paged.summary.p50_itl_s * 1e3)),
        ("p95_itl_ms", Json::Num(paged.summary.p95_itl_s * 1e3)),
        ("p99_itl_ms", Json::Num(paged.summary.p99_itl_s * 1e3)),
        ("paged_splices", Json::Num(pg.paged_splices as f64)),
        ("paged_splice_tokens", Json::Num(pg.paged_splice_tokens as f64)),
        ("cow_copies", Json::Num(pg.cow_copies as f64)),
        ("preemptions", Json::Num(pg.preemptions as f64)),
        ("prefix_expand_copies", Json::Num(pg.prefix_expand_copies as f64)),
        ("prefix_publish_skips", Json::Num(pg.prefix_publish_skips as f64)),
        ("paged_fragmentation", Json::Num(pg.paged_fragmentation())),
    ]);
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str("paged".into())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("block_tokens", Json::Num(block_tokens as f64)),
                ("share", Json::Num(share as f64)),
                ("budget_bytes", Json::Num(budget as f64)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json("serve_bench_paged", &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}

/// The ISSUE 8 flight-recorder arm (`--trace <path>`): ONE mixed
/// workload engineered to exercise every span family at once —
/// self-speculative decode (`spec_draft`/`spec_verify`), chunked
/// prefill of long cold prompts (`admit_chunked`/`prefill_chunk`),
/// warm shared-prefix admissions (`admit_warm`, primed), and a paged
/// two-slot KV budget tight enough that decode growth must preempt
/// (`preempt`/`park`/`resume`). The Chrome-trace JSON is fetched over
/// TCP (`{"trace": true}`) before the front-end shuts down, written to
/// `path` for ci/check_trace.py, and the required span kinds are
/// machine-checked here too — a trace missing any of them means a
/// recorder hook regressed, not that the workload got lucky.
#[allow(clippy::too_many_arguments)]
fn run_trace(
    engine: &Arc<Engine>,
    wb: &Workbench,
    n_requests: usize,
    max_tokens: usize,
    chunk: usize,
    spec_width: usize,
    block_tokens: usize,
    trace_events: usize,
    m: usize,
    path: &str,
) -> anyhow::Result<()> {
    let max_ctx = engine.config().max_ctx;
    let n_layers = engine.config().n_layers;
    // same self-speculative draft as the spec arm: the SAME weights
    // under an NBL-heavier plan
    let draft_m = (m + 2).min(n_layers - 1).max(1);
    let draft_plan = wb
        .report
        .plan_attn_nbl(draft_m, Criterion::CcaBound)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // workload: warm shared-prefix shorts, with every 6th request a
    // max-context cold prompt whose uncovered suffix spans multiple
    // chunks (the chunked-prefill machine), all under a 2-slot paged
    // budget so concurrent decode growth exhausts the block pool
    let snap = if chunk > 0 { chunk } else { 128 };
    let share = (2 * snap).min(max_ctx.saturating_sub(64));
    let suffix_len = 16usize;
    let shared = corpus_text(&wb.calib.tokens, 0, share);
    let long_every = 6usize;
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            if long_every > 0 && i % long_every == 0 {
                let start = (share + 1 + i * 997) % (wb.calib.tokens.len() - max_ctx - 1);
                corpus_text(&wb.calib.tokens, start, max_ctx)
            } else {
                let start = (share + 1 + i * 131) % (wb.calib.tokens.len() - suffix_len - 1);
                format!("{shared}{}", corpus_text(&wb.calib.tokens, start, suffix_len))
            }
        })
        .collect();
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    let budget = 2 * per_slot;
    println!(
        "trace workload: {n_requests} requests ({share}-token shared prefix, \
         max-context long every {long_every}), chunk {chunk}, spec width \
         {spec_width}, {block_tokens}-token blocks, budget = 2 contiguous \
         slots ({budget} bytes), ring = {trace_events} events"
    );

    let cfg = ServerConfig {
        kv_capacity_bytes: budget,
        spec: Some(SpecConfig { draft_plan, width: spec_width }),
        prefill_chunk: chunk,
        prefix_cache_bytes: 64 << 20,
        kv_block_tokens: block_tokens,
        trace_events,
        ..ServerConfig::default()
    };
    let prime_start = (share + 7) % (wb.calib.tokens.len() - suffix_len - 1);
    let prime = vec![format!(
        "{shared}{}",
        corpus_text(&wb.calib.tokens, prime_start, suffix_len)
    )];
    let res = run_load(engine, cfg, &prime, &prompts, max_tokens, true, &[])?;

    let trace_text = res.trace_json.expect("trace arm always fetches the recorder");
    let out = std::path::Path::new(path);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, &trace_text)?;

    let j = Json::parse(&trace_text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let events = j
        .get("traceEvents")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .as_arr()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut by_name: std::collections::BTreeMap<String, usize> = Default::default();
    for ev in events {
        if ev.get("ph").map_err(|e| anyhow::anyhow!("{e}"))?.as_str().unwrap_or("") == "E" {
            continue; // count each span once, at its B (instants are "i")
        }
        let name = ev
            .get("name")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_str()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        *by_name.entry(name.to_string()).or_insert(0) += 1;
    }

    let g = &res.gauges;
    println!("\n=== serve_bench results (Attn NBL-{m}, trace arm) ===");
    println!("trace events exported    {}", events.len());
    for (name, count) in &by_name {
        println!("  {name:<16} {count}");
    }
    println!("preemptions              {}", g.preemptions);
    println!("prefill chunks           {}", g.prefill_chunks);
    println!("spec rounds              {}", g.spec_rounds);
    println!("prefix hits              {}", g.prefix_hits);

    // the ISSUE 8 acceptance criterion, machine-checked: the one trace
    // covers admission (cold+warm+chunked), chunked prefill, decode,
    // speculation, and preemption/parking/resume
    assert!(
        g.preemptions > 0,
        "the 2-slot paged budget must force at least one preemption"
    );
    let required = [
        "submit",
        "queue",
        "admit_warm",
        "admit_chunked",
        "prefill_chunk",
        "decode",
        "spec_draft",
        "spec_verify",
        "preempt",
        "park",
        "resume",
        "finish",
    ];
    for name in required {
        assert!(
            by_name.contains_key(name),
            "trace must contain at least one '{name}' event; got {:?}",
            by_name.keys().collect::<Vec<_>>()
        );
    }

    println!("\ntrace JSON written to {}", out.display());
    println!("serve_bench OK");
    Ok(())
}

/// The ISSUE 5 shared-prefix workload: every request is one long shared
/// prefix (the "system prompt") plus a distinct short suffix. Served
/// twice — cold (prefix cache off) and warm (cache on, primed by the
/// first prompt) — the warm run must report a nonzero hit rate and a
/// strictly lower p50 TTFT, and both land in the nbl-bench/v1 JSON that
/// ci/bench_baseline.json floors.
fn run_prefix_share(
    engine: &Arc<Engine>,
    wb: &Workbench,
    n_requests: usize,
    max_tokens: usize,
    chunk: usize,
    replicas: usize,
    m: usize,
) -> anyhow::Result<()> {
    let max_ctx = engine.config().max_ctx;
    // the shared prefix spans two snapshot boundaries (snap = chunk, or
    // 128 when chunking is off), leaving room for the suffix + decode
    let snap = if chunk > 0 { chunk } else { 128 };
    let share = (2 * snap).min(max_ctx.saturating_sub(64));
    let suffix_len = 32usize;
    let shared = corpus_text(&wb.calib.tokens, 0, share);
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let start = (share + 1 + i * 131) % (wb.calib.tokens.len() - suffix_len - 1);
            format!("{shared}{}", corpus_text(&wb.calib.tokens, start, suffix_len))
        })
        .collect();
    println!(
        "shared-prefix workload: {} requests, {share}-token shared prefix + \
         {suffix_len}-token suffixes, chunk {chunk}"
    );

    let cold_cfg = ServerConfig { prefill_chunk: chunk, ..ServerConfig::default() };
    let warm_cfg = ServerConfig {
        prefill_chunk: chunk,
        prefix_cache_bytes: 64 << 20,
        ..ServerConfig::default()
    };
    let cold = run_load(engine, cold_cfg, &[], &prompts, max_tokens, false, &[])?;
    let prime = vec![prompts[0].clone()];
    // with --replicas N > 1, FIRST measure the single-replica hit rate,
    // then re-serve through N replicas: the prefix-affinity dispatcher
    // plus per-replica insert-on-miss must keep the replicated hit rate
    // within 10% of the single-replica value (the ISSUE 10 criterion)
    let single_hit_rate = if replicas > 1 {
        let warm_one =
            run_load(engine, warm_cfg.clone(), &prime, &prompts, max_tokens, false, &[])?;
        Some(warm_one.gauges.prefix_hit_rate())
    } else {
        None
    };
    let warm_cfg = ServerConfig { replicas, ..warm_cfg };
    let warm = run_load(engine, warm_cfg, &prime, &prompts, max_tokens, false, &[])?;

    let p50_cold = percentile(&cold.ttfts_ms, 50.0);
    let p50_warm = percentile(&warm.ttfts_ms, 50.0);
    let g = &warm.gauges;
    let hit_rate = g.prefix_hit_rate();
    println!("\n=== serve_bench results (Attn NBL-{m}, shared-prefix arm) ===");
    println!("requests (per run)       {}", prompts.len());
    if replicas > 1 {
        println!("replicas (warm run)      {}", g.replicas);
    }
    println!("p50 TTFT cold            {p50_cold:.1} ms");
    println!("p50 TTFT warm            {p50_warm:.1} ms");
    println!("prefix hits / misses     {} / {}", g.prefix_hits, g.prefix_misses);
    println!("prefix hit rate          {:.1}%", hit_rate * 100.0);
    println!("prefix hit tokens        {}", g.prefix_hit_tokens);
    println!("prefix inserts/evicts    {} / {}", g.prefix_inserts, g.prefix_evictions);
    println!("prefix bytes             {} / {}", g.prefix_bytes, g.prefix_capacity_bytes);
    let warm_tok_s = warm.summary.generated_tokens as f64 / warm.wall_s;
    println!("warm token throughput    {warm_tok_s:.1} tok/s");

    // the ISSUE 5 acceptance criteria, machine-checked
    assert!(hit_rate > 0.0, "shared-prefix workload must hit the cache");
    if replicas <= 1 {
        assert!(
            g.prefix_hits as usize >= n_requests,
            "every measured request shares the primed prefix: {} hits for {n_requests} requests",
            g.prefix_hits
        );
    }
    assert!(
        p50_warm < p50_cold,
        "warm-hit p50 TTFT must beat cold prefill: {p50_warm:.1} vs {p50_cold:.1} ms"
    );
    // the ISSUE 10 acceptance criterion, machine-checked: only one
    // replica's cache is primed, so the affinity router plus
    // insert-on-miss warm-up on the others must hold the replicated
    // hit rate within 10% of the single-replica value
    if let Some(single) = single_hit_rate {
        println!("prefix hit rate @1       {:.1}%", single * 100.0);
        assert!(
            hit_rate >= 0.9 * single,
            "replicated prefix hit rate must stay within 10% of the \
             single-replica value: {hit_rate:.3} vs {single:.3} at \
             {replicas} replicas"
        );
    }

    let metrics_json = Json::obj(vec![
        ("tok_s", Json::Num(warm_tok_s)),
        ("req_s", Json::Num(prompts.len() as f64 / warm.wall_s)),
        ("p50_ttft_cold_ms", Json::Num(p50_cold)),
        ("p50_ttft_warm_ms", Json::Num(p50_warm)),
        ("warm_over_cold_ttft", Json::Num(p50_cold / p50_warm.max(1e-9))),
        ("prefix_hit_rate", Json::Num(hit_rate)),
        ("prefix_hits", Json::Num(g.prefix_hits as f64)),
        ("prefix_hit_tokens", Json::Num(g.prefix_hit_tokens as f64)),
        ("prefix_inserts", Json::Num(g.prefix_inserts as f64)),
        ("prefix_evictions", Json::Num(g.prefix_evictions as f64)),
    ]);
    let mut metrics_json = metrics_json;
    if let Some(single) = single_hit_rate {
        metrics_json.set("prefix_hit_rate_single_replica", Json::Num(single));
        metrics_json.set("replicas", Json::Num(replicas as f64));
    }
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str("prefix".into())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("chunk", Json::Num(chunk as f64)),
                ("share", Json::Num(share as f64)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json("serve_bench_prefix", &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}

/// Deterministic seedable pseudo-Poisson arrival schedule: LCG uniforms
/// through the exponential quantile. Bursty like real traffic, yet
/// bit-identical for a given seed across runs and machines — seed 0
/// reproduces the burst arm's historical trickle exactly.
fn poisson_arrivals(n: usize, mean_gap_ms: f64, seed: u64) -> Vec<f64> {
    let mut state: u64 = seed ^ 0x9E37_79B9_7F4A_7C15;
    let mut t_ms = 0.0f64;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let u = ((state >> 33) as f64 + 1.0) / (1u64 << 31) as f64;
            t_ms += -u.ln() * mean_gap_ms;
            t_ms
        })
        .collect()
}

/// Tagged one-shot client for the burst arm: waits out its arrival
/// offset, then submits a single request carrying the fairness fields
/// (tenant, DRR weight, a loose deadline so the goodput/SLO metrics
/// engage without any shedding) and returns the server-reported TTFT
/// plus the generated token count.
fn burst_client(
    addr: std::net::SocketAddr,
    id: usize,
    prompt: String,
    max_tokens: usize,
    tenant: &'static str,
    weight: u64,
    delay_ms: f64,
) -> anyhow::Result<(f64, usize)> {
    if delay_ms > 0.0 {
        std::thread::sleep(std::time::Duration::from_micros((delay_ms * 1e3) as u64));
    }
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        r#"{{"id": {id}, "prompt": "{prompt}", "max_tokens": {max_tokens}, "tenant": "{tenant}", "weight": {weight}, "deadline_ms": 60000}}"#
    )?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
    if j.opt("error").is_some() {
        anyhow::bail!("server error: {line}");
    }
    let ttft = j
        .get("ttft_ms")
        .and_then(|v| v.as_f64())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_tokens = j
        .get("tokens")
        .and_then(|v| v.as_arr().map(|a| a.len()))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((ttft, n_tokens))
}

struct BurstRun {
    live_ttfts_ms: Vec<f64>,
    bulk_ttfts_ms: Vec<f64>,
    generated_tokens: usize,
    wall_s: f64,
    summary: MetricsSummary,
    gauges: SchedulerGauges,
}

/// One burst run: bulk requests all land at t=0, live requests trickle
/// in on the (shared) pseudo-Poisson schedule, every request on its own
/// connection so arrival order — not connection order — decides queue
/// position. `fair` tags the two classes as separate tenants with the
/// live lane at 4x weight; untagged, every request lands in one DRR
/// lane, which degenerates to exact FIFO — the baseline policy.
fn run_burst_once(
    engine: &Arc<Engine>,
    fair: bool,
    bulk: &[String],
    live: &[String],
    live_arrivals_ms: &[f64],
    bulk_max: usize,
    live_max: usize,
) -> anyhow::Result<BurstRun> {
    // two decode slots: scarce enough that the bulk burst saturates the
    // server and the queueing policy alone decides who waits
    let cfg = ServerConfig { max_batch: 2, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine.clone(), cfg));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, "127.0.0.1:0").map_err(|e| anyhow::anyhow!("{e}"))?;
    let t_all = Timer::start();
    type Client = std::thread::JoinHandle<anyhow::Result<(f64, usize)>>;
    let mut threads: Vec<(bool, Client)> = Vec::new();
    for (i, p) in bulk.iter().enumerate() {
        let (addr, p) = (front.addr, p.clone());
        let tenant = if fair { "bulk" } else { "" };
        threads.push((
            false,
            std::thread::spawn(move || burst_client(addr, 10_000 + i, p, bulk_max, tenant, 1, 0.0)),
        ));
    }
    for (i, p) in live.iter().enumerate() {
        let (addr, p) = (front.addr, p.clone());
        let tenant = if fair { "live" } else { "" };
        let weight = if fair { 4 } else { 1 };
        let delay = live_arrivals_ms[i];
        threads.push((
            true,
            std::thread::spawn(move || {
                burst_client(addr, 20_000 + i, p, live_max, tenant, weight, delay)
            }),
        ));
    }
    let mut live_ttfts = Vec::new();
    let mut bulk_ttfts = Vec::new();
    let mut tokens = 0usize;
    for (is_live, t) in threads {
        let (ttft, n) = t.join().unwrap()?;
        tokens += n;
        if is_live {
            live_ttfts.push(ttft);
        } else {
            bulk_ttfts.push(ttft);
        }
    }
    let wall_s = t_all.elapsed_s();
    front.shutdown();
    Ok(BurstRun {
        live_ttfts_ms: live_ttfts,
        bulk_ttfts_ms: bulk_ttfts,
        generated_tokens: tokens,
        wall_s,
        summary: metrics.summary(),
        gauges: metrics.gauges(),
    })
}

/// The ISSUE 9 fairness arm (`--burst`): a bulk tenant dumps its whole
/// batch at t=0 (long prompts, long decodes) while an interactive
/// tenant's short requests arrive on a deterministic pseudo-Poisson
/// trickle. Served twice on two decode slots — FIFO (everyone in one
/// lane) vs weighted-fair (live tenant at 4x DRR weight) — with
/// identical prompts and arrival offsets. Weighted-fair must cut the
/// interactive tenant's p95 TTFT strictly below FIFO's (the ISSUE 9
/// acceptance criterion), and the arm emits the SLO attainment (% live
/// requests with TTFT <= `--slo-ms`) and server-side goodput that
/// ci/bench_baseline.json floors.
fn run_burst(
    engine: &Arc<Engine>,
    wb: &Workbench,
    n_requests: usize,
    max_tokens: usize,
    slo_ms: f64,
    m: usize,
) -> anyhow::Result<()> {
    let max_ctx = engine.config().max_ctx;
    let bulk_len = 192.min(max_ctx.saturating_sub(max_tokens + 8)).max(16);
    let live_len = 16usize;
    let live_max = (max_tokens / 4).max(4);
    let corpus = &wb.calib.tokens;
    let bulk: Vec<String> = (0..n_requests)
        .map(|i| corpus_text(corpus, (i * 997) % (corpus.len() - bulk_len - 1), bulk_len))
        .collect();
    let live: Vec<String> = (0..n_requests)
        .map(|i| corpus_text(corpus, (7 + i * 131) % (corpus.len() - live_len - 1), live_len))
        .collect();
    // deterministic pseudo-Poisson arrivals (mean gap 30ms): bursty
    // like real traffic, yet identical across both runs and across
    // machines — the two policies see the SAME offered load
    let arrivals = poisson_arrivals(n_requests, 30.0, 0);
    println!(
        "burst workload: {n_requests} bulk ({bulk_len}-token prompts, {max_tokens} \
         tokens) at t=0 + {n_requests} live ({live_len}-token prompts, {live_max} \
         tokens) over {:.0} ms, 2 slots, SLO = {slo_ms:.0} ms TTFT",
        arrivals.last().copied().unwrap_or(0.0)
    );

    let fifo = run_burst_once(engine, false, &bulk, &live, &arrivals, max_tokens, live_max)?;
    let wfs = run_burst_once(engine, true, &bulk, &live, &arrivals, max_tokens, live_max)?;

    let fifo_p95 = percentile(&fifo.live_ttfts_ms, 95.0);
    let wfs_p95 = percentile(&wfs.live_ttfts_ms, 95.0);
    let ratio = fifo_p95 / wfs_p95.max(1e-9);
    let attainment = |ttfts: &[f64]| {
        ttfts.iter().filter(|&&t| t <= slo_ms).count() as f64 / ttfts.len().max(1) as f64
    };
    let slo_fifo = attainment(&fifo.live_ttfts_ms);
    let slo_wfs = attainment(&wfs.live_ttfts_ms);
    let tok_s = wfs.generated_tokens as f64 / wfs.wall_s;

    println!("\n=== serve_bench results (Attn NBL-{m}, burst arm) ===");
    println!("requests (per run)       {} bulk + {} live", bulk.len(), live.len());
    println!(
        "live p50 TTFT            fifo {:.1} ms, wfs {:.1} ms",
        percentile(&fifo.live_ttfts_ms, 50.0),
        percentile(&wfs.live_ttfts_ms, 50.0)
    );
    println!("live p95 TTFT            fifo {fifo_p95:.1} ms, wfs {wfs_p95:.1} ms");
    println!("wfs-over-fifo p95 TTFT   {ratio:.2}x");
    println!(
        "live SLO attainment      fifo {:.0}%, wfs {:.0}%",
        slo_fifo * 100.0,
        slo_wfs * 100.0
    );
    println!("bulk p95 TTFT (wfs)      {:.1} ms", percentile(&wfs.bulk_ttfts_ms, 95.0));
    println!("token throughput (wfs)   {tok_s:.1} tok/s");
    println!("goodput (wfs)            {:.1} tok/s", wfs.summary.goodput_tok_s);
    println!("server SLO attainment    {:.2}", wfs.summary.slo_attainment);
    println!(
        "shed/expired/cancelled   {} / {} / {}",
        wfs.gauges.shed, wfs.gauges.expired, wfs.gauges.cancelled
    );

    // the ISSUE 9 acceptance criterion, machine-checked: under the same
    // bursty load, weighted-fair strictly beats FIFO on the interactive
    // tenant's tail TTFT
    assert!(
        ratio > 1.0,
        "weighted-fair must cut the live tenant's p95 TTFT strictly below \
         FIFO's: wfs {wfs_p95:.1} vs fifo {fifo_p95:.1} ms"
    );
    assert_eq!(
        wfs.summary.requests,
        bulk.len() + live.len(),
        "every request must finish (deadlines are loose — nothing sheds)"
    );
    assert!(
        wfs.summary.goodput_tok_s > 0.0,
        "deadline-carrying requests must register goodput"
    );

    let metrics_json = Json::obj(vec![
        ("slo_attainment", Json::Num(slo_wfs)),
        ("slo_attainment_fifo", Json::Num(slo_fifo)),
        ("wfs_over_fifo_ttft_p95", Json::Num(ratio)),
        ("live_p50_ttft_ms", Json::Num(percentile(&wfs.live_ttfts_ms, 50.0))),
        ("live_p95_ttft_ms", Json::Num(wfs_p95)),
        ("live_p95_ttft_ms_fifo", Json::Num(fifo_p95)),
        ("bulk_p95_ttft_ms", Json::Num(percentile(&wfs.bulk_ttfts_ms, 95.0))),
        ("goodput_tok_s", Json::Num(wfs.summary.goodput_tok_s)),
        ("server_slo_attainment", Json::Num(wfs.summary.slo_attainment)),
        ("tok_s", Json::Num(tok_s)),
        ("req_s", Json::Num(wfs.summary.requests as f64 / wfs.wall_s)),
        ("shed", Json::Num(wfs.gauges.shed as f64)),
        ("expired", Json::Num(wfs.gauges.expired as f64)),
        ("cancelled", Json::Num(wfs.gauges.cancelled as f64)),
    ]);
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str("burst".into())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num((2 * n_requests) as f64)),
                ("bulk_len", Json::Num(bulk_len as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("live_max_tokens", Json::Num(live_max as f64)),
                ("slo_ms", Json::Num(slo_ms)),
                ("max_batch", Json::Num(2.0)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json("serve_bench_burst", &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}

/// Drive one streamed request on an open connection: submit, then read
/// frames until the terminal, capturing every received line verbatim
/// for ci/check_stream.py. When `cancel_after` is Some(n), a
/// `{"cancel": id}` frame is written (and captured at its send
/// position) right after the n-th token frame. Returns the streamed
/// token values, the concatenated per-frame text pieces, and the
/// terminal frame.
fn drive_stream(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    capture: &mut Vec<String>,
    id: usize,
    prompt: &str,
    max_tokens: usize,
    cancel_after: Option<usize>,
) -> anyhow::Result<(Vec<usize>, String, Json)> {
    writeln!(
        writer,
        r#"{{"id": {id}, "prompt": "{prompt}", "max_tokens": {max_tokens}, "stream": true}}"#
    )?;
    let mut tokens = Vec::new();
    let mut text = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => anyhow::bail!("connection closed mid-stream"),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        capture.push(line.trim().to_string());
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
        let frame = j
            .get("frame")
            .and_then(|f| f.as_str().map(str::to_string))
            .map_err(|e| anyhow::anyhow!("non-frame line mid-stream ({e}): {line}"))?;
        let fid = j.get("id").and_then(|v| v.as_usize()).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(fid == id, "frame for a foreign request: {line}");
        if frame != "token" {
            return Ok((tokens, text, j)); // done | error: the terminal
        }
        let index = j.get("index").and_then(|v| v.as_usize()).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            index == tokens.len(),
            "token index must be dense and monotone: got {index} after {} tokens",
            tokens.len()
        );
        tokens.push(j.get("token").and_then(|v| v.as_usize()).map_err(|e| anyhow::anyhow!("{e}"))?);
        text.push_str(
            j.get("text").and_then(|v| v.as_str()).map_err(|e| anyhow::anyhow!("{e}"))?,
        );
        if cancel_after == Some(tokens.len()) {
            let cancel = format!(r#"{{"cancel": {id}}}"#);
            writeln!(writer, "{cancel}")?;
            capture.push(cancel);
        }
    }
}

/// One full streaming session against a fresh server: a one-shot
/// reference reply, a streamed replay that must match it byte for byte
/// (greedy sampling, same engine), and a streamed request cancelled
/// after its first token frame. Every line the client receives — plus
/// the cancel frame it sends — lands in `capture` verbatim.
fn stream_session(
    engine: &Arc<Engine>,
    cfg: ServerConfig,
    label: &str,
    corpus: &[u32],
    max_tokens: usize,
    id_base: usize,
    capture: &mut Vec<String>,
) -> anyhow::Result<()> {
    let server = Arc::new(Server::new(engine.clone(), cfg));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, "127.0.0.1:0").map_err(|e| anyhow::anyhow!("{e}"))?;
    let stream = TcpStream::connect(front.addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    // (a) one-shot reference: the legacy shape, no "frame" key. Captured
    // too — the checker must tolerate mixed legacy/streamed sessions.
    let prompt = corpus_text(corpus, 3, 24);
    let id = id_base + 1;
    writeln!(writer, r#"{{"id": {id}, "prompt": "{prompt}", "max_tokens": {max_tokens}}}"#)?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    capture.push(line.trim().to_string());
    let oneshot = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(oneshot.opt("error").is_none(), "[{label}] one-shot reference failed: {line}");
    let ref_tokens: Vec<usize> = oneshot
        .get("tokens")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .as_arr()
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .iter()
        .map(|t| t.as_usize())
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let ref_text = oneshot
        .get("text")
        .and_then(|t| t.as_str().map(str::to_string))
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // (b) streamed replay of the same prompt: concatenated token frames
    // must equal the one-shot reply — the parity acceptance criterion
    let (tokens, text, done) =
        drive_stream(&mut reader, &mut writer, capture, id_base + 2, &prompt, max_tokens, None)?;
    let done_kind = done.get("frame").and_then(|f| f.as_str().map(str::to_string)).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(done_kind == "done", "[{label}] uncancelled stream must end in a done frame: {done}");
    anyhow::ensure!(
        tokens == ref_tokens,
        "[{label}] streamed tokens diverge from the one-shot reply"
    );
    anyhow::ensure!(
        text == ref_text,
        "[{label}] concatenated stream text must be byte-identical to the one-shot text"
    );
    let done_text = done
        .get("text")
        .and_then(|t| t.as_str().map(str::to_string))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(done_text == ref_text, "[{label}] terminal frame text diverges");

    // (c) streamed and cancelled after the first token frame: the
    // terminal must be the typed cancelled error, far short of the
    // token budget — the slot freed mid-decode
    let long_max = engine.config().max_ctx.saturating_sub(32).max(64);
    let (cancelled_tokens, _, term) = drive_stream(
        &mut reader,
        &mut writer,
        capture,
        id_base + 3,
        &prompt,
        long_max,
        Some(1),
    )?;
    let term_kind = term.get("frame").and_then(|f| f.as_str().map(str::to_string)).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(term_kind == "error", "[{label}] cancelled stream must end in an error frame: {term}");
    let term_err = term
        .get("error")
        .and_then(|v| v.as_str().map(str::to_string))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        term_err.contains("cancelled"),
        "[{label}] terminal must carry the typed cancelled error, got: {term_err}"
    );
    anyhow::ensure!(
        cancelled_tokens.len() < long_max,
        "[{label}] cancel must stop generation short of the {long_max}-token budget"
    );

    // the scheduler must agree the stream was torn down, not finished
    let g = metrics.gauges();
    anyhow::ensure!(g.cancelled == 1, "[{label}] cancelled gauge must be 1, got {}", g.cancelled);
    front.shutdown();
    println!(
        "  [{label}] parity over {} tokens; cancel stopped {} of {long_max}",
        ref_tokens.len(),
        cancelled_tokens.len()
    );
    Ok(())
}

/// The ISSUE 9 streaming arm (`--stream-capture <path>`): live
/// streaming sessions against the real server — a one-shot parity
/// replay plus a mid-decode cancel — in BOTH plain continuous and
/// self-speculative modes. Parity and cancellation are asserted inline;
/// every received line is captured verbatim to `path` as JSONL so
/// ci/check_stream.py can replay the session and enforce the
/// frame-order invariants offline.
fn run_stream_capture(
    engine: &Arc<Engine>,
    wb: &Workbench,
    max_tokens: usize,
    spec_width: usize,
    m: usize,
    path: &str,
) -> anyhow::Result<()> {
    let n_layers = engine.config().n_layers;
    let draft_m = (m + 2).min(n_layers - 1).max(1);
    let draft_plan = wb
        .report
        .plan_attn_nbl(draft_m, Criterion::CcaBound)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut capture = Vec::new();
    println!("stream-capture: parity + cancel sessions, plain and spec modes");
    stream_session(
        engine,
        ServerConfig::default(),
        "plain",
        &wb.calib.tokens,
        max_tokens,
        100,
        &mut capture,
    )?;
    stream_session(
        engine,
        ServerConfig {
            spec: Some(SpecConfig { draft_plan, width: spec_width }),
            ..ServerConfig::default()
        },
        "spec",
        &wb.calib.tokens,
        max_tokens,
        200,
        &mut capture,
    )?;

    let out = std::path::Path::new(path);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, capture.join("\n") + "\n")?;
    println!("\n=== serve_bench results (Attn NBL-{m}, stream-capture arm) ===");
    println!("captured lines           {}", capture.len());
    println!("capture written to {}", out.display());
    println!("serve_bench OK");
    Ok(())
}

/// The ISSUE 10 scaling arm (`--replicas-compare`): the SAME
/// decode-dominated short-prompt workload served twice — one replica,
/// then `replicas` — under ONE shared KV byte ceiling (the multi run
/// gets no extra cache; it must win on loop concurrency alone). Greedy
/// sampling over identical prompts must generate the exact same token
/// count either way (the dispatcher is routing, not resampling), the
/// `replicas` gauge must roll up to N, and the emitted
/// `replica_scaling_ratio` (multi over single decode throughput) is
/// floored in ci/bench_baseline.json. The ratio is a measurement, not
/// an in-bench assert: on a single-core runner the honest value is
/// ~1.0, and the committed floor is what gates it.
#[allow(clippy::too_many_arguments)]
fn run_replicas_compare(
    engine: &Arc<Engine>,
    wb: &Workbench,
    n_requests: usize,
    max_tokens: usize,
    chunk: usize,
    replicas: usize,
    m: usize,
    arrivals: &[f64],
) -> anyhow::Result<()> {
    // short mixed-length prompts only: scaling here is about running N
    // decode loops concurrently, not about prefill head-of-line
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let len = 16 + (i % 4) * 16;
            let start = (i * 997) % (wb.calib.tokens.len() - 128);
            corpus_text(&wb.calib.tokens, start, len)
        })
        .collect();
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    // one shared ceiling, sized so neither run is KV-starved: the
    // comparison isolates loop concurrency, not admission pressure
    let budget = 2 * replicas * per_slot;
    let base_cfg = ServerConfig {
        kv_capacity_bytes: budget,
        prefill_chunk: chunk,
        ..ServerConfig::default()
    };
    println!(
        "replicas-compare workload: {n_requests} short requests, \
         {max_tokens} tokens, 1 vs {replicas} replicas, shared KV \
         ceiling {budget} bytes"
    );

    let single_cfg = ServerConfig { replicas: 1, ..base_cfg.clone() };
    let single = run_load(engine, single_cfg, &[], &prompts, max_tokens, false, arrivals)?;
    let multi_cfg = ServerConfig { replicas, ..base_cfg };
    let multi = run_load(engine, multi_cfg, &[], &prompts, max_tokens, false, arrivals)?;

    let tok_s_single = single.summary.generated_tokens as f64 / single.wall_s;
    let tok_s_multi = multi.summary.generated_tokens as f64 / multi.wall_s;
    let ratio = tok_s_multi / tok_s_single.max(1e-9);
    println!("\n=== serve_bench results (Attn NBL-{m}, replicas-compare arm) ===");
    println!("requests (per run)       {}", prompts.len());
    println!("replicas                 1 vs {}", multi.gauges.replicas);
    println!("tok/s single             {tok_s_single:.1}");
    println!("tok/s x{replicas:<3}              {tok_s_multi:.1}");
    println!("replica scaling ratio    {ratio:.2}x");
    println!(
        "p50 TTFT single/multi    {:.1} / {:.1} ms",
        single.summary.p50_ttft_s * 1e3,
        multi.summary.p50_ttft_s * 1e3
    );
    println!(
        "iterations single/multi  {} / {}",
        single.gauges.iterations, multi.gauges.iterations
    );
    println!("prefix hits (multi)      {}", multi.gauges.prefix_hits);

    // the ISSUE 10 sanity criteria, machine-checked: replication must
    // not change WHAT is generated, only how fast
    assert_eq!(single.summary.requests, n_requests, "single run must serve every request");
    assert_eq!(multi.summary.requests, n_requests, "multi run must serve every request");
    assert_eq!(
        multi.gauges.replicas, replicas,
        "the replicas gauge must roll up to the configured lane count"
    );
    assert_eq!(single.gauges.replicas, 1, "the N=1 path reports a single lane");
    assert_eq!(
        multi.summary.generated_tokens, single.summary.generated_tokens,
        "greedy decoding must generate the same token count through \
         {replicas} replicas as through 1"
    );

    let metrics_json = Json::obj(vec![
        ("tok_s", Json::Num(tok_s_multi)),
        ("tok_s_single", Json::Num(tok_s_single)),
        ("tok_s_multi", Json::Num(tok_s_multi)),
        ("replica_scaling_ratio", Json::Num(ratio)),
        ("req_s", Json::Num(n_requests as f64 / multi.wall_s)),
        ("generated_tokens", Json::Num(multi.summary.generated_tokens as f64)),
        ("p50_ttft_ms", Json::Num(multi.summary.p50_ttft_s * 1e3)),
        ("p95_ttft_ms", Json::Num(multi.summary.p95_ttft_s * 1e3)),
        ("p50_itl_ms", Json::Num(multi.summary.p50_itl_s * 1e3)),
        ("p95_itl_ms", Json::Num(multi.summary.p95_itl_s * 1e3)),
        ("replicas", Json::Num(replicas as f64)),
    ]);
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str("replicas".into())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("chunk", Json::Num(chunk as f64)),
                ("replicas", Json::Num(replicas as f64)),
                ("budget_bytes", Json::Num(budget as f64)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json("serve_bench_replicas", &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[
        "ttft-compare",
        "prefix-share",
        "paged-compare",
        "burst",
        "replicas-compare",
    ])?;
    let m = args.get_usize("m", 2)?;
    let n_requests = args.get_usize("requests", 24)?;
    let max_tokens = args.get_usize("max-tokens", 48)?;
    let spec_width = args.get_usize("spec-width", 4)?;
    let chunk = args.get_usize("chunk", ServerConfig::default().prefill_chunk)?;
    let long_every = args.get_usize("long-every", 6)?;
    let ttft_compare = args.flag("ttft-compare");
    let replicas = args.get_usize("replicas", 1)?.max(1);
    // --arrivals poisson [--seed S --mean-gap-ms G]: pace the measured
    // load on a seedable deterministic pseudo-Poisson schedule instead
    // of firing each connection's requests back to back
    let seed = args.get_usize("seed", 0)? as u64;
    let mean_gap_ms = args.get_f64("mean-gap-ms", 30.0)?;
    let arrivals: Vec<f64> = match args.get_or("arrivals", "none") {
        "poisson" => {
            let a = poisson_arrivals(n_requests, mean_gap_ms, seed);
            println!(
                "arrivals: poisson, seed {seed}, mean gap {mean_gap_ms:.0} ms, \
                 last at {:.0} ms",
                a.last().copied().unwrap_or(0.0)
            );
            a
        }
        "none" => Vec::new(),
        other => anyhow::bail!("--arrivals must be 'poisson' or 'none', got '{other}'"),
    };
    let mode_name = args.get_or("mode", "continuous").to_string();
    let (mode, spec_on) = match mode_name.as_str() {
        "grouped" => (BatchMode::ExactLength, false),
        "spec" => (BatchMode::Continuous, true),
        _ => (BatchMode::Continuous, false),
    };
    let cfg = ExpConfig::from_env();

    // --- build the NBL-compressed engine
    let wb = Workbench::new("main", cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_layers = wb.engine.config().n_layers;
    let plan = if m == 0 {
        nbl::nbl::plan::ModelPlan::baseline(n_layers)
    } else {
        wb.report
            .plan_attn_nbl(m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    println!("serving plan: {} [{}]", plan.kind.label(), plan.describe());
    let engine = Arc::new(wb.engine.with_plan(plan).map_err(|e| anyhow::anyhow!("{e}"))?);

    // --- ISSUE 8 flight-recorder arm: one spec+chunked+paged workload
    // with the trace ring on, exported for ci/check_trace.py, then exit
    if let Some(path) = args.get("trace") {
        let block_tokens = args.get_usize("block-tokens", 64)?;
        let trace_events = args.get_usize("trace-events", 65536)?;
        return run_trace(
            &engine,
            &wb,
            n_requests,
            max_tokens,
            chunk,
            spec_width,
            block_tokens,
            trace_events,
            m,
            path,
        );
    }

    // --- ISSUE 5 shared-prefix arm: warm-vs-cold prefix reuse (with
    // --replicas N, also replicated-vs-single hit-rate parity), then exit
    if args.flag("prefix-share") {
        return run_prefix_share(&engine, &wb, n_requests, max_tokens, chunk, replicas, m);
    }

    // --- ISSUE 10 scaling arm: 1 vs N replicas under one shared KV
    // ceiling, then exit
    if args.flag("replicas-compare") {
        let n = if replicas > 1 {
            replicas
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).max(2)
        };
        return run_replicas_compare(
            &engine,
            &wb,
            n_requests,
            max_tokens,
            chunk,
            n,
            m,
            &arrivals,
        );
    }

    // --- ISSUE 6 paged-vs-contiguous arm: block-pool admission under an
    // identical two-slot budget, then exit
    if args.flag("paged-compare") {
        let block_tokens = args.get_usize("block-tokens", 64)?;
        return run_paged_compare(&engine, &wb, n_requests, max_tokens, block_tokens, m);
    }

    // --- ISSUE 9 fairness arm: bursty two-tenant load served FIFO vs
    // weighted-fair, then exit
    if args.flag("burst") {
        let slo_ms = args.get_f64("slo-ms", 1500.0)?;
        return run_burst(&engine, &wb, n_requests, max_tokens, slo_ms, m);
    }

    // --- ISSUE 9 streaming arm: captured parity + cancel sessions for
    // ci/check_stream.py, then exit
    if let Some(path) = args.get("stream-capture") {
        return run_stream_capture(&engine, &wb, max_tokens, spec_width, m, path);
    }

    // --- self-speculation: the draft is an NBL-heavier plan over the
    // same Arc-shared weights (no second checkpoint)
    let spec = if spec_on {
        let draft_m = args.get_usize("draft-m", (m + 2).min(n_layers - 1))?;
        let draft_plan = wb
            .report
            .plan_attn_nbl(draft_m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "draft plan:   {} [{}], verify width {spec_width}",
            draft_plan.kind.label(),
            draft_plan.describe()
        );
        Some(SpecConfig { draft_plan, width: spec_width })
    } else {
        None
    };

    // --- client load: MIXED-length prompts from the corpus (16/32/48/64
    // bytes interleaved), plus one max-context 512-token prompt every
    // `long_every` requests — the admission that, unchunked, stalls every
    // in-flight decode row and every queued short behind it
    let max_ctx = engine.config().max_ctx;
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let len = if long_every > 0 && i % long_every == 0 {
                max_ctx
            } else {
                16 + (i % 4) * 16
            };
            let start = (i * 997) % (wb.calib.tokens.len() - max_ctx - 1);
            corpus_text(&wb.calib.tokens, start, len)
        })
        .collect();
    let has_long = long_every > 0 && prompts.iter().any(|p| p.len() >= max_ctx / 2);

    let server_cfg =
        ServerConfig { mode, spec, prefill_chunk: chunk, replicas, ..ServerConfig::default() };
    println!("mode: {mode:?}, prefill chunk: {chunk} (0 = whole-prompt), replicas: {replicas}");
    let res = run_load(&engine, server_cfg.clone(), &[], &prompts, max_tokens, false, &arrivals)?;

    // --- report
    let s = &res.summary;
    let g = &res.gauges;
    let wall = res.wall_s;
    let p50_short = res.p50_short_ttft_ms();
    println!("\n=== serve_bench results (Attn NBL-{m}, {mode:?}, mixed lengths) ===");
    println!("requests                 {}", s.requests);
    println!("generated tokens         {}", s.generated_tokens);
    println!("wall time                {wall:.2} s");
    println!("request throughput       {:.2} req/s", s.requests as f64 / wall);
    println!("token throughput         {:.1} tok/s", s.generated_tokens as f64 / wall);
    println!("mean TTFT                {:.1} ms", s.mean_ttft_s * 1e3);
    println!("p90 TTFT                 {:.1} ms", s.p90_ttft_s * 1e3);
    println!(
        "p50/p95/p99 TTFT         {:.1} / {:.1} / {:.1} ms",
        s.p50_ttft_s * 1e3,
        s.p95_ttft_s * 1e3,
        s.p99_ttft_s * 1e3
    );
    println!(
        "p50/p95/p99 ITL          {:.2} / {:.2} / {:.2} ms",
        s.p50_itl_s * 1e3,
        s.p95_itl_s * 1e3,
        s.p99_itl_s * 1e3
    );
    println!("p50 short-request TTFT   {p50_short:.1} ms");
    println!("prefill speed            {:.0} tok/s", s.mean_prefill_tok_s);
    println!("median decode speed      {:.0} tok/s", s.median_decode_tok_s);
    println!("mean e2e latency         {:.1} ms", mean(&res.latencies) * 1e3);
    println!(
        "p90 e2e latency          {:.1} ms",
        percentile(&res.latencies, 90.0) * 1e3
    );
    if mode == BatchMode::Continuous {
        if replicas > 1 {
            println!("replicas                 {}", g.replicas);
        }
        println!("decode iterations        {}", g.iterations);
        println!("mean rows/iteration      {:.2}", g.mean_rows_per_iteration());
        println!("batch occupancy          {:.1}%", g.mean_occupancy() * 100.0);
        println!("slot reuses              {}", g.slot_reuses);
        println!("prefill chunks           {}", g.prefill_chunks);
        println!("chunked admissions       {}", g.chunked_admissions);
        println!(
            "chunk stalls             {} ({:.1} ms mean)",
            g.chunk_stalls,
            g.mean_chunk_stall_ms()
        );
    }
    if spec_on {
        println!("spec rounds              {}", g.spec_rounds);
        println!("acceptance rate          {:.1}%", g.acceptance_rate() * 100.0);
        println!(
            "tokens/target-iteration  {:.2} per row",
            g.tokens_per_row_iteration()
        );
        if args.get("draft-m").is_none() {
            // the default self-speculative draft must pay for itself on
            // the synthetic workload; a user-supplied draft plan is
            // exploratory, so its numbers are reported, not asserted
            assert!(
                g.tokens_per_row_iteration() > 1.0,
                "speculation must commit > 1 token per row per target pass, \
                 got {:.2}",
                g.tokens_per_row_iteration()
            );
        } else if g.tokens_per_row_iteration() <= 1.0 {
            println!("WARNING: this draft plan never beat plain decoding");
        }
    }
    assert_eq!(s.requests, n_requests, "all requests must be served");

    // --- chunked-vs-whole TTFT comparison (the acceptance criterion:
    // short requests admitted behind a 512-token prompt see lower p50
    // TTFT under chunked continuous admission)
    let mut p50_short_unchunked = None;
    if ttft_compare && mode == BatchMode::Continuous {
        let whole_cfg = ServerConfig { prefill_chunk: 0, ..server_cfg };
        let whole = run_load(&engine, whole_cfg, &[], &prompts, max_tokens, false, &arrivals)?;
        let p50_whole = whole.p50_short_ttft_ms();
        p50_short_unchunked = Some(p50_whole);
        println!("\n[ttft-compare] p50 short-request TTFT");
        println!("  chunked (chunk {chunk:>3})    {p50_short:8.1} ms");
        println!("  whole-prompt prefill   {p50_whole:8.1} ms");
        if has_long && g.prefill_chunks > 0 {
            assert!(
                p50_short < p50_whole,
                "chunked prefill must lower p50 short-request TTFT behind a \
                 {max_ctx}-token prompt: {p50_short:.1} vs {p50_whole:.1} ms"
            );
        } else {
            println!("  (no chunked admissions ran — comparison reported, not asserted)");
        }
    }

    // --- bench JSON (nbl-bench/v1; consumed by ci/collect_bench.py)
    let mut metrics_json = Json::obj(vec![
        ("tok_s", Json::Num(s.generated_tokens as f64 / wall)),
        ("req_s", Json::Num(s.requests as f64 / wall)),
        ("generated_tokens", Json::Num(s.generated_tokens as f64)),
        ("wall_s", Json::Num(wall)),
        ("mean_ttft_ms", Json::Num(s.mean_ttft_s * 1e3)),
        ("p50_ttft_ms", Json::Num(s.p50_ttft_s * 1e3)),
        ("p90_ttft_ms", Json::Num(s.p90_ttft_s * 1e3)),
        ("p95_ttft_ms", Json::Num(s.p95_ttft_s * 1e3)),
        ("p99_ttft_ms", Json::Num(s.p99_ttft_s * 1e3)),
        ("p50_itl_ms", Json::Num(s.p50_itl_s * 1e3)),
        ("p95_itl_ms", Json::Num(s.p95_itl_s * 1e3)),
        ("p99_itl_ms", Json::Num(s.p99_itl_s * 1e3)),
        ("p50_short_ttft_ms", Json::Num(p50_short)),
        ("mean_rows_per_iteration", Json::Num(g.mean_rows_per_iteration())),
        ("prefill_chunks", Json::Num(g.prefill_chunks as f64)),
        ("chunked_admissions", Json::Num(g.chunked_admissions as f64)),
        ("chunk_stall_ms_mean", Json::Num(g.mean_chunk_stall_ms())),
        ("spec_acceptance_rate", Json::Num(g.acceptance_rate())),
        ("tokens_per_row_iteration", Json::Num(g.tokens_per_row_iteration())),
    ]);
    if let Some(p) = p50_short_unchunked {
        metrics_json.set("p50_short_ttft_ms_unchunked", Json::Num(p));
    }
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str(mode_name.clone())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("chunk", Json::Num(chunk as f64)),
                ("long_every", Json::Num(long_every as f64)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json(&format!("serve_bench_{mode_name}"), &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}
