//! END-TO-END serving driver (the brief's required E2E example): bring up
//! the full stack — engine + scheduler + worker + TCP front-end — under an
//! NBL-compressed model, fire a MIXED-PROMPT-LENGTH workload of real
//! requests over TCP, and report latency/throughput. Results are recorded
//! in EXPERIMENTS.md and, for CI's perf-smoke job, emitted as bench JSON
//! (reports/serve_bench_<mode>.json, schema nbl-bench/v1 — see
//! ci/collect_bench.py).
//!
//! The workload interleaves four short prompt lengths and (every
//! `--long-every`-th request) one max-context 512-token prompt — the
//! head-of-line case chunked prefill exists for: without chunking, every
//! in-flight decode and every queued short stalls behind the whole long
//! prefill. `--mode grouped` runs the legacy exact-length baseline;
//! `--mode spec` runs continuous batching with self-speculative
//! draft-and-verify iterations (the draft is the SAME weights under an
//! NBL-heavier plan — paper §5 composition, served). `--ttft-compare`
//! re-runs the continuous workload with chunking disabled and asserts
//! the p50 TTFT of short requests dropped (the ISSUE 4 acceptance
//! criterion, machine-checked).
//!
//!     cargo run --release --example serve_bench \
//!         [-- --m 2 --requests 24 --max-tokens 48 \
//!              --mode spec --spec-width 4 --draft-m 4 \
//!              --chunk 128 --long-every 6 --ttft-compare]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::server::metrics::{MetricsSummary, RequestTiming, SchedulerGauges};
use nbl::server::service::{BatchMode, Server, ServerConfig, SpecConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::util::cli::Args;
use nbl::util::json::Json;
use nbl::util::timer::Timer;
use nbl::util::{mean, percentile};

/// Prompts below this many tokens count as "short" when slicing TTFT —
/// the workload's short lengths are 16..64, the long prompt is 512.
const SHORT_PROMPT_MAX: usize = 100;

struct LoadResult {
    wall_s: f64,
    latencies: Vec<f64>,
    summary: MetricsSummary,
    gauges: SchedulerGauges,
    timings: Vec<RequestTiming>,
}

impl LoadResult {
    /// p50 TTFT (ms) over the short requests — the number a long prompt
    /// at the head of the line inflates, and chunked prefill lowers.
    fn p50_short_ttft_ms(&self) -> f64 {
        let shorts: Vec<f64> = self
            .timings
            .iter()
            .filter(|t| t.prompt_tokens < SHORT_PROMPT_MAX)
            .map(|t| t.ttft_s * 1e3)
            .collect();
        percentile(&shorts, 50.0)
    }
}

/// Serve `prompts` through a fresh server + TCP front-end: 4 concurrent
/// client connections, requests round-robin-chunked across them.
fn run_load(
    engine: &Arc<Engine>,
    cfg: ServerConfig,
    prompts: &[String],
    max_tokens: usize,
) -> anyhow::Result<LoadResult> {
    let server = Arc::new(Server::new(engine.clone(), cfg));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, "127.0.0.1:0").map_err(|e| anyhow::anyhow!("{e}"))?;

    let t_all = Timer::start();
    let mut client_threads = Vec::new();
    let per_conn = prompts.len().div_ceil(4).max(1);
    for (c, chunk) in prompts.chunks(per_conn).enumerate() {
        let chunk: Vec<String> = chunk.to_vec();
        let addr = front.addr;
        client_threads.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut latencies = Vec::new();
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            for (i, p) in chunk.iter().enumerate() {
                let id = c * 1000 + i;
                let t = Timer::start();
                writeln!(
                    writer,
                    r#"{{"id": {id}, "prompt": "{p}", "max_tokens": {max_tokens}}}"#
                )?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                latencies.push(t.elapsed_s());
                let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
                if j.opt("error").is_some() {
                    anyhow::bail!("server error: {line}");
                }
            }
            Ok(latencies)
        }));
    }
    let mut latencies = Vec::new();
    for t in client_threads {
        latencies.extend(t.join().unwrap()?);
    }
    let wall_s = t_all.elapsed_s();
    front.shutdown();
    Ok(LoadResult {
        wall_s,
        latencies,
        summary: metrics.summary(),
        gauges: metrics.gauges(),
        timings: metrics.timings(),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["ttft-compare"])?;
    let m = args.get_usize("m", 2)?;
    let n_requests = args.get_usize("requests", 24)?;
    let max_tokens = args.get_usize("max-tokens", 48)?;
    let spec_width = args.get_usize("spec-width", 4)?;
    let chunk = args.get_usize("chunk", ServerConfig::default().prefill_chunk)?;
    let long_every = args.get_usize("long-every", 6)?;
    let ttft_compare = args.flag("ttft-compare");
    let mode_name = args.get_or("mode", "continuous").to_string();
    let (mode, spec_on) = match mode_name.as_str() {
        "grouped" => (BatchMode::ExactLength, false),
        "spec" => (BatchMode::Continuous, true),
        _ => (BatchMode::Continuous, false),
    };
    let cfg = ExpConfig::from_env();

    // --- build the NBL-compressed engine
    let wb = Workbench::new("main", cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_layers = wb.engine.config().n_layers;
    let plan = if m == 0 {
        nbl::nbl::plan::ModelPlan::baseline(n_layers)
    } else {
        wb.report
            .plan_attn_nbl(m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?
    };
    println!("serving plan: {} [{}]", plan.kind.label(), plan.describe());
    let engine = Arc::new(wb.engine.with_plan(plan).map_err(|e| anyhow::anyhow!("{e}"))?);

    // --- self-speculation: the draft is an NBL-heavier plan over the
    // same Arc-shared weights (no second checkpoint)
    let spec = if spec_on {
        let draft_m = args.get_usize("draft-m", (m + 2).min(n_layers - 1))?;
        let draft_plan = wb
            .report
            .plan_attn_nbl(draft_m, Criterion::CcaBound)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "draft plan:   {} [{}], verify width {spec_width}",
            draft_plan.kind.label(),
            draft_plan.describe()
        );
        Some(SpecConfig { draft_plan, width: spec_width })
    } else {
        None
    };

    // --- client load: MIXED-length prompts from the corpus (16/32/48/64
    // bytes interleaved), plus one max-context 512-token prompt every
    // `long_every` requests — the admission that, unchunked, stalls every
    // in-flight decode row and every queued short behind it
    let max_ctx = engine.config().max_ctx;
    let prompts: Vec<String> = (0..n_requests)
        .map(|i| {
            let len = if long_every > 0 && i % long_every == 0 {
                max_ctx
            } else {
                16 + (i % 4) * 16
            };
            let start = (i * 997) % (wb.calib.tokens.len() - max_ctx - 1);
            // one byte per token, JSON-safe: the byte tokenizer must see
            // EXACTLY `len` tokens (a multi-byte replacement char would
            // push a 512-byte prompt past the prefill grid)
            wb.calib.tokens[start..start + len]
                .iter()
                .map(|&t| {
                    let b = t as u8;
                    if b.is_ascii_alphanumeric() || b == b' ' {
                        b as char
                    } else {
                        ' '
                    }
                })
                .collect::<String>()
        })
        .collect();
    let has_long = long_every > 0 && prompts.iter().any(|p| p.len() >= max_ctx / 2);

    let server_cfg = ServerConfig { mode, spec, prefill_chunk: chunk, ..ServerConfig::default() };
    println!("mode: {mode:?}, prefill chunk: {chunk} (0 = whole-prompt)");
    let res = run_load(&engine, server_cfg.clone(), &prompts, max_tokens)?;

    // --- report
    let s = &res.summary;
    let g = &res.gauges;
    let wall = res.wall_s;
    let p50_short = res.p50_short_ttft_ms();
    println!("\n=== serve_bench results (Attn NBL-{m}, {mode:?}, mixed lengths) ===");
    println!("requests                 {}", s.requests);
    println!("generated tokens         {}", s.generated_tokens);
    println!("wall time                {wall:.2} s");
    println!("request throughput       {:.2} req/s", s.requests as f64 / wall);
    println!("token throughput         {:.1} tok/s", s.generated_tokens as f64 / wall);
    println!("mean TTFT                {:.1} ms", s.mean_ttft_s * 1e3);
    println!("p90 TTFT                 {:.1} ms", s.p90_ttft_s * 1e3);
    println!("p50 short-request TTFT   {p50_short:.1} ms");
    println!("prefill speed            {:.0} tok/s", s.mean_prefill_tok_s);
    println!("median decode speed      {:.0} tok/s", s.median_decode_tok_s);
    println!("mean e2e latency         {:.1} ms", mean(&res.latencies) * 1e3);
    println!(
        "p90 e2e latency          {:.1} ms",
        percentile(&res.latencies, 90.0) * 1e3
    );
    if mode == BatchMode::Continuous {
        println!("decode iterations        {}", g.iterations);
        println!("mean rows/iteration      {:.2}", g.mean_rows_per_iteration());
        println!("batch occupancy          {:.1}%", g.mean_occupancy() * 100.0);
        println!("slot reuses              {}", g.slot_reuses);
        println!("prefill chunks           {}", g.prefill_chunks);
        println!("chunked admissions       {}", g.chunked_admissions);
        println!(
            "chunk stalls             {} ({:.1} ms mean)",
            g.chunk_stalls,
            g.mean_chunk_stall_ms()
        );
    }
    if spec_on {
        println!("spec rounds              {}", g.spec_rounds);
        println!("acceptance rate          {:.1}%", g.acceptance_rate() * 100.0);
        println!(
            "tokens/target-iteration  {:.2} per row",
            g.tokens_per_row_iteration()
        );
        if args.get("draft-m").is_none() {
            // the default self-speculative draft must pay for itself on
            // the synthetic workload; a user-supplied draft plan is
            // exploratory, so its numbers are reported, not asserted
            assert!(
                g.tokens_per_row_iteration() > 1.0,
                "speculation must commit > 1 token per row per target pass, \
                 got {:.2}",
                g.tokens_per_row_iteration()
            );
        } else if g.tokens_per_row_iteration() <= 1.0 {
            println!("WARNING: this draft plan never beat plain decoding");
        }
    }
    assert_eq!(s.requests, n_requests, "all requests must be served");

    // --- chunked-vs-whole TTFT comparison (the acceptance criterion:
    // short requests admitted behind a 512-token prompt see lower p50
    // TTFT under chunked continuous admission)
    let mut p50_short_unchunked = None;
    if ttft_compare && mode == BatchMode::Continuous {
        let whole_cfg = ServerConfig { prefill_chunk: 0, ..server_cfg };
        let whole = run_load(&engine, whole_cfg, &prompts, max_tokens)?;
        let p50_whole = whole.p50_short_ttft_ms();
        p50_short_unchunked = Some(p50_whole);
        println!("\n[ttft-compare] p50 short-request TTFT");
        println!("  chunked (chunk {chunk:>3})    {p50_short:8.1} ms");
        println!("  whole-prompt prefill   {p50_whole:8.1} ms");
        if has_long && g.prefill_chunks > 0 {
            assert!(
                p50_short < p50_whole,
                "chunked prefill must lower p50 short-request TTFT behind a \
                 {max_ctx}-token prompt: {p50_short:.1} vs {p50_whole:.1} ms"
            );
        } else {
            println!("  (no chunked admissions ran — comparison reported, not asserted)");
        }
    }

    // --- bench JSON (nbl-bench/v1; consumed by ci/collect_bench.py)
    let mut metrics_json = Json::obj(vec![
        ("tok_s", Json::Num(s.generated_tokens as f64 / wall)),
        ("req_s", Json::Num(s.requests as f64 / wall)),
        ("generated_tokens", Json::Num(s.generated_tokens as f64)),
        ("wall_s", Json::Num(wall)),
        ("mean_ttft_ms", Json::Num(s.mean_ttft_s * 1e3)),
        ("p90_ttft_ms", Json::Num(s.p90_ttft_s * 1e3)),
        ("p50_short_ttft_ms", Json::Num(p50_short)),
        ("mean_rows_per_iteration", Json::Num(g.mean_rows_per_iteration())),
        ("prefill_chunks", Json::Num(g.prefill_chunks as f64)),
        ("chunked_admissions", Json::Num(g.chunked_admissions as f64)),
        ("chunk_stall_ms_mean", Json::Num(g.mean_chunk_stall_ms())),
        ("spec_acceptance_rate", Json::Num(g.acceptance_rate())),
        ("tokens_per_row_iteration", Json::Num(g.tokens_per_row_iteration())),
    ]);
    if let Some(p) = p50_short_unchunked {
        metrics_json.set("p50_short_ttft_ms_unchunked", Json::Num(p));
    }
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("serve_bench".into())),
        ("mode", Json::Str(mode_name.clone())),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
                ("chunk", Json::Num(chunk as f64)),
                ("long_every", Json::Num(long_every as f64)),
                ("m", Json::Num(m as f64)),
            ]),
        ),
        ("metrics", metrics_json),
    ]);
    let path = nbl::report::save_json(&format!("serve_bench_{mode_name}"), &bench_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nbench JSON written to {}", path.display());
    println!("serve_bench OK");
    Ok(())
}
