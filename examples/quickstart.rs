//! Quickstart: load the trained model, apply NBL to 2 attention layers,
//! and generate text — the 60-second tour of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart

use nbl::data::corpus::{Corpus, CorpusId};
use nbl::data::ByteTokenizer;
use nbl::executor::{CaptureSource, Engine};
use nbl::model::Artifacts;
use nbl::nbl::calibrate::Calibrator;
use nbl::nbl::criteria::Criterion;
use nbl::runtime::Runtime;
use nbl::spec::greedy_generate;

fn main() -> anyhow::Result<()> {
    // 1. load artifacts (HLO grid + trained weights) and build the engine
    let artifacts = Artifacts::discover()?;
    let runtime = Runtime::new(artifacts.clone())?;
    let engine = Engine::load(runtime, "main")?;
    println!(
        "loaded '{}': {} layers, d={}, {} params",
        engine.config().name,
        engine.config().n_layers,
        engine.config().d_model,
        engine.weights.param_count()
    );

    // 2. calibrate: stream activations, compute CCA bounds + LMMSE fits
    let calib = Corpus::load(&artifacts, CorpusId::TinyC4, "train")?;
    let mut source = CaptureSource::new(&engine, &calib.tokens, 16, 128);
    let report = Calibrator::run(&mut source)?;
    println!("\nper-layer CCA NMSE bound (Thm 3.2; lower = more linearizable):");
    for lc in &report.layers {
        println!("  layer {}: {:.4}", lc.layer, lc.cca.nmse_bound);
    }

    // 3. substitute the 2 most linearizable attention layers (Alg. 1)
    let plan = report.plan_attn_nbl(2, Criterion::CcaBound)?;
    println!("\nplan: {}  (KV kept: {:.0}%)", plan.describe(), plan.kv_fraction() * 100.0);
    let compressed = engine.with_plan(plan)?;

    // 4. generate from both models
    let tok = ByteTokenizer::new();
    let prompt = "the small robot ";
    let ids = tok.encode(prompt);
    let base_out = greedy_generate(&engine, &ids, 48)?;
    let nbl_out = greedy_generate(&compressed, &ids, 48)?;
    println!("\nprompt:    {prompt:?}");
    println!("baseline:  {:?}", tok.decode(&base_out));
    println!("attn-nbl2: {:?}", tok.decode(&nbl_out));
    Ok(())
}
