//! Speculative decoding + NBL (Table 6 scenario as a runnable example):
//! draft-and-verify with the 2-layer draft model against baseline and
//! NBL-compressed verifiers, printing compounding speed-ups.
//!
//!     cargo run --release --example speculative [-- --tokens 96]

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::data::ByteTokenizer;
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::runtime::Runtime;
use nbl::spec::{greedy_generate, SpeculativeDecoder};
use nbl::util::cli::Args;
use nbl::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let tokens = args.get_usize("tokens", 96)?;
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new("main", cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let artifacts = nbl::model::Artifacts::discover().map_err(|e| anyhow::anyhow!("{e}"))?;
    let runtime = Runtime::new(artifacts).map_err(|e| anyhow::anyhow!("{e}"))?;
    let draft = Engine::load(runtime, "draft").map_err(|e| anyhow::anyhow!("{e}"))?;

    let tok = ByteTokenizer::new();
    let prompt = tok.encode("the bright engine near the data hall ");

    // baseline plain decoding
    let t0 = Timer::start();
    let base_out =
        greedy_generate(&wb.engine, &prompt, tokens).map_err(|e| anyhow::anyhow!("{e}"))?;
    let base_t = t0.elapsed_s();
    println!("plain greedy: {:.2} tok/s", tokens as f64 / base_t);
    println!("  text: {:?}\n", tok.decode(&base_out[..32.min(base_out.len())]));

    for m in [0usize, 1, 2, 3] {
        let target = if m == 0 {
            wb.engine
                .with_plan(nbl::nbl::plan::ModelPlan::baseline(wb.engine.config().n_layers))
        } else {
            wb.engine
                .with_plan(wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap())
        }
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        let dec = SpeculativeDecoder::new(&target, &draft, 4);
        let t = Timer::start();
        let (out, stats) = dec.generate(&prompt, tokens).map_err(|e| anyhow::anyhow!("{e}"))?;
        let secs = t.elapsed_s();
        let label = if m == 0 { "spec".into() } else { format!("NBL-{m}+spec") };
        println!(
            "{label:<12} {:>6.2} tok/s  speedup x{:.2}  acceptance {:.2}  tok/target-pass {:.2}",
            tokens as f64 / secs,
            base_t / secs,
            stats.acceptance_rate(),
            stats.tokens_per_target_pass(),
        );
        if m == 0 {
            assert_eq!(out, base_out, "spec must match greedy");
        }
    }
    Ok(())
}
