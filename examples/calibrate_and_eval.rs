//! Full NBL pipeline on one model: calibrate -> rank -> substitute at
//! several m -> evaluate all 8 reasoning tasks + perplexity, printing a
//! Table-2-style summary. Compare with `NBL_FAST=1` for a quick pass.
//!
//!     cargo run --release --example calibrate_and_eval [-- --model main --ms 1,2,3]

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::eval::perplexity;
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;
use nbl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let model = args.get_or("model", "main");
    let ms = args.get_usize_list("ms", &[1, 2, 3])?;
    let cfg = ExpConfig::from_env();

    let wb = Workbench::new(model, cfg.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "calibrated {} on {} ({} seqs x {} tokens)\n",
        model,
        wb.calib.id.name(),
        cfg.calib_seqs,
        cfg.calib_len
    );

    let mut table = Table::new(
        &format!("calibrate_and_eval ({model})"),
        &["Method", "avg_acc", "pooled_se", "ppl", "prefill_x", "tput_x", "kv"],
    );
    let base_speed = wb.speed(&wb.engine).map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut plans = vec![nbl::nbl::plan::ModelPlan::baseline(wb.engine.config().n_layers)];
    for &m in &ms {
        plans.push(
            wb.report
                .plan_attn_nbl(m, Criterion::CcaBound)
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        );
        plans.push(wb.report.plan_attn_drop(m, Criterion::CosineDistance));
    }

    for plan in plans {
        let label = plan.kind.label();
        let kv = plan.kv_fraction();
        let engine = wb.engine.with_plan(plan).map_err(|e| anyhow::anyhow!("{e}"))?;
        let acc = wb.accuracy(&engine).map_err(|e| anyhow::anyhow!("{e}"))?;
        let ppl = perplexity(&engine, &wb.val, cfg.ppl_windows, 128)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let speed = wb.speed(&engine).map_err(|e| anyhow::anyhow!("{e}"))?;
        table.row(vec![
            label,
            format!("{:.1}", acc.avg_accuracy * 100.0),
            format!("{:.2}", acc.pooled_se * 100.0),
            format!("{ppl:.3}"),
            format!("{:.2}", speed.prefill_tok_s / base_speed.prefill_tok_s),
            format!("{:.2}", speed.decode_tok_s / base_speed.decode_tok_s),
            format!("{kv:.2}"),
        ]);
    }
    println!("{}", table.render());
    table.save("example_calibrate_and_eval").ok();
    Ok(())
}
