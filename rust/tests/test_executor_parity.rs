//! Cross-language parity: the Rust layerwise pipeline must reproduce the
//! JAX full-model forward on the trained weights (artifacts/goldens.json),
//! and the cached decode path must agree with prefill.

use std::sync::Arc;

use nbl::executor::Engine;
use nbl::model::Artifacts;
use nbl::runtime::Runtime;
use nbl::sampling::argmax;
use nbl::util::json::Json;

fn setup(model: &str) -> (Engine, Json, Vec<u32>) {
    let artifacts = Artifacts::discover().expect("run `make artifacts` first");
    let goldens = artifacts.goldens().unwrap();
    let prompt: Vec<u32> = goldens
        .get("prompt")
        .unwrap()
        .as_usize_vec()
        .unwrap()
        .iter()
        .map(|&x| x as u32)
        .collect();
    let runtime = Runtime::new(artifacts).unwrap();
    let engine = Engine::load(runtime, model).unwrap();
    (engine, goldens, prompt)
}

#[test]
fn prefill_logits_match_jax_goldens() {
    let (engine, goldens, prompt) = setup("main");
    let g = goldens.get("main").unwrap();
    let want_last = g.get("logits_last").unwrap().as_f32_vec().unwrap();
    let want_argmax = g.get("argmax_per_pos").unwrap().as_usize_vec().unwrap();

    let len = prompt.len();
    let out = engine.prefill(&prompt, 1, len, None).unwrap();
    let logits = engine.head(&out.hidden).unwrap();

    // last-position logits numerically close (fp32, 6 layers deep)
    let last = logits.at2(0, len - 1);
    let mut max_err = 0.0f32;
    for (a, b) in last.iter().zip(&want_last) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 2e-3, "last-logit max err {max_err}");

    // argmax agreement at every position
    for (t, &want) in want_argmax.iter().enumerate() {
        let got = argmax(logits.at2(0, t)) as usize;
        assert_eq!(got, want, "argmax mismatch at position {t}");
    }
}

#[test]
fn all_models_match_goldens_loosely() {
    let artifacts = Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts.clone()).unwrap();
    let goldens = artifacts.goldens().unwrap();
    let prompt: Vec<u32> = goldens
        .get("prompt")
        .unwrap()
        .as_usize_vec()
        .unwrap()
        .iter()
        .map(|&x| x as u32)
        .collect();
    for model in ["alt", "distill", "draft"] {
        let engine = Engine::load(runtime.clone(), model).unwrap();
        let out = engine.prefill(&prompt, 1, prompt.len(), None).unwrap();
        let logits = engine.head(&out.hidden).unwrap();
        let want = goldens
            .get(model)
            .unwrap()
            .get("logits_last")
            .unwrap()
            .as_f32_vec()
            .unwrap();
        let last = logits.at2(0, prompt.len() - 1);
        let mut max_err = 0.0f32;
        for (a, b) in last.iter().zip(&want) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-3, "{model}: max err {max_err}");
    }
}

#[test]
fn decode_matches_prefill_shifted() {
    // prefill(prompt[..n]) + decode(prompt[n..]) must equal the full
    // prefill logits at the same absolute positions.
    let (engine, _goldens, prompt) = setup("main");
    let n0 = 24;
    let extra = 4;
    let full = engine.prefill(&prompt[..n0 + extra], 1, n0 + extra, None).unwrap();
    let full_logits = engine.head(&full.hidden).unwrap();

    let pre = engine.prefill(&prompt[..n0], 1, n0, None).unwrap();
    let mut state = pre.state;
    for (i, &tok) in prompt[n0..n0 + extra].iter().enumerate() {
        let logits = engine.decode(&mut state, &[tok], 1).unwrap();
        let got = logits.at2(0, 0);
        let want = full_logits.at2(0, n0 + i);
        let mut max_err = 0.0f32;
        for (a, b) in got.iter().zip(want) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-3, "step {i}: max err {max_err}");
        assert_eq!(argmax(got), argmax(want), "argmax diverged at step {i}");
    }
}

#[test]
fn multi_token_decode_matches_single_steps() {
    // the speculative-verify path (S=4) must agree with 4 single steps
    let (engine, _goldens, prompt) = setup("main");
    let n0 = 16;
    let pre1 = engine.prefill(&prompt[..n0], 1, n0, None).unwrap();
    let mut s1 = pre1.state;
    let tokens = &prompt[n0..n0 + 4];
    let wide = engine.decode(&mut s1, tokens, 4).unwrap();

    let pre2 = engine.prefill(&prompt[..n0], 1, n0, None).unwrap();
    let mut s2 = pre2.state;
    for (i, &tok) in tokens.iter().enumerate() {
        let narrow = engine.decode(&mut s2, &[tok], 1).unwrap();
        let a = wide.at2(0, i);
        let b = narrow.at2(0, 0);
        let mut max_err = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            max_err = max_err.max((x - y).abs());
        }
        assert!(max_err < 2e-3, "position {i}: err {max_err}");
    }
    assert_eq!(s1.pos, s2.pos);
}

#[test]
fn wide_rows_decode_matches_stepwise_rows_decode() {
    // the speculative verify op (attn_cached_rows s=4) must agree with
    // four s=1 iterations, per row, with rows at DIFFERENT positions —
    // the invariant the spec scheduler's draft-and-verify relies on
    use nbl::executor::{RowDecode, RowSpecDecode};
    let (engine, _goldens, prompt) = setup("main");
    let lens = [12usize, 20];
    let slots = [0usize, 3];
    let mk_arena = || {
        let mut arena = engine.new_arena(8).unwrap();
        for (&len, &slot) in lens.iter().zip(&slots) {
            let pre = engine.prefill(&prompt[..len], 1, len, None).unwrap();
            arena.adopt(slot, &pre.state).unwrap();
        }
        arena
    };
    let width = 4usize;
    let feeds: Vec<Vec<u32>> = lens
        .iter()
        .map(|&len| prompt[len..len + width].to_vec())
        .collect();

    // one wide verify pass
    let mut wide_arena = mk_arena();
    let vrows: Vec<RowSpecDecode> = slots
        .iter()
        .zip(&feeds)
        .map(|(&slot, f)| RowSpecDecode { slot, tokens: f.clone() })
        .collect();
    let wide = engine.decode_rows_spec(&mut wide_arena, &vrows).unwrap();
    assert_eq!(wide.shape(), &[2, width, engine.config().vocab]);

    // the same tokens as four single-token iterations
    let mut step_arena = mk_arena();
    for j in 0..width {
        let rows: Vec<RowDecode> = slots
            .iter()
            .zip(&feeds)
            .map(|(&slot, f)| RowDecode { slot, token: f[j] })
            .collect();
        let narrow = engine.decode_rows(&mut step_arena, &rows).unwrap();
        for i in 0..slots.len() {
            let a = wide.at2(i, j);
            let b = narrow.at2(i, 0);
            let mut max_err = 0.0f32;
            for (x, y) in a.iter().zip(b) {
                max_err = max_err.max((x - y).abs());
            }
            assert!(max_err < 2e-3, "row {i} step {j}: err {max_err}");
            assert_eq!(argmax(a), argmax(b), "argmax diverged at row {i} step {j}");
        }
    }
    // both protocols leave every row advanced by `width`
    for (&slot, &len) in slots.iter().zip(&lens) {
        assert_eq!(wide_arena.pos(slot), Some(len + width));
        assert_eq!(step_arena.pos(slot), Some(len + width));
    }
}

#[test]
fn capture_stats_match_jax_goldens() {
    // per-layer attention I/O mean/std must match capture_attn_io
    let (engine, goldens, prompt) = setup("main");
    let want = goldens.get("main").unwrap().get("attn_io").unwrap();
    let mut got: Vec<(f32, f32, f32, f32)> = Vec::new();
    let mut cb = |_layer: usize, x: &nbl::tensor::Tensor, y: &nbl::tensor::Tensor| {
        got.push((x.mean(), x.std(), y.mean(), y.std()));
        Ok(())
    };
    let _ = engine
        .prefill(&prompt, 1, prompt.len(), Some(&mut cb))
        .unwrap();
    let arr = want.as_arr().unwrap();
    assert_eq!(arr.len(), got.len());
    for (i, (w, g)) in arr.iter().zip(&got).enumerate() {
        let wx = w.get("x_mean").unwrap().as_f64().unwrap() as f32;
        let wy = w.get("y_mean").unwrap().as_f64().unwrap() as f32;
        let wxs = w.get("x_std").unwrap().as_f64().unwrap() as f32;
        let wys = w.get("y_std").unwrap().as_f64().unwrap() as f32;
        assert!((g.0 - wx).abs() < 1e-3, "layer {i} x_mean {} vs {wx}", g.0);
        assert!((g.1 - wxs).abs() < 1e-3, "layer {i} x_std");
        assert!((g.2 - wy).abs() < 1e-3, "layer {i} y_mean");
        assert!((g.3 - wys).abs() < 1e-3, "layer {i} y_std");
    }
}

#[test]
fn pallas_lowering_matches_jnp_lowering() {
    // the Pallas-lowered attention executable must agree with the default
    // jnp-lowered one on the same weights (L1 parity *through PJRT*).
    let (engine, _g, prompt) = setup("main");
    let rt: &Arc<Runtime> = &engine.runtime;
    let w = &engine.weights.layers[0];
    let x = engine.weights.embed(&prompt, 1, prompt.len()).unwrap();
    let xl = nbl::runtime::lit_from_tensor(&x).unwrap();
    let args = [
        &xl,
        &nbl::runtime::lit_from_tensor(&w.attn_norm).unwrap(),
        &nbl::runtime::lit_from_tensor(&w.wq).unwrap(),
        &nbl::runtime::lit_from_tensor(&w.wk).unwrap(),
        &nbl::runtime::lit_from_tensor(&w.wv).unwrap(),
        &nbl::runtime::lit_from_tensor(&w.wo).unwrap(),
    ];
    let jnp = rt.run("attn_prefill_b1_t32", &args).unwrap();
    let pal = rt.run("attn_prefill_pallas_b1_t32", &args).unwrap();
    assert_eq!(jnp.len(), pal.len());
    for (a, b) in jnp.iter().zip(&pal) {
        let ta = nbl::runtime::tensor_from_lit(a).unwrap();
        let tb = nbl::runtime::tensor_from_lit(b).unwrap();
        assert!(ta.max_abs_diff(&tb) < 1e-4, "pallas vs jnp {}", ta.max_abs_diff(&tb));
    }
}

#[test]
fn oversized_prompt_is_rejected() {
    let (engine, _g, _p) = setup("main");
    let ids = vec![1u32; 600];
    assert!(engine.prefill(&ids, 1, 600, None).is_err());
}

#[test]
fn context_overflow_is_rejected() {
    let (engine, _g, prompt) = setup("main");
    let pre = engine.prefill(&prompt, 1, prompt.len(), None).unwrap();
    let mut state = pre.state;
    state.pos = state.max_ctx; // simulate a full cache
    assert!(engine.decode(&mut state, &[1], 1).is_err());
}
