//! End-to-end NBL: calibrate on the real trained model, build plans,
//! and verify the paper's qualitative claims at small scale:
//!   1. the trained model beats chance on the eval tasks;
//!   2. NBL-m stays close to baseline perplexity at small m;
//!   3. NBL-m degrades less than DROP-m at the same m;
//!   4. KV accounting follows (K-m)/K.

use std::sync::Arc;

use nbl::data::corpus::{Corpus, CorpusId};
use nbl::eval::perplexity;
use nbl::executor::{CaptureSource, Engine};
use nbl::model::Artifacts;
use nbl::nbl::calibrate::Calibrator;
use nbl::nbl::criteria::Criterion;
use nbl::runtime::Runtime;

struct Fixture {
    engine: Engine,
    report: nbl::nbl::calibrate::CalibrationReport,
    val: Corpus,
}

fn fixture() -> Fixture {
    let artifacts = Artifacts::discover().expect("run `make artifacts`");
    let runtime = Runtime::new(artifacts.clone()).unwrap();
    let engine = Engine::load(runtime, "main").unwrap();
    let train = Corpus::load(&artifacts, CorpusId::TinyC4, "train").unwrap();
    let val = Corpus::load(&artifacts, CorpusId::TinyC4, "val").unwrap();
    let mut src = CaptureSource::new(&engine, &train.tokens, 24, 128);
    let report = Calibrator::run(&mut src).unwrap();
    Fixture { engine, report, val }
}

#[test]
fn full_nbl_pipeline() {
    let f = fixture();
    let n_layers = f.engine.config().n_layers;
    assert_eq!(f.report.layers.len(), n_layers);

    // --- bounds are sane and layer-dependent (Fig. 2 shape)
    let scores = f.report.scores(Criterion::CcaBound);
    let d = f.engine.config().d_model as f64;
    for (i, s) in scores.iter().enumerate() {
        assert!(*s >= 0.0 && *s <= d, "layer {i} bound {s}");
    }
    let spread = scores.iter().cloned().fold(f64::MIN, f64::max)
        - scores.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 1e-3, "bounds should differentiate layers: {scores:?}");

    // --- baseline perplexity is meaningful (model trained to loss ~0.33)
    let base_ppl = perplexity(&f.engine, &f.val, 8, 128).unwrap();
    assert!(
        base_ppl > 1.0 && base_ppl < 4.0,
        "baseline ppl {base_ppl} out of expected range"
    );

    // --- NBL-1/2 stay close; DROP at same m is worse or equal
    for m in [1usize, 2] {
        let nbl_plan = f.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap();
        assert_eq!(nbl_plan.kv_layers(), n_layers - m);
        let nbl_engine = f.engine.with_plan(nbl_plan).unwrap();
        let nbl_ppl = perplexity(&nbl_engine, &f.val, 8, 128).unwrap();

        let drop_plan = f.report.plan_attn_drop(m, Criterion::CcaBound);
        let drop_engine = f.engine.with_plan(drop_plan).unwrap();
        let drop_ppl = perplexity(&drop_engine, &f.val, 8, 128).unwrap();

        assert!(
            nbl_ppl < base_ppl * 2.5,
            "NBL-{m} ppl {nbl_ppl} blew up vs base {base_ppl}"
        );
        assert!(
            nbl_ppl <= drop_ppl * 1.05,
            "NBL-{m} ({nbl_ppl}) should not be worse than DROP-{m} ({drop_ppl})"
        );
    }
}

#[test]
fn trained_model_beats_chance_on_tasks() {
    let artifacts = Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let engine = Engine::load(runtime, "main").unwrap();
    // two cheap, high-signal tasks
    let tasks: Vec<_> = nbl::eval::all_tasks()
        .iter()
        .filter(|t| t.name == "boolq" || t.name == "arc_e")
        .cloned()
        .collect();
    let summary = nbl::eval::evaluate_all(&engine, &tasks, 12, 99).unwrap();
    for t in &summary.tasks {
        let chance = match t.name {
            "boolq" => 0.5,
            _ => 0.25,
        };
        assert!(
            t.accuracy > chance + 0.15,
            "{}: accuracy {} barely above chance {chance}",
            t.name,
            t.accuracy
        );
    }
}

#[test]
fn linearized_layer_reduces_measured_nmse_vs_identity() {
    // the fitted LMMSE layer must beat the "drop" estimator (Y_hat = 0)
    // on fresh data: SSE(lmmse) < SSE(zero) for every layer.
    let f = fixture();
    let artifacts = Artifacts::discover().unwrap();
    let val = Corpus::load(&artifacts, CorpusId::TinyC4, "val").unwrap();
    let d = f.engine.config().d_model;
    for lc in &f.report.layers {
        let lin = lc.fit_linear().unwrap();
        let mut src = CaptureSource::new(&f.engine, &val.tokens, 2, 64);
        let mut sse_lin = 0.0f64;
        let mut sse_zero = 0.0f64;
        let layer = lc.layer;
        nbl::nbl::calibrate::ActivationSource::stream(&mut src, &mut |li, x, y| {
            if li == layer {
                for r in 0..x.len() / d {
                    let xr = &x[r * d..(r + 1) * d];
                    let yr = &y[r * d..(r + 1) * d];
                    let yh = lin.apply_row(xr);
                    for j in 0..d {
                        sse_lin += ((yr[j] - yh[j]) as f64).powi(2);
                        sse_zero += (yr[j] as f64).powi(2);
                    }
                }
            }
            Ok(())
        })
        .unwrap();
        assert!(
            sse_lin < sse_zero,
            "layer {layer}: lmmse SSE {sse_lin} !< zero-estimator SSE {sse_zero}"
        );
    }
}

#[test]
fn block_nbl_and_sleb_plans_execute() {
    let f = fixture();
    // Block NBL-1: replace the best whole block with a residual fit
    let scores = f.report.scores(Criterion::CcaBound);
    let idx = nbl::nbl::criteria::select_lowest(&scores, 1)[0];
    let lin = f.report.layers[idx].fit_linear_residual().unwrap();
    let mut plan = nbl::nbl::plan::ModelPlan::baseline(f.engine.config().n_layers);
    plan.kind = nbl::nbl::plan::PlanKind::BlockNbl(1);
    plan.linearize_block(idx, Arc::new(lin));
    let engine = f.engine.with_plan(plan).unwrap();
    let ppl = perplexity(&engine, &f.val, 4, 128).unwrap();
    assert!(ppl.is_finite() && ppl < 40.0, "block-NBL ppl {ppl}");

    // SLEB-1 via the greedy perplexity driver (tiny budget)
    let sleb = nbl::baselines::sleb_select(f.engine.config().n_layers, 1, |p| {
        let e = f.engine.with_plan(p.clone())?;
        perplexity(&e, &f.val, 2, 128)
    })
    .unwrap();
    let e = f.engine.with_plan(sleb).unwrap();
    assert!(perplexity(&e, &f.val, 2, 128).unwrap().is_finite());
}
