//! Bounded-exhaustive model check of the slot-lifecycle state machine
//! (DESIGN.md §Static analysis, dynamic back-stops).
//!
//! [`SlotLedger`] maintains three derived quantities incrementally —
//! the ascending occupied-index list, the free count, and the O(1)
//! free-head hint — and the serving loop trusts all three every
//! iteration. This harness drives EVERY op sequence up to a bounded
//! depth (reserve / set_pos / release over every slot, including a
//! deliberately out-of-range index) against a naive oracle that
//! re-scans a plain state vector from scratch, comparing return values
//! and every public observation after every step. A divergence prints
//! the exact op trace that produced it.
//!
//! Depth/width are small by default so the check rides in tier-1; the
//! nightly model-check job sets `NBL_MODEL_EXHAUSTIVE=1` for the deep
//! configuration (run it `--release`). Everything here is XLA-free, so
//! the nightly Miri job can interpret it too.

use nbl::kvcache::ledger::{SlotLedger, SlotState};

#[derive(Clone, Copy, Debug)]
enum Op {
    Reserve(usize),
    SetPos(usize, usize),
    Release(usize),
}

/// Naive reference model: a bare state vector, every derived quantity
/// re-derived by a full rescan (the invariant definitions, literally).
#[derive(Clone)]
struct Naive {
    rows: usize,
    slots: Vec<SlotState>,
}

impl Naive {
    fn new(rows: usize) -> Naive {
        Naive { rows, slots: vec![SlotState::Free; rows] }
    }

    fn occupied(&self) -> Vec<usize> {
        (0..self.rows)
            .filter(|&s| matches!(self.slots[s], SlotState::Occupied(_)))
            .collect()
    }

    fn free(&self) -> Vec<usize> {
        (0..self.rows).filter(|&s| self.slots[s] == SlotState::Free).collect()
    }

    /// Apply `op`; returns whether it should succeed.
    fn apply(&mut self, op: Op) -> bool {
        match op {
            Op::Reserve(s) => {
                if s >= self.rows || self.slots[s] != SlotState::Free {
                    return false;
                }
                self.slots[s] = SlotState::Reserved;
                true
            }
            Op::SetPos(s, p) => {
                if s >= self.rows {
                    return false;
                }
                self.slots[s] = SlotState::Occupied(p);
                true
            }
            Op::Release(s) => {
                if s >= self.rows {
                    return false;
                }
                self.slots[s] = SlotState::Free;
                true
            }
        }
    }
}

/// Compare every public observation of the ledger against the oracle.
fn assert_agrees(l: &SlotLedger, n: &Naive, trace: &[Op]) {
    assert!(
        l.occupied().windows(2).all(|w| w[0] < w[1]),
        "occ not strictly ascending after {trace:?}: {:?}",
        l.occupied()
    );
    assert_eq!(l.occupied(), n.occupied().as_slice(), "occ diverged after {trace:?}");
    assert_eq!(l.occupancy(), n.occupied().len(), "occupancy diverged after {trace:?}");
    let free = n.free();
    assert_eq!(l.free_slots(), free.len(), "free count diverged after {trace:?}");
    assert_eq!(l.free_slot(), free.first().copied(), "free head diverged after {trace:?}");
    assert_eq!(l.rows(), n.rows);
    // probe one index past the end too: out-of-range must read as None
    for s in 0..n.rows + 1 {
        assert_eq!(l.state(s), n.slots.get(s).copied(), "state({s}) diverged after {trace:?}");
        let want_pos = match n.slots.get(s) {
            Some(SlotState::Occupied(p)) => Some(*p),
            _ => None,
        };
        assert_eq!(l.pos(s), want_pos, "pos({s}) diverged after {trace:?}");
        assert_eq!(
            l.is_reserved(s),
            matches!(n.slots.get(s), Some(SlotState::Reserved)),
            "is_reserved({s}) diverged after {trace:?}"
        );
    }
}

/// The op alphabet at one tree node: every action on every slot, plus
/// the out-of-range index `rows`. `set_pos` takes a depth-dependent
/// position so stale-position bugs cannot hide behind equal values.
fn alphabet(rows: usize, depth: usize) -> Vec<Op> {
    let mut ops = Vec::with_capacity(3 * (rows + 1));
    for s in 0..=rows {
        ops.push(Op::Reserve(s));
        ops.push(Op::SetPos(s, depth + 1));
        ops.push(Op::Release(s));
    }
    ops
}

fn dfs(l: &SlotLedger, n: &Naive, depth_left: usize, trace: &mut Vec<Op>, visited: &mut u64) {
    if depth_left == 0 {
        return;
    }
    for op in alphabet(n.rows, trace.len()) {
        let mut l2 = l.clone();
        let mut n2 = n.clone();
        let want = n2.apply(op);
        let got = match op {
            Op::Reserve(s) => l2.reserve(s).is_ok(),
            Op::SetPos(s, p) => l2.set_pos(s, p),
            Op::Release(s) => l2.release(s),
        };
        trace.push(op);
        assert_eq!(got, want, "return value diverged after {trace:?}");
        assert_agrees(&l2, &n2, trace);
        *visited += 1;
        dfs(&l2, &n2, depth_left - 1, trace, visited);
        trace.pop();
    }
}

fn exhaustive() -> bool {
    std::env::var("NBL_MODEL_EXHAUSTIVE").is_ok_and(|v| !v.is_empty() && v != "0")
}

#[test]
fn model_ledger_bounded_exhaustive_matches_oracle() {
    // 2 rows / depth 4 visits ~6.6k states in well under a second; the
    // nightly exhaustive configuration (3 rows / depth 6) visits ~3M
    // and wants --release.
    let (rows, depth) = if exhaustive() { (3, 6) } else { (2, 4) };
    let ledger = SlotLedger::new(rows);
    let naive = Naive::new(rows);
    let mut visited = 0u64;
    dfs(&ledger, &naive, depth, &mut Vec::new(), &mut visited);
    let floor = if exhaustive() { 1_000_000 } else { 5_000 };
    assert!(visited >= floor, "model check degenerated: only {visited} states visited");
}

#[test]
fn model_ledger_long_random_walk_matches_oracle() {
    // breadth where the DFS has depth: one deterministic 20k-op walk
    // over a wider ledger, same oracle, same full-observation compare.
    let rows = 5usize;
    let mut l = SlotLedger::new(rows);
    let mut n = Naive::new(rows);
    let mut x = 0x2545F4914F6CDD1Du64;
    let mut window: Vec<Op> = Vec::new();
    for i in 0..20_000usize {
        // xorshift*: deterministic, no external RNG dep
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        let r = (x.wrapping_mul(0x2545F4914F6CDD1D) >> 32) as usize;
        let s = r % (rows + 1); // includes the out-of-range index
        let op = match (r / 7) % 3 {
            0 => Op::Reserve(s),
            1 => Op::SetPos(s, i),
            _ => Op::Release(s),
        };
        let want = n.apply(op);
        let got = match op {
            Op::Reserve(s) => l.reserve(s).is_ok(),
            Op::SetPos(s, p) => l.set_pos(s, p),
            Op::Release(s) => l.release(s),
        };
        // keep a short trailing window so a failure prints actionable
        // context instead of 20k ops
        if window.len() == 16 {
            window.remove(0);
        }
        window.push(op);
        assert_eq!(got, want, "return value diverged at step {i}, tail {window:?}");
        assert_agrees(&l, &n, &window);
    }
}
