//! Serving-stack integration: continuous batching (mixed prompt lengths,
//! slot reuse, scheduler fairness, KV accounting), legacy batched groups,
//! the async worker, the TCP front-end, speculative decoding equivalence,
//! and quantization.

use std::io::{BufRead, BufReader, Write};
use std::sync::{mpsc, Arc};

use nbl::executor::Engine;
use nbl::kvcache::KvPool;
use nbl::model::Artifacts;
use nbl::quant::{quantize_weights, QuantConfig};
use nbl::runtime::Runtime;
use nbl::sampling::SamplingParams;
use nbl::server::api::{GenRequest, StreamToken};
use nbl::server::service::{BatchMode, Server, ServerConfig, SpecConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::server::Scheduler;
use nbl::spec::{greedy_generate, SpeculativeDecoder};
use nbl::util::proptest::check;

fn engine(model: &str) -> Engine {
    let artifacts = Artifacts::discover().expect("run `make artifacts`");
    let runtime = Runtime::new(artifacts).unwrap();
    Engine::load(runtime, model).unwrap()
}

fn req(id: u64, prompt: &str, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: nbl::data::ByteTokenizer::new().encode(prompt),
        max_new_tokens: n,
        params: SamplingParams::greedy(),
        tenant: String::new(),
        weight: 1,
        deadline_ms: None,
        stream: false,
    }
}

/// A streaming variant of [`req`]: same request, but every committed
/// token is also forwarded on a per-request sink as it lands.
fn stream_req(id: u64, prompt: &str, n: usize) -> GenRequest {
    GenRequest { stream: true, ..req(id, prompt, n) }
}

/// Drain a streaming sink after its terminal response arrived. The
/// frames must all carry the request id with dense 0-based indices.
fn drain_sink(id: u64, rx: &mpsc::Receiver<StreamToken>) -> Vec<u32> {
    let mut toks = Vec::new();
    while let Ok(t) = rx.try_recv() {
        assert_eq!(t.id, id, "sink frames must carry their request id");
        assert_eq!(t.index, toks.len(), "stream indices must be dense and ordered");
        toks.push(t.token);
    }
    toks
}

#[test]
fn single_request_generates_text() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let r = server.generate_one(&req(1, "the small robot ", 24));
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens.len(), 24);
    assert!(r.ttft_ms > 0.0 && r.total_ms >= r.ttft_ms);
    // greedy continuation of the trained grammar should be ascii words
    assert!(r.text.is_ascii());
    assert!(r.text.chars().any(|c| c.is_ascii_lowercase()), "{:?}", r.text);
}

#[test]
fn batched_group_matches_single_requests() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let a = req(1, "the bright engine ", 12);
    let b = req(2, "the hidden garden ", 12);
    let solo_a = server.generate_one(&a);
    let solo_b = server.generate_one(&b);
    let group = server.run_group(&[a, b]).unwrap();
    assert_eq!(group[0].tokens, solo_a.tokens, "batch row 0 diverged");
    assert_eq!(group[1].tokens, solo_b.tokens, "batch row 1 diverged");
}

#[test]
fn group_rejects_mixed_lengths() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let e = server.run_group(&[req(1, "abcd", 2), req(2, "abcde", 2)]);
    assert!(e.is_err());
}

#[test]
fn async_worker_serves_many() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rxs: Vec<_> = (0..5)
        .map(|i| handle.submit(req(i, "there are 42 small ", 8)))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert_eq!(r.tokens.len(), 8);
    }
    assert_eq!(metrics.len(), 5);
    let s = metrics.summary();
    assert!(s.mean_prefill_tok_s > 0.0);
    handle.shutdown();
}

#[test]
fn tcp_round_trip() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let front = TcpFrontend::start(server, "127.0.0.1:0").unwrap();
    let mut conn = std::net::TcpStream::connect(front.addr).unwrap();
    writeln!(
        conn,
        r#"{{"id": 9, "prompt": "the quiet river ", "max_tokens": 6}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = nbl::util::json::Json::parse(&line).unwrap();
    assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 9);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 6);
    // malformed line comes back as an error response, not a hangup
    writeln!(conn, "not json").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"));
    // stats endpoint reports the scheduler gauges
    writeln!(conn, r#"{{"stats": true}}"#).unwrap();
    let mut line3 = String::new();
    reader.read_line(&mut line3).unwrap();
    let stats = nbl::util::json::Json::parse(&line3).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 1);
    assert!(stats.get("kv_capacity_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(stats.opt("queue_depth").is_some());
    assert!(stats.opt("mean_batch_occupancy").is_some());
    front.shutdown();
}

#[test]
fn speculative_matches_greedy_exactly() {
    let target = engine("main");
    let draft = engine("draft");
    let tok = nbl::data::ByteTokenizer::new();
    for prompt in ["the small robot ", "== ring buffer ==\na ring ", "there are 7 "] {
        let ids = tok.encode(prompt);
        let want = greedy_generate(&target, &ids, 40).unwrap();
        let dec = SpeculativeDecoder::new(&target, &draft, 4);
        let (got, stats) = dec.generate(&ids, 40).unwrap();
        assert_eq!(got, want, "speculative output diverged for {prompt:?}");
        assert!(stats.proposed > 0);
        assert!(
            stats.acceptance_rate() > 0.3,
            "draft should be useful: acceptance {}",
            stats.acceptance_rate()
        );
        assert!(stats.tokens_per_target_pass() > 1.0, "no compounding");
    }
}

#[test]
fn speculative_composes_with_nbl() {
    let target = engine("main");
    let artifacts = Artifacts::discover().unwrap();
    let train =
        nbl::data::Corpus::load(&artifacts, nbl::data::corpus::CorpusId::TinyC4, "train").unwrap();
    let mut src = nbl::executor::CaptureSource::new(&target, &train.tokens, 12, 128);
    let report = nbl::nbl::calibrate::Calibrator::run(&mut src).unwrap();
    let plan = report
        .plan_attn_nbl(2, nbl::nbl::criteria::Criterion::CcaBound)
        .unwrap();
    let nbl_target = target.with_plan(plan).unwrap();
    let draft = engine("draft");
    let tok = nbl::data::ByteTokenizer::new();
    let ids = tok.encode("the bright market ");
    let want = greedy_generate(&nbl_target, &ids, 32).unwrap();
    let dec = SpeculativeDecoder::new(&nbl_target, &draft, 4);
    let (got, stats) = dec.generate(&ids, 32).unwrap();
    assert_eq!(got, want, "NBL-compressed verifier diverged");
    assert!(stats.rounds < 32, "verification must batch tokens");
}

#[test]
fn quantized_model_still_generates() {
    let artifacts = Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let base = Engine::load(runtime.clone(), "main").unwrap();
    let q = quantize_weights(&base.weights, None, &QuantConfig { bits: 8, alpha: 0.0 }).unwrap();
    let qe = Engine::new(
        runtime,
        Arc::new(q),
        nbl::nbl::plan::ModelPlan::baseline(base.config().n_layers),
    )
    .unwrap();
    let tok = nbl::data::ByteTokenizer::new();
    let ids = tok.encode("the small robot ");
    let a = greedy_generate(&base, &ids, 16).unwrap();
    let b = greedy_generate(&qe, &ids, 16).unwrap();
    // int8 is near-lossless at this scale: outputs should mostly agree
    let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    assert!(agree >= 12, "int8 generation diverged early: {agree}/16");
}

#[test]
fn kv_pool_admission_control() {
    let cfg = ServerConfig { kv_capacity_bytes: 1024, ..ServerConfig::default() };
    let server = Server::new(Arc::new(engine("main")), cfg);
    // a single group needs ~MBs of KV; a 1 KiB pool must refuse
    let r = server.generate_one(&req(1, "the small robot ", 4));
    assert!(r.error.is_some());
    assert!(r.error.unwrap().contains("KV pool exhausted"));
}

// ---------------------------------------------------------------------------
// continuous batching (iteration-level scheduling over per-request KV slots)

#[test]
fn continuous_batching_mixes_prompt_lengths() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    // four DIFFERENT prompt lengths submitted together: the old
    // exact-length protocol served these as four batch-1 groups; the
    // continuous scheduler must decode them in shared iterations
    let prompts = [
        "hi ",
        "the small robot ",
        "a much longer prompt about walled gardens ",
        "the quick brown fox jumps over the lazy dog and keeps going ",
    ];
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| handle.submit(req(i as u64, p, 24)))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 24);
    }
    let g = metrics.gauges();
    assert_eq!(g.admissions, 4);
    assert!(
        g.mean_rows_per_iteration() > 1.0,
        "requests with different prompt lengths must share decode \
         iterations, got {:.2} rows/iter over {} iterations",
        g.mean_rows_per_iteration(),
        g.iterations
    );
    handle.shutdown();
}

#[test]
fn continuous_batching_matches_solo_outputs() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let reqs = [
        req(1, "the bright engine ", 12),
        req(2, "a hidden garden of ", 12),
        req(3, "ring ", 12),
    ];
    // greedy solo references (legacy batch-1 protocol)
    let solo: Vec<_> = reqs.iter().map(|r| server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    // same requests through the continuous worker, mixed lengths
    let handle = server.clone().spawn();
    let rxs: Vec<_> = reqs.iter().map(|r| handle.submit(r.clone())).collect();
    for (rx, s) in rxs.into_iter().zip(&solo) {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens, s.tokens, "continuous decode diverged from solo");
    }
    handle.shutdown();
}

#[test]
fn finished_slot_is_reused_without_restarting_the_batch() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    // 12 mixed-length requests against an 8-row arena: at least 4
    // admissions must land in slots freed by finished requests, while
    // other rows keep decoding (the batch never restarts). Varied
    // max_tokens stagger the departures.
    let rxs: Vec<_> = (0..12u64)
        .map(|i| {
            let p = "the small robot walked around "[..(10 + (i as usize % 4) * 5)].to_string();
            handle.submit(req(i, &p, 6 + (i as usize % 3) * 8))
        })
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.tokens.is_empty());
    }
    let g = metrics.gauges();
    assert_eq!(g.admissions, 12);
    assert!(
        g.slot_reuses >= 1,
        "a freed KV slot must be reused by a later request: {g:?}"
    );
    assert!(
        g.mean_rows_per_iteration() > 1.0,
        "slot reuse must happen mid-flight, not batch-by-batch: {g:?}"
    );
    handle.shutdown();
}

#[test]
fn exact_length_mode_still_serves() {
    let cfg = ServerConfig { mode: BatchMode::ExactLength, ..ServerConfig::default() };
    let server = Arc::new(Server::new(Arc::new(engine("main")), cfg));
    let handle = server.clone().spawn();
    let rxs: Vec<_> = (0..3)
        .map(|i| handle.submit(req(i, "the small robot ", 6)))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 6);
    }
    handle.shutdown();
}

#[test]
fn shutdown_answers_every_pending_request() {
    // regression: a Submission::Shutdown drained mid-loop used to drop
    // pending reply channels silently (clients hung on a dead receiver);
    // every submitted request must now receive SOME response
    for mode in [BatchMode::Continuous, BatchMode::ExactLength] {
        let cfg = ServerConfig { mode, ..ServerConfig::default() };
        let server = Arc::new(Server::new(Arc::new(engine("main")), cfg));
        let handle = server.clone().spawn();
        let rxs: Vec<_> = (0..6)
            .map(|i| handle.submit(req(i, "the small robot ", 200)))
            .collect();
        handle.shutdown();
        for rx in rxs {
            let r = rx
                .recv()
                .expect("pending request must be answered on shutdown, not dropped");
            // either it finished in time or it was refused — never a hang
            assert!(r.error.is_some() || !r.tokens.is_empty());
        }
    }
}

#[test]
fn scheduler_never_starves_the_oldest_request() {
    // property: over random arrival/finish churn, requests are admitted
    // in exactly arrival order (head-of-queue discipline), no matter how
    // slots free up or how prompt lengths vary
    check(
        0xC0FFEE,
        50,
        |g| {
            let n = g.size(40);
            (0..n).map(|_| g.usize_in(0, 2)).collect::<Vec<usize>>()
        },
        |trace| {
            const SLOTS: usize = 4;
            const SLOT_BYTES: usize = 100;
            let pool = KvPool::new(SLOTS * SLOT_BYTES);
            let mut sched = Scheduler::new();
            let mut next_id = 0u64;
            let mut leases = Vec::new();
            let mut admitted: Vec<u64> = Vec::new();
            for &ev in trace {
                if ev <= 1 {
                    // arrival (prompt length varies with id)
                    sched.push(GenRequest {
                        id: next_id,
                        prompt: vec![1; 8 + (next_id as usize % 5)],
                        ..req(next_id, "x", 4)
                    });
                    next_id += 1;
                } else {
                    // a resident request finishes: slot + lease free
                    leases.pop();
                }
                // admission pass, oldest first
                while leases.len() < SLOTS {
                    match sched.next_admission(SLOTS - leases.len(), &pool, SLOT_BYTES) {
                        Some(r) => {
                            admitted.push(r.id);
                            leases.push(pool.reserve(SLOT_BYTES).unwrap());
                        }
                        None => break,
                    }
                }
            }
            for (i, &id) in admitted.iter().enumerate() {
                if id != i as u64 {
                    return Err(format!("admission out of arrival order: {admitted:?}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// speculative continuous batching (draft-and-verify iterations)

/// Run a mixed-length, slot-churning workload (12 requests over an
/// 8-row arena, staggered max_tokens) through a server and collect the
/// responses in submission order.
fn churn_workload(server: &Arc<Server>) -> Vec<nbl::server::GenResponse> {
    let handle = server.clone().spawn();
    let rxs: Vec<_> = (0..12u64)
        .map(|i| {
            let p = "the small robot walked around "[..(10 + (i as usize % 4) * 5)].to_string();
            handle.submit(req(i, &p, 6 + (i as usize % 3) * 8))
        })
        .collect();
    let out: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    handle.shutdown();
    out
}

#[test]
fn speculative_continuous_matches_plain_continuous() {
    // token-for-token parity under mixed prompt lengths and slot reuse,
    // for both a perfect draft (full-accept + bonus + draft catch-up
    // path) and a degraded draft (constant rejections + rollback at the
    // acceptance boundary). Exactness must not depend on draft quality.
    let engine = Arc::new(engine("main"));
    let plain = Arc::new(Server::new(engine.clone(), ServerConfig::default()));
    let want = churn_workload(&plain);
    for r in &want {
        assert!(r.error.is_none(), "{:?}", r.error);
    }

    let n_layers = engine.config().n_layers;
    let perfect = nbl::nbl::plan::ModelPlan::baseline(n_layers);
    let mut degraded = nbl::nbl::plan::ModelPlan::baseline(n_layers);
    degraded.drop_attn(1);
    degraded.drop_attn(3);

    for (label, draft_plan) in [("perfect", perfect), ("degraded", degraded)] {
        let cfg = ServerConfig {
            spec: Some(SpecConfig { draft_plan, width: 4 }),
            ..ServerConfig::default()
        };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let metrics = server.metrics.clone();
        let got = churn_workload(&server);
        let mut total_tokens = 0usize;
        for (g, w) in got.iter().zip(&want) {
            assert!(g.error.is_none(), "[{label}] {:?}", g.error);
            assert_eq!(
                g.tokens, w.tokens,
                "[{label} draft] speculative serving diverged from plain \
                 continuous on request {}",
                w.id
            );
            total_tokens += g.tokens.len();
        }
        let g = metrics.gauges();
        assert!(g.spec_rounds > 0, "[{label}] no speculative rounds ran");
        assert!(g.spec_proposed > 0, "[{label}] draft proposed nothing");
        assert!(
            g.spec_accepted <= g.spec_proposed,
            "[{label}] accounting: accepted {} > proposed {}",
            g.spec_accepted,
            g.spec_proposed
        );
        // every served token is either the admission prefill token or a
        // committed decode token — the gauge must account for all of
        // them. (Holds because this workload never finishes a request on
        // its prefill token: max_tokens >= 6 and no eos is configured;
        // such a request would serve 1 token without ever being
        // admitted.)
        assert_eq!(
            g.committed_tokens as usize + g.admissions as usize,
            total_tokens,
            "[{label}] committed_tokens + admissions must equal served tokens"
        );
        if label == "perfect" {
            // a draft that IS the target proposes exactly the target's
            // greedy continuation. Mid-stream everything is accepted;
            // the aggregate rate still sits well below 1.0 because each
            // request's final verify round discards its outstanding
            // proposals when the budget hits (structural waste, not a
            // protocol bug), so assert a margin that cleanly separates
            // it from a genuinely diverging draft without flaking.
            assert!(
                g.acceptance_rate() > 0.55,
                "perfect draft must be accepted at a high rate: {}",
                g.acceptance_rate()
            );
            assert!(
                g.tokens_per_row_iteration() > 1.5,
                "speculation must batch commits: {:.2} tokens/row-iteration",
                g.tokens_per_row_iteration()
            );
        } else {
            // dropped-attention draft diverges: rollback at the
            // acceptance boundary must have been exercised
            assert!(
                g.spec_accepted < g.spec_proposed,
                "degraded draft should see rejections (rollback path): \
                 {}/{} accepted",
                g.spec_accepted,
                g.spec_proposed
            );
        }
    }
}

#[test]
fn speculative_server_solo_request_matches_generate_one() {
    // the simplest end-to-end check: one request, spec on, equals the
    // synchronous batch-1 protocol token-for-token. The absurd width
    // must snap onto the AOT cached-lens grid instead of erroring every
    // iteration (regression).
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(7, "the quiet river ", 24));
    assert!(solo.error.is_none());
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    let cfg = ServerConfig {
        spec: Some(SpecConfig { draft_plan, width: 999 }),
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let handle = server.clone().spawn();
    let r = handle.submit(req(7, "the quiet river ", 24)).recv().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens, solo.tokens, "spec solo diverged");
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// bugfix regressions (ISSUE 2 satellites)

#[test]
fn exact_length_ttft_includes_queue_wait() {
    // regression: ExactLength used to start the TTFT clock at group
    // formation, under-reporting queue wait. B (different prompt length,
    // forced into a second group) is served only after A's group runs to
    // completion, so B's TTFT must cover A's whole service time.
    let cfg = ServerConfig { mode: BatchMode::ExactLength, ..ServerConfig::default() };
    let server = Arc::new(Server::new(Arc::new(engine("main")), cfg));
    let handle = server.clone().spawn();
    let rx_a = handle.submit(req(1, "the small robot ", 64));
    let rx_b = handle.submit(req(2, "a hidden garden of light ", 2));
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    assert!(
        b.ttft_ms >= 0.5 * a.total_ms,
        "ExactLength TTFT must include queue wait: B waited through A's \
         service ({:.1} ms) but reported TTFT {:.1} ms",
        a.total_ms,
        b.ttft_ms
    );
    handle.shutdown();
}

#[test]
fn continuous_ttft_includes_queue_wait() {
    // one-slot KV budget: B queues until A finishes, and B's TTFT must
    // say so (regression for the silently-restarted stopwatch fallback)
    let engine = Arc::new(engine("main"));
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    let cfg = ServerConfig { kv_capacity_bytes: per_slot, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let handle = server.clone().spawn();
    let rx_a = handle.submit(req(1, "the small robot ", 64));
    let rx_b = handle.submit(req(2, "a hidden garden of light ", 2));
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    assert!(
        b.ttft_ms >= 0.5 * a.total_ms,
        "continuous TTFT must include KV-queue wait: A served {:.1} ms, \
         B reported TTFT {:.1} ms",
        a.total_ms,
        b.ttft_ms
    );
    handle.shutdown();
}

#[test]
fn context_boundary_generates_every_fitting_token() {
    // regression: clamping to max_ctx - len silently dropped the last
    // generable token. A prompt of length L supports max_ctx - L + 1
    // outputs (prefill token + one per decode write).
    let engine = Arc::new(engine("main"));
    let max_ctx = engine.config().max_ctx;
    let prompt_len = max_ctx - 12;
    let prompt = "a".repeat(prompt_len);
    let budget = max_ctx - prompt_len + 1; // 13
    let want = {
        // synchronous batch-1 protocol (run_group)
        let server = Server::new(engine.clone(), ServerConfig::default());
        let r = server.generate_one(&req(1, &prompt, 1000));
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(
            r.tokens.len(),
            budget,
            "run_group must generate to context exhaustion"
        );
        r.tokens
    };
    // continuous worker, plain and speculative (the spec path must step
    // its width down near the boundary instead of overflowing)
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    for spec in [None, Some(SpecConfig { draft_plan, width: 4 })] {
        let label = if spec.is_some() { "spec" } else { "plain" };
        let cfg = ServerConfig { spec, ..ServerConfig::default() };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let handle = server.clone().spawn();
        let r = handle.submit(req(1, &prompt, 1000)).recv().unwrap();
        assert!(r.error.is_none(), "[{label}] {:?}", r.error);
        assert_eq!(
            r.tokens.len(),
            budget,
            "[{label}] continuous worker must generate to context exhaustion"
        );
        assert_eq!(r.tokens, want, "[{label}] boundary tokens diverged");
        handle.shutdown();
    }
}

#[test]
fn oversized_batch_returns_shape_error() {
    // regression: an oversized decode used to trip a debug_assert (or
    // mis-slice in release) instead of failing with Error::Shape
    let engine = engine("main");
    let plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    let mut state = nbl::kvcache::KvState::empty(&plan, engine.config(), 16, 8);
    let ids = vec![0u32; 16];
    match engine.decode(&mut state, &ids, 1) {
        Err(nbl::error::Error::Shape(_)) => {}
        other => panic!("oversized batch must fail with Error::Shape, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// chunked prefill (ISSUE 4: cache-appending chunks interleaved with decode)

/// ASCII text of exactly `len` byte-tokens from a repeating phrase.
fn long_text(len: usize) -> String {
    "the small robot walked around the garden and "
        .chars()
        .cycle()
        .take(len)
        .collect()
}

#[test]
fn engine_prefill_chunk_chain_matches_whole_prefill() {
    // token-level parity at the engine layer: a 150-token prompt (not
    // divisible by the 32-token chunk) prefilled as 32-token chunks +
    // a ragged tail must greedy-decode identically to one whole prefill
    let engine = engine("main");
    let cfg = engine.config();
    let plan = nbl::nbl::plan::ModelPlan::baseline(cfg.n_layers);
    let prompt = nbl::data::ByteTokenizer::new().encode(&long_text(150));
    let chunk = 32usize;

    let whole = engine.prefill(&prompt, 1, prompt.len(), None).unwrap();
    let mut whole_state = whole.state;
    let logits = engine.head(&whole.hidden).unwrap();
    let mut want = vec![nbl::sampling::argmax(logits.at2(0, prompt.len() - 1))];

    let mut state = nbl::kvcache::KvState::empty(&plan, cfg, 1, 1);
    let mut done = 0usize;
    let mut last = None;
    while done < prompt.len() {
        let step = chunk.min(prompt.len() - done);
        let hidden = engine
            .prefill_chunk(&mut state, &prompt[done..done + step], step)
            .unwrap();
        last = Some((hidden, step));
        done += step;
    }
    assert_eq!(state.pos, prompt.len(), "chunked state must land on the prompt length");
    let (hidden, tail) = last.expect("at least one chunk ran");
    let logits = engine.head(&hidden).unwrap();
    let mut got = vec![nbl::sampling::argmax(logits.at2(0, tail - 1))];

    // continue greedily through the cached path on BOTH states: every
    // chunk boundary the chain crossed must be invisible downstream
    for _ in 0..16 {
        let lw = engine.decode(&mut whole_state, &[*want.last().unwrap()], 1).unwrap();
        want.push(nbl::sampling::argmax(lw.at2(0, 0)));
        let lg = engine.decode(&mut state, &[*got.last().unwrap()], 1).unwrap();
        got.push(nbl::sampling::argmax(lg.at2(0, 0)));
    }
    assert_eq!(got, want, "chunked prefill diverged from whole prefill");
}

#[test]
fn chunked_continuous_matches_solo_under_churn() {
    // end-to-end parity: long prompts (crossing several chunk
    // boundaries, lengths not divisible by the chunk) mixed with shorts
    // through the chunked continuous worker must match the synchronous
    // whole-prefill protocol token for token — including admissions that
    // land mid-prefill (batch churn around the pending machine)
    let engine = Arc::new(engine("main"));
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let reqs = [
        req(1, &long_text(150), 10),
        req(2, "the bright engine ", 12),
        req(3, &long_text(97), 10),
        req(4, "ring ", 12),
        req(5, "a hidden garden of ", 12),
    ];
    let solo: Vec<_> = reqs.iter().map(|r| solo_server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }

    let cfg = ServerConfig { prefill_chunk: 32, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rxs: Vec<_> = reqs.iter().map(|r| handle.submit(r.clone())).collect();
    for (rx, s) in rxs.into_iter().zip(&solo) {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens, s.tokens, "chunked continuous decode diverged from solo");
    }
    let g = metrics.gauges();
    assert_eq!(g.admissions, 5);
    assert_eq!(g.chunked_admissions, 2, "both long prompts must chunk: {g:?}");
    // 150 -> 4x32 + 22-token tail = 5 chunks; 97 -> 3x32 + 1 = 4 chunks
    assert_eq!(g.prefill_chunks, 9, "chunk count must match the grid math: {g:?}");
    handle.shutdown();
}

#[test]
fn chunked_spec_continuous_matches_solo() {
    // chunked prefill composes with speculative serving: the draft
    // arena prefills the same chunks in lockstep, and outputs still
    // match the plain synchronous protocol exactly
    let engine = Arc::new(engine("main"));
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let reqs = [
        req(1, &long_text(140), 10),
        req(2, "the quiet river ", 12),
        req(3, "a hidden garden of ", 12),
    ];
    let solo: Vec<_> = reqs.iter().map(|r| solo_server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    let cfg = ServerConfig {
        prefill_chunk: 32,
        spec: Some(SpecConfig { draft_plan, width: 4 }),
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rxs: Vec<_> = reqs.iter().map(|r| handle.submit(r.clone())).collect();
    for (rx, s) in rxs.into_iter().zip(&solo) {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens, s.tokens, "chunked spec serving diverged from solo");
    }
    let g = metrics.gauges();
    assert_eq!(g.chunked_admissions, 1, "{g:?}");
    assert!(g.spec_rounds > 0, "speculation must still run: {g:?}");
    handle.shutdown();
}

#[test]
fn chunking_disabled_still_serves_long_prompts() {
    // prefill_chunk: 0 is the whole-prefill fallback rung — identical
    // outputs, zero chunk activity
    let engine = Arc::new(engine("main"));
    let r1 = req(1, &long_text(150), 8);
    let solo = Server::new(engine.clone(), ServerConfig::default()).generate_one(&r1);
    assert!(solo.error.is_none());
    let cfg = ServerConfig { prefill_chunk: 0, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let r = handle.submit(r1).recv().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens, solo.tokens);
    let g = metrics.gauges();
    assert_eq!(g.prefill_chunks, 0);
    assert_eq!(g.chunked_admissions, 0);
    handle.shutdown();
}

#[test]
fn chunked_ttft_starts_at_submission_and_spans_chunks() {
    // ISSUE 4 bugfix regression: the first token of a chunked admission
    // arrives N iterations after admission began, and the stopwatch must
    // keep running from SUBMISSION through all of them. With a one-slot
    // KV budget, B queues behind A's entire chunked service, so B's
    // TTFT must cover it — a restarted stopwatch would report near zero.
    let engine = Arc::new(engine("main"));
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    let cfg = ServerConfig {
        kv_capacity_bytes: per_slot,
        prefill_chunk: 32,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rx_a = handle.submit(req(1, &long_text(256), 32));
    let rx_b = handle.submit(req(2, "a hidden garden of light ", 2));
    let a = rx_a.recv().unwrap();
    let b = rx_b.recv().unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    assert_eq!(a.tokens.len(), 32);
    let g = metrics.gauges();
    assert_eq!(g.chunked_admissions, 1, "{g:?}");
    assert_eq!(g.prefill_chunks, 8, "256 tokens / 32-token chunks: {g:?}");
    // A's own TTFT spans its 8 chunk iterations: it cannot beat the
    // whole-prefill's share of total time by orders of magnitude
    assert!(a.ttft_ms > 0.0 && a.ttft_ms <= a.total_ms);
    assert!(
        b.ttft_ms >= 0.5 * a.total_ms,
        "chunked TTFT must include queue wait: A served {:.1} ms, \
         B reported TTFT {:.1} ms",
        a.total_ms,
        b.ttft_ms
    );
    handle.shutdown();
}

#[test]
fn chunk_stall_gauges_observe_decode_interference() {
    // a short request decodes while a long prompt chunks its way in:
    // the interference gauges must see chunks that ran with decode rows
    // live, and the short must be unaffected token-wise
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(7, "the quiet river ", 40));
    assert!(solo.error.is_none());
    let cfg = ServerConfig { prefill_chunk: 32, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rx_short = handle.submit(req(7, "the quiet river ", 40));
    let rx_long = handle.submit(req(8, &long_text(256), 8));
    let short = rx_short.recv().unwrap();
    let long = rx_long.recv().unwrap();
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, solo.tokens, "interleaved chunks must not disturb decode");
    let g = metrics.gauges();
    assert!(g.prefill_chunks >= 8, "{g:?}");
    assert!(
        g.chunk_stalls >= 1,
        "chunks ran while a row decoded; the stall gauge must see it: {g:?}"
    );
    assert!(g.chunk_stall_s > 0.0 && g.mean_chunk_stall_ms() > 0.0, "{g:?}");
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// prefix-aware KV reuse (ISSUE 5: radix-tree prompt cache + snapshot adoption)

#[test]
fn prefix_snapshot_restore_matches_cold_prefill() {
    // tentpole invariant at the engine layer: restore a snapshot taken
    // at a prefill boundary, prefill only the suffix, adopt the result
    // into an arena row at a NONZERO position, and greedy-decode —
    // token-identical to a cold whole-prompt prefill
    let engine = engine("main");
    let cfg = engine.config();
    let plan = nbl::nbl::plan::ModelPlan::baseline(cfg.n_layers);
    let prompt = nbl::data::ByteTokenizer::new().encode(&long_text(150));
    let cut = 96usize;

    // cold reference: whole prefill + batch-1 cached decode
    let cold = engine.prefill(&prompt, 1, prompt.len(), None).unwrap();
    let mut cold_state = cold.state;
    let logits = engine.head(&cold.hidden).unwrap();
    let mut want = vec![nbl::sampling::argmax(logits.at2(0, prompt.len() - 1))];

    // snapshot the first `cut` tokens out of a partial prefill
    let mut base = nbl::kvcache::KvState::empty(&plan, cfg, 1, 1);
    engine.prefill_chunk(&mut base, &prompt[..cut], cut).unwrap();
    let snap = nbl::kvcache::prefix::KvSnapshot::from_state(&base, cut).unwrap();
    assert!(snap.bytes() > 0);

    // warm path: restore + suffix-only prefill
    let mut state = snap.restore_state(&plan, cfg).unwrap();
    assert_eq!(state.pos, cut);
    let hidden = engine.prefill_suffix(&mut state, &prompt[cut..]).unwrap();
    assert_eq!(state.pos, prompt.len());
    let logits = engine.head(&hidden).unwrap();
    let mut got = vec![nbl::sampling::argmax(logits.at2(0, prompt.len() - cut - 1))];

    // adopt the warm state into an arena row mid-context and decode
    // through the continuous rows path against the cold KvState
    let mut arena = engine.new_arena(8).unwrap();
    arena.adopt(1, &state).unwrap();
    assert_eq!(arena.pos(1), Some(prompt.len()));
    for _ in 0..16 {
        let lw = engine.decode(&mut cold_state, &[*want.last().unwrap()], 1).unwrap();
        want.push(nbl::sampling::argmax(lw.at2(0, 0)));
        let rows = [nbl::executor::RowDecode { slot: 1, token: *got.last().unwrap() }];
        let lg = engine.decode_rows(&mut arena, &rows).unwrap();
        got.push(nbl::sampling::argmax(lg.at2(0, 0)));
    }
    assert_eq!(got, want, "prefix-adopted decode diverged from cold prefill");
}

#[test]
fn prefix_cache_serving_matches_cold_outputs() {
    // ISSUE 5 acceptance: greedy outputs token-identical with the prefix
    // cache on vs off, continuous AND spec modes, under slot churn with
    // heavily shared prefixes (10 requests, 8-row arena, staggered
    // max_tokens, one shared 96-token system prompt)
    let engine = Arc::new(engine("main"));
    let shared = long_text(96);
    let reqs: Vec<GenRequest> = (0..10u64)
        .map(|i| {
            let tail = format!(" case {i} of the garden walk tour");
            let take = 8 + (i as usize % 4) * 4;
            req(i, &format!("{shared}{}", &tail[..take]), 6 + (i as usize % 3) * 6)
        })
        .collect();
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let solo: Vec<_> = reqs.iter().map(|r| solo_server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    for (label, spec) in [("plain", None), ("spec", Some(SpecConfig { draft_plan, width: 4 }))] {
        let cfg = ServerConfig {
            prefix_cache_bytes: 32 << 20,
            prefill_chunk: 32,
            spec,
            ..ServerConfig::default()
        };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let metrics = server.metrics.clone();
        let handle = server.clone().spawn();
        let rxs: Vec<_> = reqs.iter().map(|r| handle.submit(r.clone())).collect();
        for (rx, s) in rxs.into_iter().zip(&solo) {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "[{label}] {:?}", r.error);
            assert_eq!(
                r.tokens, s.tokens,
                "[{label}] prefix-cached serving diverged from cold on request {}",
                s.id
            );
        }
        let g = metrics.gauges();
        assert_eq!(g.admissions, 10, "[{label}] {g:?}");
        assert!(g.prefix_inserts > 0, "[{label}] prefill must publish snapshots: {g:?}");
        assert!(g.prefix_hits > 0, "[{label}] shared prefixes must hit: {g:?}");
        assert!(g.prefix_hit_tokens > 0, "[{label}] {g:?}");
        assert!(g.prefix_hit_rate() > 0.0, "[{label}] {g:?}");
        assert!(g.prefix_bytes > 0, "[{label}] resident snapshots must be accounted: {g:?}");
        handle.shutdown();
    }
}

#[test]
fn prefix_warm_chunked_machine_matches_solo() {
    // a hit whose uncovered suffix still exceeds one chunk re-enters
    // the chunked machine mid-prompt (done = covered): outputs must
    // stay token-identical to cold solo serving AND the warm machines
    // must run fewer chunks than cold ones would
    let engine = Arc::new(engine("main"));
    let shared = long_text(64);
    // prompts share EXACTLY the first 64 tokens, then diverge (the
    // digit) before a long common-phrase suffix — the radix tree must
    // stop at the divergence, not match the phrase again
    let reqs: Vec<GenRequest> = (0..3u64)
        .map(|i| req(i, &format!("{shared}{i} {}", long_text(76)), 8))
        .collect();
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let solo: Vec<_> = reqs.iter().map(|r| solo_server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    let cfg = ServerConfig {
        prefix_cache_bytes: 32 << 20,
        prefill_chunk: 32,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rxs: Vec<_> = reqs.iter().map(|r| handle.submit(r.clone())).collect();
    for (rx, s) in rxs.into_iter().zip(&solo) {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens, s.tokens, "warm chunked machine diverged on request {}", s.id);
    }
    let g = metrics.gauges();
    // prompts are 142 tokens: cold chunks 5x (32+32+32+32+14); the two
    // warm machines adopt 64 tokens and chunk only 32+32+14
    assert_eq!(g.chunked_admissions, 3, "{g:?}");
    assert_eq!(g.prefix_hits, 2, "requests 2 and 3 must adopt the shared 64: {g:?}");
    assert_eq!(g.prefix_hit_tokens, 128, "{g:?}");
    assert_eq!(g.prefill_chunks, 5 + 3 + 3, "warm machines must skip covered chunks: {g:?}");
    handle.shutdown();
}

#[test]
fn warm_long_head_slips_past_running_machine() {
    // regression (PR 5 review): the machine guard classifies the queue
    // head by its cache-UNCOVERED suffix. A warm 139-token prompt whose
    // cached prefix leaves an 11-token suffix must admit whole between
    // a cold 256-token machine's chunks — NOT wait out all 8 of them —
    // and still decode token-identically to cold solo serving.
    let engine = Arc::new(engine("main"));
    let shared = long_text(128);
    let warm_req = req(3, &format!("{shared} extra bits"), 4);
    let solo = Server::new(engine.clone(), ServerConfig::default()).generate_one(&warm_req);
    assert!(solo.error.is_none());
    let cfg = ServerConfig {
        prefix_cache_bytes: 32 << 20,
        prefill_chunk: 32,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    // prime the tree with the shared prefix, then race a cold long
    // machine (distinct first byte -> no shared prefix) against the
    // warm head queued right behind it
    let p = handle.submit(req(1, &shared, 2)).recv().unwrap();
    assert!(p.error.is_none(), "{:?}", p.error);
    let rx_cold = handle.submit(req(2, &format!("q{}", long_text(255)), 8));
    let rx_warm = handle.submit(warm_req);
    let cold = rx_cold.recv().unwrap();
    let warm = rx_warm.recv().unwrap();
    assert!(cold.error.is_none() && warm.error.is_none());
    assert_eq!(warm.tokens, solo.tokens, "slipped warm admission diverged");
    let g = metrics.gauges();
    assert_eq!(g.prefix_hits, 1, "the warm head must adopt the primed prefix: {g:?}");
    assert!(
        warm.ttft_ms < 0.75 * cold.ttft_ms,
        "a warm head (11-token suffix) must not wait out the cold machine's \
         8 chunks: warm TTFT {:.1} ms vs cold TTFT {:.1} ms",
        warm.ttft_ms,
        cold.ttft_ms
    );
    handle.shutdown();
}

#[test]
fn prefix_cache_disabled_reports_zero_gauges() {
    // prefix_cache_bytes: 0 (the default) must leave the serving path
    // untouched: no probes, no inserts, no budget
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let r = handle.submit(req(1, &long_text(96), 8)).recv().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let g = metrics.gauges();
    assert_eq!(g.prefix_hits + g.prefix_misses, 0);
    assert_eq!(g.prefix_inserts, 0);
    assert_eq!(g.prefix_capacity_bytes, 0);
    handle.shutdown();
}

// ---------------------------------------------------------------------------
// paged KV (ISSUE 6: block-pool cache, zero-copy prefix sharing,
// copy-on-write, preemptive scheduling)

#[test]
fn paged_preemption_round_trip_matches_plain_continuous() {
    // acceptance: under a 4-block budget, three short admissions fill
    // the pool and the first request to cross a 16-token block boundary
    // must evict the youngest resident (LIFO). The victim's row caches
    // snapshot to host, it re-admits when blocks free up, and every
    // token stream still matches an unconstrained plain server exactly.
    let engine = Arc::new(engine("main"));
    let plain = Arc::new(Server::new(engine.clone(), ServerConfig::default()));
    let want = churn_workload(&plain);
    for r in &want {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let bt = 16usize;
    let t_bpb = nbl::kvcache::kv_bytes(engine.config(), engine.plan.kv_layers(), 1, bt, 4);
    let cfg = ServerConfig {
        kv_block_tokens: bt,
        kv_capacity_bytes: 4 * t_bpb,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let got = churn_workload(&server);
    for (g, w) in got.iter().zip(&want) {
        assert!(g.error.is_none(), "{:?}", g.error);
        assert_eq!(
            g.tokens, w.tokens,
            "request {} diverged across preempt/re-admit",
            w.id
        );
    }
    let g = metrics.gauges();
    assert!(
        g.preemptions >= 1,
        "a 4-block budget under 12-request churn must force eviction: {g:?}"
    );
    // every preemption re-admits exactly once (all 12 requests finished)
    assert_eq!(
        g.admissions,
        12 + g.preemptions,
        "admissions must count initial admits plus resumes: {g:?}"
    );
    assert!(g.blocks_capacity > 0 && g.paged_block_tokens == bt, "{g:?}");
}

#[test]
fn paged_preemption_round_trip_matches_plain_spec() {
    // the same round trip under speculative serving: preemption must
    // snapshot BOTH arenas' rows between verify rounds and resume them
    // in lockstep, with outputs still equal to the plain server's.
    let engine = Arc::new(engine("main"));
    let plain = Arc::new(Server::new(engine.clone(), ServerConfig::default()));
    let want = churn_workload(&plain);
    for r in &want {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    let bt = 16usize;
    let t_bpb = nbl::kvcache::kv_bytes(engine.config(), engine.plan.kv_layers(), 1, bt, 4);
    let d_bpb = nbl::kvcache::kv_bytes(engine.config(), draft_plan.kv_layers(), 1, bt, 4);
    let cfg = ServerConfig {
        kv_block_tokens: bt,
        kv_capacity_bytes: 4 * t_bpb + 4 * d_bpb,
        spec: Some(SpecConfig { draft_plan, width: 4 }),
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let got = churn_workload(&server);
    for (g, w) in got.iter().zip(&want) {
        assert!(g.error.is_none(), "{:?}", g.error);
        assert_eq!(
            g.tokens, w.tokens,
            "[spec] request {} diverged across preempt/re-admit",
            w.id
        );
    }
    let g = metrics.gauges();
    assert!(g.spec_rounds > 0, "speculation must still run: {g:?}");
    assert!(
        g.preemptions >= 1,
        "[spec] the block budget must force eviction: {g:?}"
    );
    assert_eq!(g.admissions, 12 + g.preemptions, "{g:?}");
}

#[test]
fn paged_admission_outlives_contiguous_under_one_budget() {
    // tentpole acceptance: under an IDENTICAL KV byte budget (two
    // contiguous slots' worth), block-granular admission must hold
    // strictly more concurrent rows than worst-case contiguous
    // admission — short requests charge one block, not max_ctx.
    let engine = Arc::new(engine("main"));
    let per_slot = nbl::kvcache::slot_bytes(engine.config(), &engine.plan);
    let budget = 2 * per_slot;
    let run = |kv_block_tokens: usize| {
        let cfg = ServerConfig {
            kv_capacity_bytes: budget,
            kv_block_tokens,
            ..ServerConfig::default()
        };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let metrics = server.metrics.clone();
        let handle = server.clone().spawn();
        let rxs: Vec<_> = (0..8u64)
            .map(|i| handle.submit(req(i, "the small robot ", 8)))
            .collect();
        let out: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        handle.shutdown();
        (out, metrics.gauges())
    };
    let (cont, cg) = run(0);
    let (paged, pg) = run(32);
    for (c, p) in cont.iter().zip(&paged) {
        assert!(c.error.is_none(), "{:?}", c.error);
        assert!(p.error.is_none(), "{:?}", p.error);
        assert_eq!(p.tokens, c.tokens, "paged admission changed outputs");
    }
    assert!(cg.peak_rows <= 2, "the budget holds exactly two contiguous slots: {cg:?}");
    assert!(
        pg.peak_rows > cg.peak_rows,
        "paged admission must hold strictly more concurrent rows under the \
         same budget: paged {} vs contiguous {}",
        pg.peak_rows,
        cg.peak_rows
    );
    assert!(pg.blocks_capacity > 0 && pg.paged_block_tokens == 32, "{pg:?}");
    assert_eq!(pg.preemptions, 0, "one-block rows must coexist without eviction: {pg:?}");
}

#[test]
fn paged_prefix_adoption_is_zero_copy() {
    // tentpole acceptance: a warm admission under the block pool
    // splices cache-resident blocks into its table — ZERO per-layer
    // snapshot expansion copies (the gauge that counts them stays 0),
    // exactly one splice, copy-on-write only for the partial tail
    // block — and still decodes token-identically to cold serving.
    // Also the ISSUE 6 small fix: re-publishing a boundary whose block
    // run is already resident must skip (and gauge the skip).
    let engine = Arc::new(engine("main"));
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let a = req(1, &long_text(100), 8);
    let b = req(2, &format!("{}zq marble atrium run", long_text(64)), 8);
    let c = req(3, &long_text(64), 4);
    let solo: Vec<_> = [&a, &b, &c].iter().map(|r| solo_server.generate_one(r)).collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    let cfg = ServerConfig {
        prefix_cache_bytes: 32 << 20,
        // chunking off so the snap stays EXACTLY 64 (chunking would
        // align it up to the chunk size and move the boundary)
        prefill_chunk: 0,
        prefix_snap: 64,
        // 48-token blocks: the adopted 64-token run is one full shared
        // block plus a 16-token partial tail that must copy-on-write
        kv_block_tokens: 48,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    // strictly sequential: A publishes the 64-token boundary, B adopts
    // it as a block splice, C (EXACTLY the boundary, below the probe
    // cap) prefills cold and its publication must hit the resident run
    for (r, s) in [(a, &solo[0]), (b, &solo[1]), (c, &solo[2])] {
        let got = handle.submit(r).recv().unwrap();
        assert!(got.error.is_none(), "{:?}", got.error);
        assert_eq!(got.tokens, s.tokens, "paged-warm serving diverged from cold");
    }
    handle.shutdown();
    let g = metrics.gauges();
    assert_eq!(g.prefix_inserts, 1, "only A publishes a new run: {g:?}");
    assert_eq!(g.prefix_hits, 1, "B must adopt the published 64-token run: {g:?}");
    assert_eq!(g.paged_splices, 1, "{g:?}");
    assert_eq!(g.paged_splice_tokens, 64, "{g:?}");
    assert_eq!(g.cow_copies, 1, "the 16-token tail copies on write, nothing else: {g:?}");
    assert_eq!(
        g.prefix_expand_copies, 0,
        "a paged splice must never expand host snapshots: {g:?}"
    );
    assert!(
        g.prefix_publish_skips >= 1,
        "C re-publishing the resident 64-run must skip: {g:?}"
    );
}

#[test]
fn paged_block_accounting_returns_to_zero_after_churn() {
    // invariant: the pool's reserved bytes always equal the private
    // frames the tables hold, through arbitrary attach/grow/release/
    // preempt churn, and return to exactly zero when every table drops
    check(
        0xB10C5,
        30,
        |g| {
            let n = g.size(80);
            (0..n)
                .map(|_| (g.usize_in(0, 3), g.usize_in(0, 7), g.usize_in(1, 64)))
                .collect::<Vec<(usize, usize, usize)>>()
        },
        |ops| {
            const BPB: usize = 100;
            let pool = Arc::new(KvPool::new(16 * BPB));
            let mut pk = nbl::kvcache::paged::PagedKv::new(8, BPB, 0, pool.clone(), 8);
            for &(kind, slot, tokens) in ops {
                match kind {
                    0 => {
                        let _ = pk.attach(slot, tokens, None);
                    }
                    1 => {
                        pk.grow(slot, tokens, None);
                    }
                    2 => pk.release(slot),
                    _ => pk.preempt(slot),
                }
                let s = pk.stats();
                if pool.in_use() != s.used_blocks * BPB {
                    return Err(format!(
                        "accounting drift: pool holds {} bytes, tables hold {} private blocks",
                        pool.in_use(),
                        s.used_blocks
                    ));
                }
            }
            for slot in 0..8 {
                pk.release(slot);
            }
            if pool.in_use() != 0 {
                return Err(format!("leaked {} bytes after churn", pool.in_use()));
            }
            Ok(())
        },
    );
}

#[test]
fn paged_accounting_survives_error_injection_churn() {
    // error-injection extension of the churn invariant above: every
    // FAILED attach/grow (slot already attached, slot out of range,
    // ask over budget) and every mid-sequence preemption must leave
    // the pool identity intact — pool bytes equal private frames times
    // bytes-per-block, on both the target and draft sides. An
    // independent naive oracle predicts each op's outcome, so an op
    // that "fails" but still moves the pool (or succeeds when it
    // should not have) is caught at the op that broke it.
    check(
        0xE44012,
        30,
        |g| {
            let n = g.size(80);
            (0..n)
                .map(|_| {
                    (
                        g.usize_in(0, 4),
                        // slot 8 is out of range on an 8-row table:
                        // deliberate error injection
                        g.usize_in(0, 8),
                        g.usize_in(1, 64),
                        g.usize_in(1, 48),
                    )
                })
                .collect::<Vec<(usize, usize, usize, usize)>>()
        },
        |ops| {
            const BT: usize = 4; // block size in tokens
            const BPB: usize = 100; // same both sides: used_blocks * BPB stays exact
            const CAP: usize = 24 * BPB;
            let blocks = |tokens: usize| (tokens + BT - 1) / BT;
            let pool = Arc::new(KvPool::new(CAP));
            let mut pk = nbl::kvcache::paged::PagedKv::new(BT, BPB, BPB, pool.clone(), 8);
            // oracle state: (target frames, draft frames, target tokens,
            // draft tokens) per attached slot
            let mut model: [Option<(usize, usize, usize, usize)>; 8] = Default::default();
            let held = |m: &[Option<(usize, usize, usize, usize)>; 8]| -> usize {
                m.iter().flatten().map(|&(tf, df, _, _)| (tf + df) * BPB).sum()
            };
            for &(kind, slot, t, d) in ops {
                match kind {
                    0 | 4 => {
                        // kind 4 inflates the ask so over-budget attach
                        // failures are common, not incidental
                        let t = if kind == 4 { t * 8 } else { t };
                        let want = match model.get(slot) {
                            Some(None) => {
                                let bytes = (blocks(t) + blocks(d)) * BPB;
                                pool.in_use() + bytes <= CAP
                            }
                            _ => false, // already attached or out of range
                        };
                        let got = pk.attach(slot, t, Some(d)).is_ok();
                        if got != want {
                            return Err(format!("attach({slot},{t},{d}) ok={got}, oracle {want}"));
                        }
                        if got {
                            model[slot] = Some((blocks(t), blocks(d), t, d));
                        }
                    }
                    1 => {
                        let want = match model.get(slot) {
                            Some(Some((tf, df, tt, dt))) => {
                                let t_new = blocks(t.max(*tt)).saturating_sub(*tf);
                                let d_new = blocks(d.max(*dt)).saturating_sub(*df);
                                if pool.in_use() + (t_new + d_new) * BPB <= CAP {
                                    Some((tf + t_new, df + d_new, t.max(*tt), d.max(*dt)))
                                } else {
                                    None
                                }
                            }
                            _ => None, // unattached or out of range
                        };
                        let got = pk.grow(slot, t, Some(d));
                        if got != want.is_some() {
                            return Err(format!(
                                "grow({slot},{t},{d}) ok={got}, oracle {}",
                                want.is_some()
                            ));
                        }
                        if let Some(next) = want {
                            model[slot] = Some(next);
                        }
                    }
                    2 => {
                        pk.release(slot);
                        if let Some(m) = model.get_mut(slot) {
                            *m = None;
                        }
                    }
                    _ => {
                        pk.preempt(slot);
                        if let Some(m) = model.get_mut(slot) {
                            *m = None;
                        }
                    }
                }
                let s = pk.stats();
                if pool.in_use() != s.used_blocks * BPB || pool.in_use() != held(&model) {
                    return Err(format!(
                        "identity broken after kind {kind} on slot {slot}: pool {} bytes, \
                         tables {} blocks, oracle {} bytes",
                        pool.in_use(),
                        s.used_blocks,
                        held(&model)
                    ));
                }
            }
            for slot in 0..8 {
                pk.release(slot);
            }
            if pool.in_use() != 0 {
                return Err(format!("leaked {} bytes after churn", pool.in_use()));
            }
            Ok(())
        },
    );
}

#[test]
fn kv_pool_accounting_returns_to_zero_after_churn() {
    // invariant: reserved bytes always equal the sum of live leases, and
    // return to exactly zero after arbitrary join/leave churn
    check(
        0xBADCAB,
        30,
        |g| {
            let n = g.size(60);
            (0..n)
                .map(|_| (g.usize_in(0, 1), g.usize_in(1, 64)))
                .collect::<Vec<(usize, usize)>>()
        },
        |ops| {
            let pool = Arc::new(KvPool::new(1 << 14));
            let mut held = Vec::new();
            for &(kind, x) in ops {
                if kind == 0 {
                    if let Ok(l) = KvPool::reserve_owned(&pool, x * 64) {
                        held.push(l);
                    }
                } else if !held.is_empty() {
                    held.swap_remove(x % held.len());
                }
                let live: usize = held.iter().map(|l| l.bytes()).sum();
                if pool.in_use() != live {
                    return Err(format!(
                        "accounting drift: pool says {}, leases hold {live}",
                        pool.in_use()
                    ));
                }
            }
            held.clear();
            if pool.in_use() != 0 {
                return Err(format!("leaked {} bytes after churn", pool.in_use()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// observability: flight recorder + TTFT attribution (DESIGN.md §Observability)

#[test]
fn ttft_attribution_sums_to_ttft() {
    // acceptance: queue + prefill + stall must reconstruct TTFT within
    // 1% for EVERY request (park is lifetime parking, excluded — a
    // request preempted after its first token still has exact TTFT
    // attribution). The identity holds by construction in
    // Stopwatch::finish; this guards the wiring: a phase that stops
    // feeding its stopwatch shows up as attribution drift here.
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let got = churn_workload(&server);
    for r in &got {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let timings = metrics.timings();
    assert_eq!(timings.len(), 12);
    for t in &timings {
        let parts = t.queue_s + t.prefill_s + t.stall_s;
        let tol = (t.ttft_s * 0.01).max(1e-9);
        assert!(
            (parts - t.ttft_s).abs() <= tol,
            "attribution drifted: queue {} + prefill {} + stall {} = {parts} \
             vs ttft {}",
            t.queue_s,
            t.prefill_s,
            t.stall_s,
            t.ttft_s
        );
        assert!(t.queue_s >= 0.0 && t.prefill_s >= 0.0 && t.stall_s >= 0.0 && t.park_s >= 0.0);
        // prefill work really happened and was charged somewhere
        assert!(t.ttft_s > 0.0);
    }
    // the summary's streaming-histogram percentiles see the same data
    let s = metrics.summary();
    assert!(s.mean_queue_s >= 0.0 && s.mean_prefill_s > 0.0);
}

#[test]
fn trace_records_span_families_through_server() {
    // a real mixed workload through the worker with the recorder on:
    // the export must be balanced per lane, time-ordered, and contain
    // the lifecycle families every request passes through
    let cfg = ServerConfig { trace_events: 4096, ..ServerConfig::default() };
    let server = Arc::new(Server::new(Arc::new(engine("main")), cfg));
    let trace = server.trace.clone();
    let got = churn_workload(&server);
    for r in &got {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let st = trace.stats();
    assert!(st.recorded > 0, "recorder saw no events");
    assert_eq!(st.capacity, 4096);

    let j = trace.export_chrome();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
    assert!(!events.is_empty());
    let mut last_ts = f64::NEG_INFINITY;
    let mut stacks: std::collections::BTreeMap<(usize, usize), Vec<String>> = Default::default();
    let mut seen = std::collections::BTreeSet::new();
    for ev in &events {
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "export must be time-ordered");
        last_ts = ts;
        let name = ev.get("name").unwrap().as_str().unwrap().to_string();
        let lane = (
            ev.get("pid").unwrap().as_usize().unwrap(),
            ev.get("tid").unwrap().as_usize().unwrap(),
        );
        match ev.get("ph").unwrap().as_str().unwrap() {
            "B" => {
                stacks.entry(lane).or_default().push(name.clone());
                seen.insert(name);
            }
            "E" => {
                let top = stacks.entry(lane).or_default().pop();
                assert_eq!(top.as_deref(), Some(name.as_str()), "spans must nest per lane");
            }
            "i" => {
                seen.insert(name);
            }
            ph => panic!("unexpected ph {ph:?}"),
        }
    }
    for (lane, stack) in &stacks {
        assert!(stack.is_empty(), "lane {lane:?} left open spans {stack:?}");
    }
    for want in ["submit", "queue", "decode", "finish", "intake", "admission"] {
        assert!(seen.contains(want), "missing '{want}' events; saw {seen:?}");
    }
}

#[test]
fn trace_disabled_is_inert_through_server() {
    // trace_events = 0 (the default) must record nothing — the hot path
    // stays a branch on a plain field, and the export is empty
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let trace = server.trace.clone();
    let got = churn_workload(&server);
    for r in &got {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let st = trace.stats();
    assert_eq!((st.capacity, st.recorded, st.dropped), (0, 0, 0));
    let j = trace.export_chrome();
    assert!(j.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
}

#[test]
fn timing_retention_bounds_raw_samples_through_server() {
    // bounded MetricsHub: with a 4-sample retention window, a 12-request
    // workload keeps only the 4 newest raw timings and counts the rest
    // dropped — while the lifetime histograms still summarize all 12
    let cfg = ServerConfig { timing_retention: 4, ..ServerConfig::default() };
    let server = Arc::new(Server::new(Arc::new(engine("main")), cfg));
    let metrics = server.metrics.clone();
    let got = churn_workload(&server);
    for r in &got {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    assert_eq!(metrics.timings().len(), 4);
    let s = metrics.summary();
    assert_eq!(s.requests, 12, "lifetime counters must survive the window");
    assert_eq!(s.timings_retained, 4);
    assert_eq!(s.timings_dropped, 8);
    assert_eq!(s.timings_capacity, 4);
    assert!(s.mean_ttft_s > 0.0, "histogram summaries cover all requests");
}

// ---------------------------------------------------------------------------
// streaming front end (ISSUE 9: per-token sinks, cancellation, deadlines,
// weighted-fair intake — DESIGN.md §Streaming front end)

#[test]
fn streamed_tokens_match_one_shot_reply_exactly() {
    // tentpole acceptance: the per-token sink is a byte-exact view of
    // the one-shot reply — same tokens, dense 0-based indices — in
    // plain AND speculative continuous serving, with non-streaming
    // traffic interleaved on the same worker.
    let engine = Arc::new(engine("main"));
    let solo_server = Server::new(engine.clone(), ServerConfig::default());
    let prompts = ["the small robot ", "a hidden garden of ", "ring ", "the quiet river "];
    let solo: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| solo_server.generate_one(&req(i as u64, p, 16)))
        .collect();
    for s in &solo {
        assert!(s.error.is_none(), "{:?}", s.error);
    }
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    for (label, spec) in [("plain", None), ("spec", Some(SpecConfig { draft_plan, width: 4 }))] {
        let cfg = ServerConfig { spec, ..ServerConfig::default() };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let handle = server.clone().spawn();
        // even ids stream, odd ids use the one-shot path, concurrently
        let mut sinks = Vec::new();
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                if i % 2 == 0 {
                    let (tx, srx) = mpsc::channel();
                    sinks.push((i, srx));
                    handle.submit_streaming(stream_req(i as u64, p, 16), tx)
                } else {
                    handle.submit(req(i as u64, p, 16))
                }
            })
            .collect();
        let got: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        for (g, s) in got.iter().zip(&solo) {
            assert!(g.error.is_none(), "[{label}] {:?}", g.error);
            assert_eq!(g.tokens, s.tokens, "[{label}] request {} diverged", s.id);
        }
        for (i, srx) in &sinks {
            let streamed = drain_sink(*i as u64, srx);
            assert_eq!(
                &streamed, &got[*i].tokens,
                "[{label}] the sink for request {i} must carry every committed token"
            );
        }
        handle.shutdown();
    }
}

#[test]
fn tcp_streaming_round_trip() {
    // wire-level framing: a {"stream":true} request gets dense token
    // frames then exactly one "done" terminal carrying the full
    // one-shot body, and the same connection still serves the legacy
    // protocol afterwards (the idle read cadence is restored)
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let front = TcpFrontend::start(server, "127.0.0.1:0").unwrap();
    let mut conn = std::net::TcpStream::connect(front.addr).unwrap();
    writeln!(
        conn,
        r#"{{"id": 3, "prompt": "the quiet river ", "max_tokens": 6, "stream": true}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut tokens = Vec::new();
    let done = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = nbl::util::json::Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 3);
        let frame = j.get("frame").unwrap().as_str().unwrap().to_string();
        if frame == "token" {
            assert_eq!(
                j.get("index").unwrap().as_usize().unwrap(),
                tokens.len(),
                "token frames must arrive dense and in order"
            );
            tokens.push(j.get("token").unwrap().as_usize().unwrap());
        } else {
            break j;
        }
    };
    assert_eq!(done.get("frame").unwrap().as_str().unwrap(), "done");
    let body: Vec<usize> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(tokens.len(), 6);
    assert_eq!(tokens, body, "token frames must reassemble the one-shot body");
    writeln!(conn, r#"{{"id": 4, "prompt": "the quiet river ", "max_tokens": 4}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = nbl::util::json::Json::parse(&line).unwrap();
    assert!(j.opt("frame").is_none(), "one-shot replies carry no frame tag");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    front.shutdown();
}

#[test]
fn cancel_mid_decode_frees_the_slot_for_a_queued_request() {
    // acceptance: with a one-row arena, B queues behind a long-running
    // A. Cancelling A mid-decode must answer A with the typed error,
    // free row 0 within one iteration, and admit B into the SAME row
    // (the slot-reuse gauge sees it) — with the KV pool back to zero.
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(2, "a hidden garden of ", 8));
    assert!(solo.error.is_none());
    let cfg = ServerConfig { max_batch: 1, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let pool = server.pool.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(stream_req(1, "the small robot ", 400), sink);
    // A is mid-decode once its first committed token hits the sink
    let first = srx.recv().expect("A must stream its first token");
    assert_eq!((first.id, first.index), (1, 0));
    let rx_b = handle.submit(req(2, "a hidden garden of ", 8));
    handle.cancel(1);
    let a = rx_a.recv().unwrap();
    assert!(
        a.error.as_deref().is_some_and(|e| e.contains("cancelled")),
        "cancel must answer with the typed error: {:?}",
        a.error
    );
    let b = rx_b.recv().unwrap();
    assert!(b.error.is_none(), "{:?}", b.error);
    assert_eq!(b.tokens, solo.tokens, "the admitted-after-cancel request diverged");
    let g = metrics.gauges();
    assert_eq!(g.cancelled, 1, "{g:?}");
    assert!(g.slot_reuses >= 1, "B must admit into the row the cancel freed: {g:?}");
    handle.shutdown();
    assert_eq!(pool.in_use(), 0, "cancel leaked KV pool bytes");
}

#[test]
fn cancel_during_chunked_prefill_releases_the_reservation() {
    // a near-max-context prompt chunks its way in over ~14 iterations;
    // a cancel sent after the second chunk lands mid-machine, so the
    // reserved row and its KV lease must come back without the prompt
    // ever producing a token — and the worker keeps serving afterwards
    let engine = Arc::new(engine("main"));
    let max_ctx = engine.config().max_ctx;
    let prompt = long_text(max_ctx - 64);
    let cfg = ServerConfig { prefill_chunk: 32, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let pool = server.pool.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(stream_req(1, &prompt, 16), sink);
    let t0 = std::time::Instant::now();
    while metrics.gauges().prefill_chunks < 2 {
        assert!(t0.elapsed().as_secs() < 60, "chunked machine never started");
        std::thread::yield_now();
    }
    handle.cancel(1);
    let a = rx_a.recv().unwrap();
    assert!(
        a.error.as_deref().is_some_and(|e| e.contains("cancelled")),
        "{:?}",
        a.error
    );
    assert!(
        srx.try_recv().is_err(),
        "the cancel landed mid-prefill: no token can have streamed"
    );
    let b = handle.submit(req(2, "the small robot ", 8)).recv().unwrap();
    assert!(b.error.is_none(), "the worker must keep serving after the teardown");
    assert_eq!(metrics.gauges().cancelled, 1);
    handle.shutdown();
    assert_eq!(pool.in_use(), 0, "a cancelled machine leaked its reservation");
}

#[test]
fn cancel_in_spec_lockstep_releases_both_arenas() {
    // cancelling between verify rounds must release the target row AND
    // its lockstep draft row: the shared pool drops to zero bytes the
    // moment the cancel is answered, and the next request decodes
    // token-identically to the plain protocol on the same row
    let engine = Arc::new(engine("main"));
    let want = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(2, "a hidden garden of ", 12));
    assert!(want.error.is_none());
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    let cfg = ServerConfig {
        max_batch: 1,
        spec: Some(SpecConfig { draft_plan, width: 4 }),
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let pool = server.pool.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(stream_req(1, "the small robot ", 400), sink);
    let _ = srx.recv().expect("A must stream its first token");
    handle.cancel(1);
    let a = rx_a.recv().unwrap();
    assert!(
        a.error.as_deref().is_some_and(|e| e.contains("cancelled")),
        "{:?}",
        a.error
    );
    // the release runs before the reply is sent, so by now both the
    // target and draft leases are gone
    assert_eq!(pool.in_use(), 0, "a spec cancel must release BOTH arenas");
    let b = handle.submit(req(2, "a hidden garden of ", 12)).recv().unwrap();
    assert!(b.error.is_none(), "{:?}", b.error);
    assert_eq!(b.tokens, want.tokens, "spec serving diverged after a lockstep cancel");
    assert_eq!(metrics.gauges().cancelled, 1);
    handle.shutdown();
}

#[test]
fn cancel_while_parked_drops_the_snapshot_cleanly() {
    // paged preemption parks the YOUNGEST resident (LIFO); cancelling
    // the parked request must drop its host snapshots without touching
    // the survivor, whose output still matches unconstrained serving.
    // Budget: 6 blocks of 16 tokens against two 64-token requests
    // (4 blocks peak each) — contention is guaranteed at ~3.5 blocks.
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(1, "the small robot ", 48));
    assert!(solo.error.is_none());
    let bt = 16usize;
    let bpb = nbl::kvcache::kv_bytes(engine.config(), engine.plan.kv_layers(), 1, bt, 4);
    let cfg = ServerConfig {
        kv_block_tokens: bt,
        kv_capacity_bytes: 6 * bpb,
        ..ServerConfig::default()
    };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let pool = server.pool.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(stream_req(1, "the small robot ", 48), sink);
    let _ = srx.recv().expect("A must stream its first token");
    // B (younger) joins; when the pool runs dry it is the LIFO victim
    let rx_b = handle.submit(req(2, "a hidden garden of ", 48));
    let t0 = std::time::Instant::now();
    while metrics.gauges().preemptions < 1 {
        assert!(t0.elapsed().as_secs() < 60, "the block budget never forced a preemption");
        std::thread::yield_now();
    }
    handle.cancel(2);
    let b = rx_b.recv().unwrap();
    assert!(
        b.error.as_deref().is_some_and(|e| e.contains("cancelled")),
        "{:?}",
        b.error
    );
    let a = rx_a.recv().unwrap();
    assert!(a.error.is_none(), "the survivor must be untouched: {:?}", a.error);
    assert_eq!(a.tokens, solo.tokens, "the survivor diverged across the eviction");
    let g = metrics.gauges();
    assert_eq!(g.cancelled, 1, "{g:?}");
    assert!(g.preemptions >= 1, "{g:?}");
    handle.shutdown();
    assert_eq!(pool.in_use(), 0, "a parked cancel leaked blocks or leases");
}

#[test]
fn queued_request_past_its_deadline_is_shed_with_the_typed_error() {
    // intake-side deadline shed: B can never admit while A holds the
    // one-row arena, so its 1 ms budget blows in queue — the reply is
    // the typed deadline error, the shed gauge sees it, and SLO
    // attainment counts it as a miss (unlike a cancellation)
    let engine = Arc::new(engine("main"));
    let cfg = ServerConfig { max_batch: 1, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(stream_req(1, "the small robot ", 300), sink);
    let _ = srx.recv().expect("A must stream its first token");
    let rx_b = handle.submit(GenRequest {
        deadline_ms: Some(1),
        ..req(2, "a hidden garden of ", 8)
    });
    let b = rx_b.recv().unwrap();
    assert!(
        b.error.as_deref().is_some_and(|e| e.contains("deadline")),
        "queue shed must use the typed deadline error: {:?}",
        b.error
    );
    let g = metrics.gauges();
    assert_eq!(g.shed, 1, "{g:?}");
    assert_eq!(g.expired, 0, "{g:?}");
    assert_eq!(
        metrics.summary().slo_attainment,
        0.0,
        "a shed IS a missed deadline and must count against attainment"
    );
    handle.cancel(1);
    let a = rx_a.recv().unwrap();
    assert!(a.error.is_some());
    handle.shutdown();
}

#[test]
fn mid_decode_deadline_expiry_frees_the_slot_and_counts_the_miss() {
    // observe-side deadline enforcement: a 25 ms budget against a
    // ~400-token decode expires mid-flight. The reply is the typed
    // error, the expired gauge (not shed) sees it, the row frees for
    // the next request, and nothing leaks.
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(2, "a hidden garden of ", 8));
    assert!(solo.error.is_none());
    let cfg = ServerConfig { max_batch: 1, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let metrics = server.metrics.clone();
    let pool = server.pool.clone();
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx_a = handle.submit_streaming(
        GenRequest { deadline_ms: Some(25), ..stream_req(1, "the small robot ", 400) },
        sink,
    );
    let a = rx_a.recv().unwrap();
    assert!(
        a.error.as_deref().is_some_and(|e| e.contains("deadline")),
        "mid-decode expiry must use the typed error: {:?}",
        a.error
    );
    let streamed = drain_sink(1, &srx);
    assert!(
        streamed.len() < 400,
        "the budget must cut the decode short, not let it run out"
    );
    let g = metrics.gauges();
    assert_eq!(g.expired, 1, "{g:?}");
    assert_eq!(g.shed, 0, "{g:?}");
    assert_eq!(metrics.summary().slo_attainment, 0.0);
    // the freed row serves the next request normally
    let b = handle.submit(req(2, "a hidden garden of ", 8)).recv().unwrap();
    assert!(b.error.is_none(), "{:?}", b.error);
    assert_eq!(b.tokens, solo.tokens, "serving diverged after an expiry teardown");
    handle.shutdown();
    assert_eq!(pool.in_use(), 0, "an expiry teardown leaked KV bytes");
}

// ---------------------------------------------------------------------------
// data-parallel replication (ISSUE 10)

#[test]
fn replicated_continuous_matches_single_replica_under_churn() {
    // cross-replica token parity: the same churn workload through 4
    // engine replicas behind the dispatcher must produce the exact
    // greedy outputs of the single-worker loop, plain AND
    // self-speculative — replication decides WHERE a request decodes,
    // never WHAT it decodes.
    let engine = Arc::new(engine("main"));
    let single = Arc::new(Server::new(engine.clone(), ServerConfig::default()));
    let want = churn_workload(&single);
    for r in &want {
        assert!(r.error.is_none(), "{:?}", r.error);
    }

    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(1);
    let configs = [
        ("plain", ServerConfig { replicas: 4, ..ServerConfig::default() }),
        (
            "spec",
            ServerConfig {
                replicas: 4,
                spec: Some(SpecConfig { draft_plan, width: 4 }),
                ..ServerConfig::default()
            },
        ),
    ];
    for (label, cfg) in configs {
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let metrics = server.metrics.clone();
        let pool = server.pool.clone();
        let got = churn_workload(&server);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.error.is_none(), "[{label}] {:?}", g.error);
            assert_eq!(
                g.tokens, w.tokens,
                "[{label}] replicated serving diverged from the single \
                 worker on request {}",
                w.id
            );
        }
        let g = metrics.gauges();
        assert_eq!(g.replicas, 4, "[{label}] gauge rollup must report 4 lanes");
        let busy = metrics
            .lane_gauges()
            .iter()
            .filter(|l| l.admissions > 0)
            .count();
        assert!(
            busy >= 2,
            "[{label}] 12 concurrent requests must spread over more than \
             one replica, got {busy} busy lane(s)"
        );
        assert_eq!(pool.in_use(), 0, "[{label}] replicated shutdown leaked KV bytes");
    }
}

#[test]
fn replicated_streaming_keeps_frame_order_and_cancel_works() {
    // the host lane defers frame emission off the decode thread; the
    // per-request FIFO must still deliver dense in-order indices with
    // every frame before the terminal, and a cancel must tear down
    // mid-decode exactly as on the single worker.
    let engine = Arc::new(engine("main"));
    let solo = Server::new(engine.clone(), ServerConfig::default())
        .generate_one(&req(5, "the quiet river ", 16));
    assert!(solo.error.is_none());

    let cfg = ServerConfig { replicas: 2, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let handle = server.clone().spawn();
    let (sink, srx) = mpsc::channel();
    let rx = handle.submit_streaming(stream_req(5, "the quiet river ", 16), sink);
    let r = rx.recv().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens, solo.tokens, "replicated stream diverged from solo");
    let streamed = drain_sink(5, &srx);
    assert_eq!(streamed, r.tokens, "streamed frames must mirror the terminal reply");

    let (sink2, srx2) = mpsc::channel();
    let rx2 = handle.submit_streaming(stream_req(6, "the small robot ", 400), sink2);
    let _ = srx2.recv().expect("request 6 must stream its first token");
    handle.cancel(6);
    let r2 = rx2.recv().unwrap();
    assert!(
        r2.error.as_deref().is_some_and(|e| e.contains("cancelled")),
        "cancel through the dispatcher must use the typed error: {:?}",
        r2.error
    );
    assert!(drain_sink(6, &srx2).len() < 400, "cancel must cut the decode short");
    handle.shutdown();
}

#[test]
fn replicated_shutdown_answers_every_pending_request() {
    // shutdown broadcast: every replica drains its queue/slots through
    // its outbox, so no submitted request is left hanging even when the
    // server dies mid-decode.
    let engine = Arc::new(engine("main"));
    let cfg = ServerConfig { replicas: 3, ..ServerConfig::default() };
    let server = Arc::new(Server::new(engine, cfg));
    let handle = server.clone().spawn();
    let rxs: Vec<_> = (0..9u64)
        .map(|i| handle.submit(req(i, "the small robot walked ", 200)))
        .collect();
    handle.shutdown();
    for rx in rxs {
        let r = rx.recv().expect("every pending request must be answered");
        assert!(
            r.error.is_none() || r.error.as_deref().is_some_and(|e| e.contains("shut down")),
            "pending requests either finish or get the shutdown error: {:?}",
            r.error
        );
    }
}
