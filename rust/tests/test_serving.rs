//! Serving-stack integration: batched groups, the async worker, the TCP
//! front-end, speculative decoding equivalence, and quantization.

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use nbl::executor::Engine;
use nbl::model::Artifacts;
use nbl::quant::{quantize_weights, QuantConfig};
use nbl::runtime::Runtime;
use nbl::sampling::SamplingParams;
use nbl::server::api::GenRequest;
use nbl::server::service::{Server, ServerConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::spec::{greedy_generate, SpeculativeDecoder};

fn engine(model: &str) -> Engine {
    let artifacts = Artifacts::discover().expect("run `make artifacts`");
    let runtime = Runtime::new(artifacts).unwrap();
    Engine::load(runtime, model).unwrap()
}

fn req(id: u64, prompt: &str, n: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: nbl::data::ByteTokenizer::new().encode(prompt),
        max_new_tokens: n,
        params: SamplingParams::greedy(),
    }
}

#[test]
fn single_request_generates_text() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let r = server.generate_one(&req(1, "the small robot ", 24));
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens.len(), 24);
    assert!(r.ttft_ms > 0.0 && r.total_ms >= r.ttft_ms);
    // greedy continuation of the trained grammar should be ascii words
    assert!(r.text.is_ascii());
    assert!(r.text.chars().any(|c| c.is_ascii_lowercase()), "{:?}", r.text);
}

#[test]
fn batched_group_matches_single_requests() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let a = req(1, "the bright engine ", 12);
    let b = req(2, "the hidden garden ", 12);
    let solo_a = server.generate_one(&a);
    let solo_b = server.generate_one(&b);
    let group = server.run_group(&[a, b]).unwrap();
    assert_eq!(group[0].tokens, solo_a.tokens, "batch row 0 diverged");
    assert_eq!(group[1].tokens, solo_b.tokens, "batch row 1 diverged");
}

#[test]
fn group_rejects_mixed_lengths() {
    let server = Server::new(Arc::new(engine("main")), ServerConfig::default());
    let e = server.run_group(&[req(1, "abcd", 2), req(2, "abcde", 2)]);
    assert!(e.is_err());
}

#[test]
fn async_worker_serves_many() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let metrics = server.metrics.clone();
    let handle = server.clone().spawn();
    let rxs: Vec<_> = (0..5)
        .map(|i| handle.submit(req(i, "there are 42 small ", 8)))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none());
        assert_eq!(r.tokens.len(), 8);
    }
    assert_eq!(metrics.len(), 5);
    let s = metrics.summary();
    assert!(s.mean_prefill_tok_s > 0.0);
    handle.shutdown();
}

#[test]
fn tcp_round_trip() {
    let server = Arc::new(Server::new(Arc::new(engine("main")), ServerConfig::default()));
    let front = TcpFrontend::start(server, "127.0.0.1:0").unwrap();
    let mut conn = std::net::TcpStream::connect(front.addr).unwrap();
    writeln!(
        conn,
        r#"{{"id": 9, "prompt": "the quiet river ", "max_tokens": 6}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = nbl::util::json::Json::parse(&line).unwrap();
    assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 9);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 6);
    // malformed line comes back as an error response, not a hangup
    writeln!(conn, "not json").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"));
    front.shutdown();
}

#[test]
fn speculative_matches_greedy_exactly() {
    let target = engine("main");
    let draft = engine("draft");
    let tok = nbl::data::ByteTokenizer::new();
    for prompt in ["the small robot ", "== ring buffer ==\na ring ", "there are 7 "] {
        let ids = tok.encode(prompt);
        let want = greedy_generate(&target, &ids, 40).unwrap();
        let dec = SpeculativeDecoder::new(&target, &draft, 4);
        let (got, stats) = dec.generate(&ids, 40).unwrap();
        assert_eq!(got, want, "speculative output diverged for {prompt:?}");
        assert!(stats.proposed > 0);
        assert!(
            stats.acceptance_rate() > 0.3,
            "draft should be useful: acceptance {}",
            stats.acceptance_rate()
        );
        assert!(stats.tokens_per_target_pass() > 1.0, "no compounding");
    }
}

#[test]
fn speculative_composes_with_nbl() {
    let target = engine("main");
    let artifacts = Artifacts::discover().unwrap();
    let train =
        nbl::data::Corpus::load(&artifacts, nbl::data::corpus::CorpusId::TinyC4, "train").unwrap();
    let mut src = nbl::executor::CaptureSource::new(&target, &train.tokens, 12, 128);
    let report = nbl::nbl::calibrate::Calibrator::run(&mut src).unwrap();
    let plan = report
        .plan_attn_nbl(2, nbl::nbl::criteria::Criterion::CcaBound)
        .unwrap();
    let nbl_target = target.with_plan(plan).unwrap();
    let draft = engine("draft");
    let tok = nbl::data::ByteTokenizer::new();
    let ids = tok.encode("the bright market ");
    let want = greedy_generate(&nbl_target, &ids, 32).unwrap();
    let dec = SpeculativeDecoder::new(&nbl_target, &draft, 4);
    let (got, stats) = dec.generate(&ids, 32).unwrap();
    assert_eq!(got, want, "NBL-compressed verifier diverged");
    assert!(stats.rounds < 32, "verification must batch tokens");
}

#[test]
fn quantized_model_still_generates() {
    let artifacts = Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let base = Engine::load(runtime.clone(), "main").unwrap();
    let q = quantize_weights(&base.weights, None, &QuantConfig { bits: 8, alpha: 0.0 }).unwrap();
    let qe = Engine::new(
        runtime,
        Arc::new(q),
        nbl::nbl::plan::ModelPlan::baseline(base.config().n_layers),
    )
    .unwrap();
    let tok = nbl::data::ByteTokenizer::new();
    let ids = tok.encode("the small robot ");
    let a = greedy_generate(&base, &ids, 16).unwrap();
    let b = greedy_generate(&qe, &ids, 16).unwrap();
    // int8 is near-lossless at this scale: outputs should mostly agree
    let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    assert!(agree >= 12, "int8 generation diverged early: {agree}/16");
}

#[test]
fn kv_pool_admission_control() {
    let cfg = ServerConfig { max_batch: 8, kv_capacity_bytes: 1024, eos: None };
    let server = Server::new(Arc::new(engine("main")), cfg);
    // a single group needs ~MBs of KV; a 1 KiB pool must refuse
    let r = server.generate_one(&req(1, "the small robot ", 4));
    assert!(r.error.is_some());
    assert!(r.error.unwrap().contains("KV pool exhausted"));
}
