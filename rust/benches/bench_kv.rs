//! Table 21: KV-cache sizes under NBL.
//!
//! Two parts: (a) the paper's own dimensions (Llama-3.1-8B: d=4096,
//! 32 heads / 8 kv groups, 32 layers, fp16, batch 64) through our §H.2
//! formula — must reproduce the paper's GB column exactly; (b) measured
//! cache-literal bytes of OUR engine vs the formula — must match too.

use nbl::kvcache::kv_bytes;
use nbl::model::config::ModelConfig;
use nbl::report::Table;

fn paper_config() -> ModelConfig {
    ModelConfig {
        name: "llama-3.1-8b".into(),
        vocab: 128_256,
        d_model: 4096,
        n_layers: 32,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        d_ff: 14336,
        max_ctx: 131_072,
        rope_theta: 500000.0,
        norm_eps: 1e-5,
    }
}

fn main() {
    let cfg = paper_config();
    let batch = 64;
    let mut table = Table::new(
        "Table 21: KV-cache size (GB), Llama-3.1-8B dims, batch 64, fp16",
        &["ctx", "Original", "NBL-4", "NBL-8", "NBL-12", "NBL-16"],
    );
    // paper's expected values for the Original column
    let expect_gb = [(512usize, 4.0f64), (1024, 8.0), (2048, 16.0), (4096, 32.0), (128_000, 1000.0)];
    for (ctx, want) in expect_gb {
        let mut row = vec![ctx.to_string()];
        for m in [0usize, 4, 8, 12, 16] {
            let bytes = kv_bytes(&cfg, cfg.n_layers - m, batch, ctx, 2);
            row.push(format!("{:.1}", bytes as f64 / 1e9));
        }
        let got = kv_bytes(&cfg, cfg.n_layers, batch, ctx, 2) as f64 / 1e9;
        assert!(
            (got - want).abs() / want < 0.08,
            "ctx {ctx}: formula gives {got:.2} GB, paper says {want} GB"
        );
        table.row(row);
    }
    println!("{}", table.render());
    table.save("table21_kv").unwrap();

    // (b) our engine's measured cache bytes match the formula
    let artifacts = nbl::model::Artifacts::discover().unwrap();
    let runtime = nbl::runtime::Runtime::new(artifacts).unwrap();
    let engine = nbl::executor::Engine::load(runtime, "main").unwrap();
    let ids = vec![1u32; 32];
    let pre = engine.prefill(&ids, 1, 32, None).unwrap();
    let mcfg = engine.config();
    let mut measured = 0usize;
    for c in pre.state.caches.iter().flatten() {
        measured += c.0.size_bytes() + c.1.size_bytes();
    }
    let formula = kv_bytes(mcfg, mcfg.n_layers, 1, mcfg.max_ctx, 4);
    println!(
        "[check] measured cache bytes {measured} == formula {formula}: {}",
        measured == formula
    );
    assert_eq!(measured, formula, "measured KV bytes must equal §H.2 formula");
    println!("bench_kv OK");
}
