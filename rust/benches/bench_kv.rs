//! Table 21: KV-cache sizes under NBL, plus the serving payoff.
//!
//! Three parts: (a) the paper's own dimensions (Llama-3.1-8B: d=4096,
//! 32 heads / 8 kv groups, 32 layers, fp16, batch 64) through our §H.2
//! formula — must reproduce the paper's GB column exactly; (b) measured
//! cache-literal bytes of OUR engine vs the formula — must match too;
//! (c) a mixed-prompt-length workload served by the continuous-batching
//! scheduler vs the exact-length-grouping baseline — the structural KV
//! saving only becomes throughput when the batch stays full.

use std::sync::Arc;

use nbl::kvcache::kv_bytes;
use nbl::model::config::ModelConfig;
use nbl::report::Table;
use nbl::sampling::SamplingParams;
use nbl::server::api::GenRequest;
use nbl::server::metrics::MetricsSummary;
use nbl::server::service::{BatchMode, Server, ServerConfig, SpecConfig};
use nbl::util::json::Json;
use nbl::util::timer::Timer;

fn paper_config() -> ModelConfig {
    ModelConfig {
        name: "llama-3.1-8b".into(),
        vocab: 128_256,
        d_model: 4096,
        n_layers: 32,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        d_ff: 14336,
        max_ctx: 131_072,
        rope_theta: 500000.0,
        norm_eps: 1e-5,
    }
}

fn main() {
    let cfg = paper_config();
    let batch = 64;
    let mut table = Table::new(
        "Table 21: KV-cache size (GB), Llama-3.1-8B dims, batch 64, fp16",
        &["ctx", "Original", "NBL-4", "NBL-8", "NBL-12", "NBL-16"],
    );
    // paper's expected values for the Original column
    let expect_gb =
        [(512usize, 4.0f64), (1024, 8.0), (2048, 16.0), (4096, 32.0), (128_000, 1000.0)];
    for (ctx, want) in expect_gb {
        let mut row = vec![ctx.to_string()];
        for m in [0usize, 4, 8, 12, 16] {
            let bytes = kv_bytes(&cfg, cfg.n_layers - m, batch, ctx, 2);
            row.push(format!("{:.1}", bytes as f64 / 1e9));
        }
        let got = kv_bytes(&cfg, cfg.n_layers, batch, ctx, 2) as f64 / 1e9;
        assert!(
            (got - want).abs() / want < 0.08,
            "ctx {ctx}: formula gives {got:.2} GB, paper says {want} GB"
        );
        table.row(row);
    }
    println!("{}", table.render());
    table.save("table21_kv").unwrap();

    // (b) our engine's measured cache bytes match the formula
    let artifacts = nbl::model::Artifacts::discover().unwrap();
    let runtime = nbl::runtime::Runtime::new(artifacts).unwrap();
    let engine = Arc::new(nbl::executor::Engine::load(runtime, "main").unwrap());
    let ids = vec![1u32; 32];
    let pre = engine.prefill(&ids, 1, 32, None).unwrap();
    let mcfg = engine.config();
    let mut measured = 0usize;
    for c in pre.state.caches.iter().flatten() {
        measured += c.0.size_bytes() + c.1.size_bytes();
    }
    let formula = kv_bytes(mcfg, mcfg.n_layers, 1, mcfg.max_ctx, 4);
    println!(
        "[check] measured cache bytes {measured} == formula {formula}: {}",
        measured == formula
    );
    assert_eq!(measured, formula, "measured KV bytes must equal §H.2 formula");

    // (c) mixed-prompt-length serving: continuous batching vs the
    // exact-length-grouping baseline, identical workload
    let n_requests = 16usize;
    let max_tokens = 24usize;
    let workload = |id: u64| GenRequest {
        id,
        // four distinct lengths interleaved: worst case for exact-length
        // grouping (each group degenerates towards batch 1)
        prompt: vec![(id % 200) as u32 + 1; 8 + (id as usize % 4) * 8],
        max_new_tokens: max_tokens,
        params: SamplingParams::greedy(),
        tenant: String::new(),
        weight: 1,
        deadline_ms: None,
        stream: false,
    };
    type ModeResult = (f64, usize, f64, f64, f64, MetricsSummary);
    let run_mode = |mode: BatchMode, spec: Option<SpecConfig>| -> ModeResult {
        let cfg = ServerConfig { mode, spec, ..ServerConfig::default() };
        let server = Arc::new(Server::new(engine.clone(), cfg));
        let metrics = server.metrics.clone();
        let handle = server.clone().spawn();
        let t = Timer::start();
        let rxs: Vec<_> = (0..n_requests as u64).map(|i| handle.submit(workload(i))).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let wall = t.elapsed_s();
        let summary = metrics.summary();
        let toks = summary.generated_tokens;
        let g = metrics.gauges();
        handle.shutdown();
        (
            wall,
            toks,
            g.mean_rows_per_iteration(),
            g.acceptance_rate(),
            g.tokens_per_row_iteration(),
            summary,
        )
    };
    let (wall_g, toks_g, _, _, _, _) = run_mode(BatchMode::ExactLength, None);
    let (wall_c, toks_c, occ_c, _, _, sum_c) = run_mode(BatchMode::Continuous, None);
    // continuous + self-speculation: the draft drops attention in two
    // layers (cheaper forward, same weights) and the target verifies
    // width-4 blocks per row
    let mut draft_plan = nbl::nbl::plan::ModelPlan::baseline(engine.config().n_layers);
    draft_plan.drop_attn(2);
    draft_plan.drop_attn(4);
    let (wall_s, toks_s, _, acc_s, tpi_s, _) = run_mode(
        BatchMode::Continuous,
        Some(SpecConfig { draft_plan, width: 4 }),
    );
    let tps_g = toks_g as f64 / wall_g.max(1e-9);
    let tps_c = toks_c as f64 / wall_c.max(1e-9);
    let tps_s = toks_s as f64 / wall_s.max(1e-9);
    println!("\n[serving] {n_requests} mixed-length requests x {max_tokens} tokens");
    println!("  exact-length grouping   {tps_g:8.1} tok/s  ({wall_g:.2} s)");
    println!(
        "  continuous batching     {tps_c:8.1} tok/s  ({wall_c:.2} s, {occ_c:.2} rows/iter)"
    );
    println!(
        "    TTFT p50/p95/p99      {:.1} / {:.1} / {:.1} ms, ITL {:.2} / {:.2} / {:.2} ms",
        sum_c.p50_ttft_s * 1e3,
        sum_c.p95_ttft_s * 1e3,
        sum_c.p99_ttft_s * 1e3,
        sum_c.p50_itl_s * 1e3,
        sum_c.p95_itl_s * 1e3,
        sum_c.p99_itl_s * 1e3
    );
    println!(
        "  continuous + spec       {tps_s:8.1} tok/s  ({wall_s:.2} s, acceptance {:.0}%, \
         {tpi_s:.2} tok/row-iter)",
        acc_s * 100.0
    );
    println!("  speedup (cont/grouped)  {:8.2}x", tps_c / tps_g.max(1e-9));
    println!("  speedup (spec/cont)     {:8.2}x", tps_s / tps_c.max(1e-9));
    assert_eq!(toks_s, toks_c, "speculation must not change token counts");

    // bench JSON for CI's perf trajectory (nbl-bench/v1; merged into
    // BENCH_<sha>.json by ci/collect_bench.py)
    let bench_json = Json::obj(vec![
        ("schema", Json::Str("nbl-bench/v1".into())),
        ("bench", Json::Str("bench_kv".into())),
        ("provenance", nbl::report::provenance()),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(n_requests as f64)),
                ("max_tokens", Json::Num(max_tokens as f64)),
            ]),
        ),
        (
            "metrics",
            Json::obj(vec![
                ("tok_s_grouped", Json::Num(tps_g)),
                ("tok_s_continuous", Json::Num(tps_c)),
                ("tok_s_spec", Json::Num(tps_s)),
                ("speedup_cont_over_grouped", Json::Num(tps_c / tps_g.max(1e-9))),
                ("speedup_spec_over_cont", Json::Num(tps_s / tps_c.max(1e-9))),
                ("rows_per_iteration", Json::Num(occ_c)),
                // latency distribution of the continuous run (record-only
                // trajectory keys in ci/bench_baseline.json)
                ("p50_ttft_ms", Json::Num(sum_c.p50_ttft_s * 1e3)),
                ("p95_ttft_ms", Json::Num(sum_c.p95_ttft_s * 1e3)),
                ("p99_ttft_ms", Json::Num(sum_c.p99_ttft_s * 1e3)),
                ("p50_itl_ms", Json::Num(sum_c.p50_itl_s * 1e3)),
                ("p95_itl_ms", Json::Num(sum_c.p95_itl_s * 1e3)),
                ("p99_itl_ms", Json::Num(sum_c.p99_itl_s * 1e3)),
            ]),
        ),
    ]);
    let path = nbl::report::save_json("bench_kv", &bench_json).unwrap();
    println!("bench JSON written to {}", path.display());
    let bucket = engine.decode_group_bucket(ServerConfig::default().max_batch);
    if engine.supports_row_decode(bucket) {
        assert!(
            tps_c > tps_g,
            "continuous batching must beat exact-length grouping on mixed \
             lengths: {tps_c:.1} vs {tps_g:.1} tok/s"
        );
    } else {
        println!(
            "  (attn_cached_rows_b{bucket}_s1 not in the AOT grid: per-row \
             fallback path, speedup not asserted — rebuild artifacts)"
        );
    }
    println!("bench_kv OK");
}
