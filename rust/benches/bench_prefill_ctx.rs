//! Figure 3: prefill speed-up of NBL-m vs context length.
//!
//! Shape to hold: the speed-up over the baseline widens with context
//! length (the O(n^2 d) attention term grows; the O(n d^2) linear
//! replacement doesn't) and with m.

use nbl::bench::experiments::{measure_speed, ExpConfig, Workbench};
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;

fn main() {
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new("main", cfg.clone()).unwrap();
    let contexts: &[usize] = if std::env::var("NBL_FAST").is_ok() {
        &[32, 128]
    } else {
        &[32, 128, 512]
    };
    let ms = [0usize, 1, 2, 3, 4];

    let mut table = Table::new(
        "Figure 3 analogue: prefill speed-up vs context length",
        &["ctx", "NBL-0", "NBL-1", "NBL-2", "NBL-3", "NBL-4"],
    );
    let mut series: Vec<Vec<f64>> = Vec::new();
    for &ctx in contexts {
        let mut row = vec![ctx.to_string()];
        let mut speeds = Vec::new();
        for &m in &ms {
            let engine = if m == 0 {
                wb.engine.with_plan(nbl::nbl::plan::ModelPlan::baseline(
                    wb.engine.config().n_layers,
                ))
            } else {
                wb.engine
                    .with_plan(wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap())
            }
            .unwrap();
            let s = measure_speed(&engine, &wb.calib.tokens, ctx, 4, cfg.speed_reps).unwrap();
            speeds.push(s.prefill_tok_s);
        }
        let base = speeds[0];
        for s in &speeds {
            row.push(format!("{:.3}", s / base));
        }
        series.push(speeds.iter().map(|s| s / base).collect());
        table.row(row);
    }
    println!("{}", table.render());
    table.save("fig3_prefill_ctx").unwrap();

    // shape check: speed-up of the largest m grows with context
    if series.len() >= 2 {
        let m_idx = ms.len() - 1;
        println!(
            "[check] NBL-{} speed-up at ctx {} = {:.3}, at ctx {} = {:.3} (paper: grows)",
            ms[m_idx],
            contexts[0],
            series[0][m_idx],
            contexts[series.len() - 1],
            series[series.len() - 1][m_idx]
        );
    }
}
