//! Ablations: Tables 14/15 (calibration-set dependency), 17/18
//! (CCA-bound vs cosine criterion), 19 (greedy selection), 20 (layer
//! rankings) and Figure 2 (per-layer CCA bound).

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::data::corpus::{Corpus, CorpusId};
use nbl::eval::perplexity;
use nbl::executor::CaptureSource;
use nbl::nbl::calibrate::{greedy_select, Calibrator};
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;

fn main() {
    let cfg = ExpConfig::from_env();
    let artifacts = nbl::model::Artifacts::discover().unwrap();

    // workbenches calibrated on each corpus
    let wb_c4 = Workbench::with_corpus("main", cfg.clone(), CorpusId::TinyC4).unwrap();
    let wb_wiki = Workbench::with_corpus("main", cfg.clone(), CorpusId::TinyWiki).unwrap();
    let val_c4 = Corpus::load(&artifacts, CorpusId::TinyC4, "val").unwrap();
    let val_wiki = Corpus::load(&artifacts, CorpusId::TinyWiki, "val").unwrap();

    // ---- Tables 14/15: perplexity cross-matrix
    let m = 2usize;
    let mut t14 = Table::new(
        "Tables 14/15 analogue: calibration-set dependency (ppl)",
        &["Method", "calib", "ppl tiny-c4", "ppl tiny-wiki"],
    );
    for (wb, calib_name) in [(&wb_c4, "tiny-c4"), (&wb_wiki, "tiny-wiki")] {
        for (label, plan) in [
            (
                format!("Attn NBL-{m}"),
                wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap(),
            ),
            (
                format!("Attn DROP-{m}"),
                wb.report.plan_attn_drop(m, Criterion::CosineDistance),
            ),
        ] {
            let e = wb.engine.with_plan(plan).unwrap();
            let p_c4 = perplexity(&e, &val_c4, cfg.ppl_windows, 128).unwrap();
            let p_wiki = perplexity(&e, &val_wiki, cfg.ppl_windows, 128).unwrap();
            t14.row(vec![
                label,
                calib_name.into(),
                format!("{p_c4:.3}"),
                format!("{p_wiki:.3}"),
            ]);
        }
    }
    println!("{}", t14.render());
    t14.save("table14_calib_dependency").unwrap();

    // ---- Tables 17/18: criterion comparison (accuracy at each m)
    let mut t17 = Table::new(
        "Tables 17/18 analogue: CCA-bound vs cosine-distance criterion",
        &["m", "CCA avg acc", "Cosine avg acc"],
    );
    let mut last = (0.0, 0.0);
    for m in [1usize, 2, 3, 4] {
        if m >= wb_c4.engine.config().n_layers {
            break;
        }
        let cca_plan = wb_c4.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap();
        let cos_plan = wb_c4
            .report
            .plan_attn_nbl(m, Criterion::CosineDistance)
            .unwrap();
        let acc_cca = wb_c4
            .accuracy(&wb_c4.engine.with_plan(cca_plan).unwrap())
            .unwrap()
            .avg_accuracy;
        let acc_cos = wb_c4
            .accuracy(&wb_c4.engine.with_plan(cos_plan).unwrap())
            .unwrap()
            .avg_accuracy;
        t17.row(vec![
            m.to_string(),
            format!("{:.1}", acc_cca * 100.0),
            format!("{:.1}", acc_cos * 100.0),
        ]);
        last = (acc_cca, acc_cos);
    }
    println!("{}", t17.render());
    t17.save("table17_criterion").unwrap();
    println!(
        "[check] at the largest m: CCA {:.3} vs cosine {:.3} (paper: CCA >= cosine)",
        last.0, last.1
    );

    // ---- Table 19: greedy selection
    let mut t19 = Table::new(
        "Table 19 analogue: greedy vs one-shot CCA selection",
        &["m", "Greedy avg acc", "One-shot CCA avg acc"],
    );
    for m in [1usize, 2, 3] {
        let greedy_plan = greedy_select(wb_c4.engine.config().n_layers, m, |plan| {
            let engine = wb_c4.engine.with_plan(plan.clone())?;
            let mut src = CaptureSource::new(
                &engine,
                &wb_c4.calib.tokens,
                cfg.calib_seqs / 2,
                cfg.calib_len,
            );
            Calibrator::run(&mut src)
        })
        .unwrap();
        let acc_greedy = wb_c4
            .accuracy(&wb_c4.engine.with_plan(greedy_plan).unwrap())
            .unwrap()
            .avg_accuracy;
        let acc_oneshot = wb_c4
            .accuracy(
                &wb_c4
                    .engine
                    .with_plan(wb_c4.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap())
                    .unwrap(),
            )
            .unwrap()
            .avg_accuracy;
        t19.row(vec![
            m.to_string(),
            format!("{:.1}", acc_greedy * 100.0),
            format!("{:.1}", acc_oneshot * 100.0),
        ]);
    }
    println!("{}", t19.render());
    t19.save("table19_greedy").unwrap();

    // ---- Table 20 + Figure 2: rankings and per-layer bounds
    let mut t20 = Table::new(
        "Table 20 analogue: layer importance rankings (most->least)",
        &["model", "calib", "criterion", "ranking"],
    );
    for (wb, calib_name) in [(&wb_c4, "tiny-c4"), (&wb_wiki, "tiny-wiki")] {
        for crit in [Criterion::CcaBound, Criterion::CosineDistance] {
            let ranking = wb.report.importance_ranking(crit);
            t20.row(vec![
                "main".into(),
                calib_name.into(),
                crit.name().into(),
                format!("{ranking:?}"),
            ]);
        }
    }
    println!("{}", t20.render());
    t20.save("table20_rankings").unwrap();

    let mut f2 = Table::new(
        "Figure 2 analogue: per-layer CCA NMSE bound (main model)",
        &["layer", "nmse_bound", "bound_per_dim", "cosine_distance"],
    );
    for lc in &wb_c4.report.layers {
        f2.row(vec![
            lc.layer.to_string(),
            format!("{:.4}", lc.cca.nmse_bound),
            format!("{:.6}", lc.cca.nmse_bound_per_dim),
            format!("{:.4}", lc.cosine_distance),
        ]);
    }
    println!("{}", f2.render());
    f2.save("fig2_layer_bounds").unwrap();
}
