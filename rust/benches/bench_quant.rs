//! Table 5: NBL on top of a quantized baseline (the Llama-3.1-70B + AWQ
//! experiment, scaled: int8 AWQ-like quantization of the tiny model).
//!
//! Shape to hold: NBL preserves the quantized baseline's accuracy better
//! than DROP at matched m; the NBL linear layers are quantized too
//! (App. E.6).

use std::sync::Arc;

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::nbl::plan::{BlockOp, ModelPlan, PlanKind};
use nbl::quant::{quantize_linear_layer, quantize_weights, QuantConfig};
use nbl::report::Table;

fn main() {
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new("main", cfg).unwrap();
    let n_layers = wb.engine.config().n_layers;

    // activation scales from calibration (mean |stream|): AWQ's `a_k`
    let d = wb.engine.config().d_model;
    let mut act = vec![0.0f32; d];
    let mut n = 0;
    for lc in &wb.report.layers {
        if lc.stats.n > 0 {
            for (a, &c) in act.iter_mut().zip(lc.stats.cxx.data().iter().step_by(d + 1)) {
                *a += c.sqrt() as f32; // diag(Cxx)^1/2 ~ channel std
            }
            n += 1;
        }
    }
    for a in act.iter_mut() {
        *a /= n.max(1) as f32;
    }

    let qcfg = QuantConfig { bits: 8, alpha: 0.5 };
    let qweights = Arc::new(quantize_weights(&wb.engine.weights, Some(&act), &qcfg).unwrap());
    let qbase = Engine::new(
        wb.runtime.clone(),
        qweights.clone(),
        ModelPlan::baseline(n_layers),
    )
    .unwrap();

    let mut table = Table::new(
        "Table 5 analogue: NBL/DROP on the int8-AWQ-quantized baseline",
        &["Method", "avg_acc", "pooled_se", "prefill_x", "tput_x"],
    );
    let base_acc = wb.accuracy(&qbase).unwrap();
    let base_speed = wb.speed(&qbase).unwrap();
    table.row(vec![
        "Baseline (quant.)".into(),
        format!("{:.1}", base_acc.avg_accuracy * 100.0),
        format!("{:.2}", base_acc.pooled_se * 100.0),
        "1.00".into(),
        "1.00".into(),
    ]);

    let mut results = Vec::new();
    for m in [2usize, 3, 4] {
        if m >= n_layers {
            break;
        }
        // NBL on the quantized model, with quantized linear layers
        let mut plan = wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap();
        plan.kind = PlanKind::Custom(format!("Attn NBL-{m} (quant.)"));
        for lp in plan.layers.iter_mut() {
            if let BlockOp::Linear(lin) = &lp.attn {
                lp.attn =
                    BlockOp::Linear(Arc::new(quantize_linear_layer(lin, Some(&act), &qcfg)));
            }
        }
        let nbl_e = Engine::new(wb.runtime.clone(), qweights.clone(), plan).unwrap();
        let nbl_acc = wb.accuracy(&nbl_e).unwrap();
        let nbl_speed = wb.speed(&nbl_e).unwrap();

        let mut dplan = wb.report.plan_attn_drop(m, Criterion::CosineDistance);
        dplan.kind = PlanKind::Custom(format!("Attn DROP-{m} (quant.)"));
        let drop_e = Engine::new(wb.runtime.clone(), qweights.clone(), dplan).unwrap();
        let drop_acc = wb.accuracy(&drop_e).unwrap();
        let drop_speed = wb.speed(&drop_e).unwrap();

        for (label, acc, speed) in [
            (format!("Attn DROP-{m}"), &drop_acc, drop_speed),
            (format!("Attn NBL-{m}"), &nbl_acc, nbl_speed),
        ] {
            table.row(vec![
                label,
                format!("{:.1}", acc.avg_accuracy * 100.0),
                format!("{:.2}", acc.pooled_se * 100.0),
                format!("{:.2}", speed.prefill_tok_s / base_speed.prefill_tok_s),
                format!("{:.2}", speed.decode_tok_s / base_speed.decode_tok_s),
            ]);
        }
        results.push((m, nbl_acc.avg_accuracy, drop_acc.avg_accuracy));
    }
    println!("{}", table.render());
    table.save("table5_quant").unwrap();
    if let Some((m, nbl, drop)) = results.last() {
        println!(
            "[check] at m={m}: NBL {nbl:.3} vs DROP {drop:.3} on the quantized \
             baseline (paper: NBL preserves accuracy better)"
        );
    }
}
