//! Figure 4: accuracy vs KV-savings and vs throughput — the pareto
//! curves of NBL vs DROP across compression levels.
//!
//! Shape to hold: at high compression the NBL curve sits above DROP's.

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;

fn main() {
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new("main", cfg).unwrap();
    let n_layers = wb.engine.config().n_layers;

    let mut table = Table::new(
        "Figure 4 analogue: accuracy / KV / throughput pareto (NBL vs DROP)",
        &["method", "m", "avg_acc", "pooled_se", "kv_fraction", "tput_ratio"],
    );
    let base_speed = wb.speed(&wb.engine).unwrap();
    let mut nbl_at_max = 0.0;
    let mut drop_at_max = 0.0;
    let max_m = (n_layers - 1).min(5);
    for m in 0..=max_m {
        for method in ["nbl", "drop"] {
            let plan = if m == 0 {
                nbl::nbl::plan::ModelPlan::baseline(n_layers)
            } else if method == "nbl" {
                wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap()
            } else {
                wb.report.plan_attn_drop(m, Criterion::CosineDistance)
            };
            let kv = plan.kv_fraction();
            let engine = wb.engine.with_plan(plan).unwrap();
            let acc = wb.accuracy(&engine).unwrap();
            let speed = wb.speed(&engine).unwrap();
            table.row(vec![
                method.into(),
                m.to_string(),
                format!("{:.3}", acc.avg_accuracy),
                format!("{:.3}", acc.pooled_se),
                format!("{kv:.3}"),
                format!("{:.3}", speed.decode_tok_s / base_speed.decode_tok_s),
            ]);
            if m == max_m {
                if method == "nbl" {
                    nbl_at_max = acc.avg_accuracy;
                } else {
                    drop_at_max = acc.avg_accuracy;
                }
            }
            if m == 0 {
                break; // baseline only once
            }
        }
    }
    println!("{}", table.render());
    table.save("fig4_pareto").unwrap();
    println!(
        "[check] at m={max_m}: NBL acc {nbl_at_max:.3} vs DROP acc {drop_at_max:.3} \
         (paper: NBL pareto-dominates at high compression)"
    );
}
