//! Table 6: speculative decoding + NBL compounding speed-ups.
//!
//! EAGLE-3-alone analogue = draft+verify with the uncompressed target;
//! NBL-m + spec = same protocol with the NBL-compressed verifier.
//! Shape to hold: speed-up compounds (spec x NBL > spec alone),
//! monotone in m; output equals plain greedy exactly.

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;
use nbl::runtime::Runtime;
use nbl::spec::{greedy_generate, SpeculativeDecoder};
use nbl::util::timer::Timer;

fn time_plain(engine: &Engine, prompt: &[u32], n: usize) -> f64 {
    let t = Timer::start();
    let _ = greedy_generate(engine, prompt, n).unwrap();
    t.elapsed_s()
}

fn time_spec(target: &Engine, draft: &Engine, prompt: &[u32], n: usize) -> (f64, f64, usize) {
    let dec = SpeculativeDecoder::new(target, draft, 4);
    let t = Timer::start();
    let (_, stats) = dec.generate(prompt, n).unwrap();
    (t.elapsed_s(), stats.acceptance_rate(), stats.rounds)
}

fn main() {
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new("main", cfg.clone()).unwrap();
    let artifacts = nbl::model::Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let draft = Engine::load(runtime, "draft").unwrap();

    let gen = cfg.speed_gen.max(48);
    let prompt = &wb.calib.tokens[..64];
    // single-core timing is noisy: median of >=5 reps after warmup
    let reps = cfg.speed_reps.max(5);

    // best-of-N: robust to the shared-vCPU contention of this testbed
    let best = |xs: &Vec<f64>| xs.iter().cloned().fold(f64::INFINITY, f64::min);

    // baseline: plain greedy on the uncompressed target (warm first)
    let _ = greedy_generate(&wb.engine, prompt, gen).unwrap();
    let base_times: Vec<f64> = (0..reps)
        .map(|_| time_plain(&wb.engine, prompt, gen))
        .collect();
    let base = best(&base_times);

    // "Proj." column: the paper's 8B-scale regime keeps draft acceptance
    // ~constant under NBL (the verifier barely changes); at our 6-layer
    // toy scale NBL visibly shifts the output distribution, so we also
    // report the projection that combines the MEASURED per-round cost of
    // the NBL verifier with the spec-alone acceptance (EXPERIMENTS.md).
    let mut table = Table::new(
        "Table 6 analogue: speculative decoding + NBL (greedy, width 4)",
        &["Configuration", "Speedup", "Proj.", "Acceptance", "tokens/s"],
    );
    table.row(vec![
        "Target alone (greedy)".into(),
        "1.00".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", gen as f64 / base),
    ]);
    let mut tokens_per_round_alone = 0.0f64;

    let mut last_speedup = 0.0;
    for m in [0usize, 1, 2, 3] {
        let target = if m == 0 {
            wb.engine
                .with_plan(nbl::nbl::plan::ModelPlan::baseline(
                    wb.engine.config().n_layers,
                ))
                .unwrap()
        } else {
            wb.engine
                .with_plan(wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap())
                .unwrap()
        };
        // verify exact equivalence before timing (also warms every
        // executable this config touches, so compilation never pollutes
        // the timed reps)
        let want = greedy_generate(&target, prompt, gen).unwrap();
        let (got, _) = SpeculativeDecoder::new(&target, &draft, 4)
            .generate(prompt, gen)
            .unwrap();
        assert_eq!(want, got, "speculative output must match greedy (m={m})");
        let _ = SpeculativeDecoder::new(&target, &draft, 4)
            .generate(prompt, gen)
            .unwrap();

        let mut times = Vec::new();
        let mut acc = 0.0;
        let mut rounds = 1usize;
        for _ in 0..reps {
            let (t, a, r) = time_spec(&target, &draft, prompt, gen);
            times.push(t);
            acc = a;
            rounds = r.max(1);
        }
        let t = best(&times);
        let label = if m == 0 {
            "Spec alone (EAGLE slot)".to_string()
        } else {
            format!("Attn NBL-{m} + Spec")
        };
        let speedup = base / t;
        // measured per-round cost of this verifier x spec-alone acceptance
        let round_time = t / rounds as f64;
        if m == 0 {
            tokens_per_round_alone = gen as f64 / rounds as f64;
        }
        let projected = base / (round_time * gen as f64 / tokens_per_round_alone.max(1e-9));
        table.row(vec![
            label,
            format!("{speedup:.2}"),
            format!("{projected:.2}"),
            format!("{acc:.2}"),
            format!("{:.1}", gen as f64 / t),
        ]);
        last_speedup = projected;
    }
    println!("{}", table.render());
    table.save("table6_speculative").unwrap();
    println!(
        "[check] largest compound speed-up x{last_speedup:.2} (paper: 4.07x on A100; \
         shape = compounding, monotone in m)"
    );
}
