//! Tables 2 / 3 / 4 / 8 (+ the interval Tables 9-12): the main
//! accuracy-vs-speed grid over {Baseline, SliceGPT-%, SLEB-m,
//! Block DROP/NBL-m, Attn DROP/NBL-m} for each model.
//!
//! Model mapping (DESIGN.md §2): main -> Mistral-7B slot,
//! alt -> Llama-3.1-8B slot, distill -> DeepSeek-R1-Distill slot.
//! Shape to hold: Attn NBL >= Attn DROP >= Block* >= SLEB/SliceGPT at
//! matched m; NBL degrades gracefully at the largest m.

use nbl::bench::experiments::{build_method_grid, evaluate_grid, main_table, ExpConfig, Workbench};

fn run_model(model: &str, table_id: &str) {
    let cfg = ExpConfig::from_env();
    let wb = Workbench::new(model, cfg).unwrap();
    let n_layers = wb.engine.config().n_layers;
    // paper uses m in {4,8,12,16} of 32 layers; scale to our K
    let ms: Vec<usize> = [1usize, 2, 3, 4]
        .iter()
        .copied()
        .filter(|&m| m < n_layers)
        .collect();
    let rows = build_method_grid(&wb, &ms).unwrap();
    let evaluated = evaluate_grid(&wb, &rows).unwrap();
    let table = main_table(
        &format!("Main table ({model} model, K={n_layers} layers)"),
        &evaluated,
    );
    println!("{}", table.render());
    table.save(table_id).unwrap();

    // qualitative shape checks (soft: print loudly instead of panicking
    // so one noisy cell doesn't kill the whole table run)
    let find = |label: &str| evaluated.iter().find(|r| r.label == label);
    if let (Some(nbl), Some(drop)) = (find("Attn NBL-3"), find("Attn DROP-3")) {
        let diff = nbl.summary.avg_accuracy - drop.summary.avg_accuracy;
        println!(
            "[check] Attn NBL-3 vs DROP-3 accuracy delta: {:+.3} (paper: NBL wins at high m)",
            diff
        );
    }
    if let (Some(base), Some(nbl)) = (find("Baseline"), find("Attn NBL-1")) {
        println!(
            "[check] NBL-1 accuracy drop vs baseline: {:+.3} (paper: ~0)",
            nbl.summary.avg_accuracy - base.summary.avg_accuracy
        );
    }
}

fn main() {
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "all".into());
    match model.as_str() {
        "main" => run_model("main", "table2_main"),
        "alt" => run_model("alt", "table3_alt"),
        "distill" => run_model("distill", "table4_distill"),
        _ => {
            run_model("main", "table2_main");
            run_model("alt", "table3_alt");
            run_model("distill", "table4_distill");
        }
    }
}
