//! §Perf microbenchmarks: the hot-path costs the optimization pass
//! iterates on (EXPERIMENTS.md §Perf records before/after).
//!
//! - decode step latency per layer-op (attn_cached vs linear_block):
//!   the very trade NBL makes;
//! - prefill latency per bucket;
//! - gram accumulation: Rust loop vs XLA `gram` executable;
//! - Jacobi eigh / SVD / LMMSE solve at model width.

use nbl::bench::{bench_for, BenchStats};
use nbl::linalg::{eigh, singular_values, solve_psd, Mat};
use nbl::model::Artifacts;
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;
use nbl::runtime::{lit_from_tensor, Runtime};
use nbl::stats::GramAccumulator;
use nbl::tensor::Tensor;
use nbl::util::rng::Rng;

fn main() {
    let fast = std::env::var("NBL_FAST").is_ok();
    let min_t = if fast { 0.2 } else { 1.0 };
    let artifacts = Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let engine = nbl::executor::Engine::load(runtime.clone(), "main").unwrap();
    let corpus = nbl::data::Corpus::load(
        nbl::executor::Engine::load(runtime.clone(), "main")
            .unwrap()
            .runtime
            .artifacts(),
        nbl::data::corpus::CorpusId::TinyC4,
        "train",
    )
    .unwrap();

    let mut stats: Vec<BenchStats> = Vec::new();

    // ---- end-to-end decode step (full layer stack), baseline vs NBL-3
    {
        let prompt = &corpus.tokens[..128];
        let pre = engine.prefill(prompt, 1, 128, None).unwrap();
        let mut state = pre.state;
        stats.push(bench_for("decode_step/baseline", 3, min_t, || {
            if state.remaining() == 0 {
                state.pos = 128;
            }
            let _ = engine.decode(&mut state, &[42], 1).unwrap();
        }));

        let mut src =
            nbl::executor::CaptureSource::new(&engine, &corpus.tokens, 8, 128);
        let report = nbl::nbl::calibrate::Calibrator::run(&mut src).unwrap();
        let nbl_engine = engine
            .with_plan(report.plan_attn_nbl(3, Criterion::CcaBound).unwrap())
            .unwrap();
        let pre2 = nbl_engine.prefill(prompt, 1, 128, None).unwrap();
        let mut state2 = pre2.state;
        stats.push(bench_for("decode_step/attn-nbl-3", 3, min_t, || {
            if state2.remaining() == 0 {
                state2.pos = 128;
            }
            let _ = nbl_engine.decode(&mut state2, &[42], 1).unwrap();
        }));
    }

    // ---- prefill per bucket
    for t in [32usize, 128, 512] {
        let prompt = &corpus.tokens[..t];
        // warm the executables outside the timer
        let _ = engine.prefill(prompt, 1, t, None).unwrap();
        stats.push(bench_for(&format!("prefill/b1_t{t}"), 1, min_t, || {
            let _ = engine.prefill(prompt, 1, t, None).unwrap();
        }));
    }

    // ---- single-op dispatch: attn_cached vs linear_block at S=1
    {
        let d = engine.config().d_model;
        let x = Tensor::zeros(vec![1, 1, d]);
        let xl = lit_from_tensor(&x).unwrap();
        let w = lit_from_tensor(&Tensor::zeros(vec![d, d])).unwrap();
        let b = lit_from_tensor(&Tensor::zeros(vec![d])).unwrap();
        let _ = runtime.run("linear_block_b1_t1", &[&xl, &w, &b]).unwrap();
        stats.push(bench_for("op/linear_block_b1_t1", 3, min_t, || {
            let _ = runtime.run("linear_block_b1_t1", &[&xl, &w, &b]).unwrap();
        }));
    }

    // ---- gram: rust accumulation vs XLA executable
    {
        let n = 4096usize;
        let dg = 128usize;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..n * dg).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..n * dg).map(|_| rng.normal_f32()).collect();
        stats.push(bench_for("gram/rust", 1, min_t, || {
            let mut acc = GramAccumulator::new(dg);
            acc.update(&x, &y).unwrap();
        }));
        let xt = Tensor::new(vec![n, dg], x.clone()).unwrap();
        let yt = Tensor::new(vec![n, dg], y.clone()).unwrap();
        let xl = lit_from_tensor(&xt).unwrap();
        let yl = lit_from_tensor(&yt).unwrap();
        let op = format!("gram_jnp_n{n}_d{dg}");
        let _ = runtime.run(&op, &[&xl, &yl]).unwrap();
        stats.push(bench_for("gram/xla_jnp", 1, min_t, || {
            let _ = runtime.run(&op, &[&xl, &yl]).unwrap();
        }));
        let op_p = format!("gram_n{n}_d{dg}");
        let _ = runtime.run(&op_p, &[&xl, &yl]).unwrap();
        stats.push(bench_for("gram/xla_pallas", 1, min_t, || {
            let _ = runtime.run(&op_p, &[&xl, &yl]).unwrap();
        }));
    }

    // ---- O(d^3) calibration core at model width
    {
        let d = 128usize;
        let mut rng = Rng::new(6);
        let a = Mat::from_fn(d, d, |_, _| rng.normal());
        let mut psd = a.matmul_nt(&a);
        for i in 0..d {
            psd[(i, i)] += 1.0;
        }
        let b = Mat::from_fn(d, d, |_, _| rng.normal());
        stats.push(bench_for("linalg/eigh_128", 1, min_t, || {
            let _ = eigh(&psd).unwrap();
        }));
        stats.push(bench_for("linalg/svd_128", 1, min_t, || {
            let _ = singular_values(&b).unwrap();
        }));
        stats.push(bench_for("linalg/solve_psd_128", 1, min_t, || {
            let _ = solve_psd(&psd, &b, 0.0).unwrap();
        }));
    }

    let mut table = Table::new(
        "§Perf microbenchmarks",
        &["bench", "median_ms", "p10_ms", "p90_ms", "iters"],
    );
    for s in &stats {
        println!("{}", s.line());
        table.row(vec![
            s.name.clone(),
            format!("{:.3}", s.median_s * 1e3),
            format!("{:.3}", s.p10_s * 1e3),
            format!("{:.3}", s.p90_s * 1e3),
            s.iters.to_string(),
        ]);
    }
    table.save("perf_micro").unwrap();
}
