//! Table 1 / Table 7: calibration runtime scaling with model width.
//!
//! Runs Algorithm 2 (covariance -> eigh -> inverse sqrts -> CCA SVD ->
//! LMMSE solve) on random activations at d in {64,128,256,512}, with the
//! paper's 256-sample x 2048-context workload scaled to s*t = 64*256
//! rows, and reports seconds/layer + extrapolated whole-model totals.
//! Expected shape: runtime grows superlinearly (the O(d^3) term) while
//! the O(s*t*d^2) accumulation dominates at small d.

use nbl::nbl::cca::cca_bound;
use nbl::nbl::lmmse::lmmse_fit;
use nbl::report::Table;
use nbl::stats::GramAccumulator;
use nbl::util::rng::Rng;
use nbl::util::timer::Timer;

fn calibrate_once(d: usize, rows: usize, chunk: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    // synthetic "activations": y = tanh-ish function of x
    let mut acc = GramAccumulator::new(d);
    let t_total = Timer::start();
    let mut x = vec![0.0f32; chunk * d];
    let mut y = vec![0.0f32; chunk * d];
    let mut done = 0;
    while done < rows {
        let n = chunk.min(rows - done);
        for v in x.iter_mut().take(n * d) {
            *v = rng.normal_f32();
        }
        for i in 0..n * d {
            y[i] = (x[i] * 0.7).tanh() + 0.1 * rng.normal_f32();
        }
        acc.update(&x[..n * d], &y[..n * d]).unwrap();
        done += n;
    }
    let accum_s = t_total.elapsed_s();

    let t_solve = Timer::start();
    let stats = acc.finalize().unwrap();
    let _cca = cca_bound(&stats).unwrap();
    let _lin = lmmse_fit(&stats, 1e-8).unwrap();
    (accum_s, t_solve.elapsed_s())
}

fn main() {
    let fast = std::env::var("NBL_FAST").is_ok();
    let dims: &[usize] = if fast { &[64, 128] } else { &[64, 128, 256, 512] };
    let rows = if fast { 4096 } else { 16384 }; // s*t token rows
    let layer_counts = [6usize, 8, 32, 80];

    let mut table = Table::new(
        "Table 1/7 analogue: calibration runtime vs width (Alg. 2)",
        &["d", "rows", "accum_s", "solve_s", "per_layer_s", "x6L", "x32L", "x80L"],
    );
    let mut prev: Option<f64> = None;
    for &d in dims {
        let (accum, solve) = calibrate_once(d, rows, 1024, 42);
        let per_layer = accum + solve;
        let mut cells = vec![
            d.to_string(),
            rows.to_string(),
            format!("{accum:.3}"),
            format!("{solve:.3}"),
            format!("{per_layer:.3}"),
        ];
        for &l in &layer_counts[..3] {
            cells.push(format!("{:.1}", per_layer * l as f64));
        }
        table.row(cells);
        if let Some(p) = prev {
            // doubling d must increase runtime (sanity of the scaling claim)
            assert!(per_layer > p, "runtime must grow with d");
        }
        prev = Some(per_layer);
    }
    println!("{}", table.render());
    let path = table.save("table1_calibration").unwrap();
    println!("saved {}", path.display());
}
