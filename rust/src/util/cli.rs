//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists options that
    /// take no value; everything else starting with `--` consumes one.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Config(format!("option --{stripped} needs a value"))
                    })?;
                    out.options.insert(stripped.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be an integer"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be a number"))),
        }
    }

    /// Comma-separated usize list.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| Error::Config(format!("--{name}: bad int '{p}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(&s(&["cmd", "--m", "4", "--fast", "--k=v", "pos2"]), &["fast"])
            .unwrap();
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("m"), Some("4"));
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get_usize("m", 0).unwrap(), 4);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&s(&["--key"]), &[]).is_err());
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(&s(&["--ms", "1,2,3"]), &[]).unwrap();
        assert_eq!(a.get_usize_list("ms", &[9]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize_list("other", &[9]).unwrap(), vec![9]);
    }
}
