//! Mini property-testing harness (proptest is not available offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs. On failure it retries with progressively "smaller" regenerated
//! inputs (size-directed shrinking: the generator receives a shrink level
//! and should produce simpler cases at higher levels), then panics with
//! the seed + smallest failing case so runs are reproducible.

use crate::util::rng::Rng;

/// Context handed to generators: RNG + shrink level (0 = full size).
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub shrink: u32,
}

impl<'a> Gen<'a> {
    /// Size budget helper: full at shrink=0, halved each level, min 1.
    pub fn size(&mut self, full: usize) -> usize {
        (full >> self.shrink).max(1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32() * scale).collect()
    }
}

/// Run a property over generated cases. Panics with diagnostics on failure.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut generate: impl FnMut(&mut Gen) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = generate(&mut Gen { rng: &mut rng, shrink: 0 });
        if let Err(msg) = property(&input) {
            // shrink: regenerate at increasing shrink levels from a fresh
            // stream derived from the failing case index
            let mut smallest: (String, String) = (format!("{input:?}"), msg);
            for level in 1..6 {
                let mut srng = Rng::new(seed ^ (case_idx as u64) << 17 ^ level as u64);
                for _ in 0..20 {
                    let cand = generate(&mut Gen { rng: &mut srng, shrink: level });
                    if let Err(m) = property(&cand) {
                        smallest = (format!("{cand:?}"), m);
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case_idx}).\n\
                 smallest failing input: {}\nreason: {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0_f32.max(x.abs()).max(y.abs());
        if (x - y).abs() / denom > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            1,
            50,
            |g| {
                let n = g.size(64);
                g.vec_f32(n, 1.0)
            },
            |v| {
                let sum: f32 = v.iter().map(|x| x * x).sum();
                if sum >= 0.0 { Ok(()) } else { Err("negative".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            2,
            50,
            |g| g.usize_in(0, 100),
            |&n| if n < 90 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.1], 1e-3).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-3).is_ok());
    }
}
