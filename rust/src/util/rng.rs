//! Deterministic PRNG (xoshiro256**) — no `rand` crate offline.
//!
//! Used by calibration sampling, workload generators, the eval tasks and
//! the property-test harness. Streams are fully determined by the seed so
//! every benchmark table is reproducible bit-for-bit.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vec of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::std_dev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
