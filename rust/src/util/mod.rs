//! Infrastructure substrates built from scratch (no serde/clap/rand/
//! criterion are available offline — see DESIGN.md §3).

pub mod cli;
pub mod hist;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Rng;
pub use timer::Timer;

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking: our metric/state mutexes hold plain counters, so the
/// invariant a poisoning panic could have broken is "a count is one
/// off", which beats killing the worker loop (nbl-lint pass `panic`
/// bans `.lock().unwrap()` on the hot path).
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) of an unsorted slice, linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: a stray NaN from a degenerate timing must not panic
    // the stats path (it sorts last and only perturbs p100)
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let xs = [3.25];
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), 3.25);
        }
        assert_eq!(mean(&xs), 3.25);
        assert_eq!(median(&xs), 3.25);
        assert_eq!(std_dev(&xs), 0.0);
    }

    #[test]
    fn unsorted_input_is_sorted_internally() {
        let shuffled = [4.0, 1.0, 3.0, 2.0];
        let sorted = [1.0, 2.0, 3.0, 4.0];
        for p in [0.0, 10.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&shuffled, p), percentile(&sorted, p));
        }
        assert_eq!(median(&shuffled), 2.5);
        // the input slice itself is untouched (percentile sorts a copy)
        assert_eq!(shuffled, [4.0, 1.0, 3.0, 2.0]);
    }

    #[test]
    fn percentile_interpolates_and_tolerates_nan() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert!((percentile(&xs, 25.0) - 20.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 46.0).abs() < 1e-12);
        // NaN sorts last under total_cmp instead of panicking
        let with_nan = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert_eq!(percentile(&with_nan, 50.0), 2.0);
    }
}
