//! Log-bucketed streaming histogram: O(1)-memory percentile recording.
//!
//! `MetricsHub` used to keep every raw latency sample and sort them on
//! each stats request — unbounded memory on a long-running server and
//! O(n log n) under the hub lock. This histogram holds a fixed 512
//! buckets spaced geometrically over [1e-7, 1e7] (seconds covers ~100ns
//! to ~115 days; the same range serves tok/s rates), so recording is a
//! single index increment and quantiles walk at most 512 counters.
//!
//! Bucket growth factor is 10^(14/512) ≈ 1.065, so a mid-bucket
//! quantile estimate is within ±3.3% of the true sample — tighter than
//! run-to-run serving noise. `min_seen`/`max_seen` clamp the estimate,
//! which makes the 0- and 1-sample cases exact and keeps q0/q100 honest.

const BUCKETS: usize = 512;
const LO: f64 = 1e-7;
const HI: f64 = 1e7;

/// Fixed-size streaming histogram over positive f64 samples.
///
/// Values outside [LO, HI] clamp into the edge buckets (still counted,
/// still min/max-tracked); non-finite and non-positive samples land in
/// bucket 0.
#[derive(Debug, Clone)]
pub struct StreamingHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min_seen: f64,
    max_seen: f64,
}

impl Default for StreamingHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min_seen: f64::INFINITY,
            max_seen: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if !v.is_finite() || v <= LO {
            return 0;
        }
        let span = HI.ln() - LO.ln();
        let idx = ((v.ln() - LO.ln()) / span * BUCKETS as f64) as usize;
        idx.min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` (the representative value a
    /// quantile query reports for samples that landed there).
    fn midpoint(i: usize) -> f64 {
        let span = HI.ln() - LO.ln();
        let l = LO.ln() + span * (i as f64 + 0.5) / BUCKETS as f64;
        l.exp()
    }

    pub fn record(&mut self, v: f64) {
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            self.min_seen = self.min_seen.min(v);
            self.max_seen = self.max_seen.max(v);
        }
    }

    /// Fold another histogram into this one (same fixed bucketing, so
    /// merge is exact: counts add).
    pub fn merge(&mut self, other: &StreamingHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min_seen = self.min_seen.min(other.min_seen);
        self.max_seen = self.max_seen.max(other.max_seen);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    /// p-th quantile (0..=100), same rank convention as
    /// `util::percentile` (rank = p/100 · (n−1)): walk the cumulative
    /// counts to the bucket containing the rank and report its
    /// geometric midpoint, clamped to the observed [min, max] so the
    /// empty slice gives 0.0 and a single sample is exact. p = 100
    /// reports the observed max outright (a clamped-to-edge-bucket
    /// outlier would otherwise report the bucket midpoint).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 || !self.min_seen.is_finite() {
            // min/max update together, so a non-finite min means every
            // sample was non-finite — nothing honest to report (and
            // clamp() would panic on an inverted range)
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        if p >= 100.0 {
            return self.max_seen;
        }
        let rank = (p / 100.0) * (self.count as f64 - 1.0);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum as f64 > rank {
                return Self::midpoint(i).clamp(self.min_seen, self.max_seen);
            }
        }
        self.max_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Rng};

    #[test]
    fn empty_and_single_sample() {
        let mut h = StreamingHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        h.record(0.0375);
        // one sample: min==max clamp makes every quantile exact
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.quantile(p), 0.0375);
        }
        assert!((h.mean() - 0.0375).abs() < 1e-12);
    }

    #[test]
    fn quantiles_track_raw_percentiles_within_bucket_tolerance() {
        // log-uniform samples across five decades: the regime latency
        // distributions live in
        let mut rng = Rng::new(0x517cc1b7);
        let samples: Vec<f64> = (0..4000)
            .map(|_| 10f64.powf(rng.uniform() * 5.0 - 4.0))
            .collect();
        let mut h = StreamingHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let raw = percentile(&samples, p);
            let est = h.quantile(p);
            let rel = (est - raw).abs() / raw.max(1e-12);
            assert!(
                rel < 0.10,
                "p{p}: histogram {est} vs raw {raw} ({:.1}% off)",
                rel * 100.0
            );
        }
        assert!((h.mean() - crate::util::mean(&samples)).abs() / h.mean() < 1e-9);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..500).map(|_| rng.uniform() * 3.0 + 1e-3).collect();
        let (a_half, b_half) = xs.split_at(200);
        let mut a = StreamingHistogram::new();
        let mut b = StreamingHistogram::new();
        let mut whole = StreamingHistogram::new();
        for &x in a_half {
            a.record(x);
        }
        for &x in b_half {
            b.record(x);
        }
        for &x in &xs {
            whole.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.counts, whole.counts);
        for p in [5.0, 50.0, 95.0] {
            assert_eq!(a.quantile(p), whole.quantile(p));
        }
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
    }

    #[test]
    fn degenerate_samples_stay_bounded() {
        let mut h = StreamingHistogram::new();
        h.record(0.0); // non-positive clamps to bucket 0
        h.record(-1.0);
        h.record(f64::NAN); // counted, excluded from sum/min/max
        h.record(1e12); // beyond HI clamps to the top bucket
        assert_eq!(h.count(), 4);
        let q = h.quantile(100.0);
        assert!(q.is_finite());
        assert_eq!(q, 1e12, "max clamp keeps the extreme honest");
    }

    #[test]
    fn all_non_finite_samples_report_zero() {
        let mut h = StreamingHistogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 2);
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(h.quantile(p), 0.0, "no honest value exists at p{p}");
        }
    }
}
