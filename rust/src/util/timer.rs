//! Wall-clock timing helpers used across benches and metrics.

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    #[test]
    fn timed_measures_something() {
        let (v, s) = super::timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(s >= 0.004, "{s}");
    }
}
