//! Minimal JSON parser/writer (serde is not available offline).
//!
//! Covers the full JSON grammar we produce and consume: manifests,
//! goldens, reports, the line-JSON serving protocol. Numbers are f64;
//! integer accessors validate losslessness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value. Objects use BTreeMap for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Json(format!("{}: {}", path.as_ref().display(), e))
        })?;
        Json::parse(&text)
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key '{key}'"))),
            _ => Err(Error::Json(format!("not an object (want key '{key}')"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Json("not a number".into())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 * 4096.0 {
            return Err(Error::Json(format!("not a usize: {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json("not a string".into())),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json("not a bool".into())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json("not an array".into())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json("not an object".into())),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // --------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    pub fn arr_str(xs: impl IntoIterator<Item = String>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Str).collect())
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    // ---------------------------------------------------------------- write

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialized JSON text (`to_string()` comes with it). An inherent
/// `to_string` would shadow this and trip clippy's `inherent_to_string`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, got '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, got '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, got '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                }
                c => {
                    // collect the full utf8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(Error::Json("truncated utf8".into()));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| Error::Json("invalid utf8".into()))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}' at byte {start}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn round_trip() {
        let cases = [
            r#"{"k":[1,2.5,-3],"s":"he\"llo","t":true,"n":null}"#,
            r#"[[],{},[{"a":[]}]]"#,
            "3.141592653589793",
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let j2 = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, j2);
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""Aç日""#).unwrap();
        assert_eq!(j, Json::Str("Aç日".into()));
        let out = Json::Str("tab\there".into()).to_string();
        assert_eq!(out, r#""tab\there""#);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 7, "v": [1.0, 2.0]}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(j.get("v").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0]);
        assert!(j.get("missing").is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
    }
}
