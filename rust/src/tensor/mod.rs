//! Host-side dense f32 tensors (activations, weights).
//!
//! Deliberately minimal: the heavy math runs either in PJRT executables
//! (runtime) or in `linalg::Mat` (calibration). `Tensor` is the typed
//! carrier between those worlds: shape-checked, row-major, convertible
//! to/from XLA literals (see `runtime::literals`).

use crate::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape, data: (0..n).map(&mut f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Slice `[b, t, :]` of a 3-D tensor.
    pub fn at2(&self, b: usize, t: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 3);
        let (d1, d2) = (self.shape[1], self.shape[2]);
        let off = (b * d1 + t) * d2;
        &self.data[off..off + d2]
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn std(&self) -> f32 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.data.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
            / self.data.len() as f32)
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Flatten leading dims: [B,T,D] -> rows of a (B*T, D) view (used to
    /// feed calibration with token-wise rows, paper §3.1 stacking).
    pub fn rows_2d(&self) -> (usize, usize) {
        let d = *self.shape.last().expect("rank >= 1");
        (self.data.len() / d, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing() {
        let t = Tensor::from_fn(vec![2, 3, 4], |i| i as f32);
        assert_eq!(t.at2(1, 2), &[20.0, 21.0, 22.0, 23.0]);
        let m = Tensor::from_fn(vec![2, 3], |i| i as f32);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn reshape_and_rows() {
        let t = Tensor::from_fn(vec![2, 3, 4], |i| i as f32);
        assert_eq!(t.rows_2d(), (6, 4));
        let r = t.reshape(vec![6, 4]).unwrap();
        assert_eq!(r.shape(), &[6, 4]);
        assert!(r.reshape(vec![5, 5]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!((t.mean() - 2.5).abs() < 1e-6);
    }
}
