//! Proposition 3.1 — the closed-form LMMSE estimator.
//!
//! Column-vector convention in the paper: W = C_YX C_XX^{-1},
//! b = E[Y] - W E[X]. The executor's linear block computes row-vector
//! `y_row = x_row @ Wmat + b`, so `Wmat = W^T = C_XX^{-1} C_XY`, i.e. one
//! PSD solve of the normal equations `C_XX · Wmat = C_XY`.

use crate::error::Result;
use crate::linalg::solve_psd;
use crate::stats::SampleStats;

/// Default ridge added to C_XX when it is numerically singular.
pub const DEFAULT_RIDGE: f64 = 1e-8;

/// A fitted linear substitution layer (the executor uploads these as
/// arguments of the `linear_block` executable).
#[derive(Debug, Clone)]
pub struct LinearLayer {
    pub d_in: usize,
    pub d_out: usize,
    /// Row-major [d_in, d_out] so that y = x @ w + b.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl LinearLayer {
    /// Apply on the host (used by tests and the quantization path).
    pub fn apply_row(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.d_in);
        let mut y = self.b.clone();
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &self.w[k * self.d_out..(k + 1) * self.d_out];
            for (o, &wv) in y.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        y
    }
}

/// Fit the LMMSE estimator from finalized statistics.
pub fn lmmse_fit(stats: &SampleStats, ridge: f64) -> Result<LinearLayer> {
    let d = stats.cxx.rows();
    // Wmat = Cxx^{-1} Cxy  (row-vector orientation)
    let wmat = solve_psd(&stats.cxx, &stats.cxy, ridge)?;
    // b = E[Y] - E[X] @ Wmat
    let b: Vec<f32> = (0..d)
        .map(|j| {
            let proj: f64 = (0..d).map(|k| stats.mean_x[k] * wmat[(k, j)]).sum();
            (stats.mean_y[j] - proj) as f32
        })
        .collect();
    Ok(LinearLayer { d_in: d, d_out: d, w: wmat.to_f32(), b })
}

/// Fit against the *residual* output (used by Block-NBL where the whole
/// transformer block including its residual is replaced): y+ = x @ W + b.
pub fn lmmse_fit_residual(stats: &SampleStats, ridge: f64) -> Result<LinearLayer> {
    let (mean_yp, cx_yp, _) = stats.residual_output();
    let d = stats.cxx.rows();
    let wmat = solve_psd(&stats.cxx, &cx_yp, ridge)?;
    let b: Vec<f32> = (0..d)
        .map(|j| {
            let proj: f64 = (0..d).map(|k| stats.mean_x[k] * wmat[(k, j)]).sum();
            (mean_yp[j] - proj) as f32
        })
        .collect();
    Ok(LinearLayer { d_in: d, d_out: d, w: wmat.to_f32(), b })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::GramAccumulator;
    use crate::util::rng::Rng;

    fn make_xy(
        rng: &mut Rng,
        n: usize,
        d: usize,
        f: impl Fn(&[f32], &mut Rng) -> Vec<f32>,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n * d];
        for r in 0..n {
            let xr: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let yr = f(&xr, rng);
            x[r * d..(r + 1) * d].copy_from_slice(&xr);
            y[r * d..(r + 1) * d].copy_from_slice(&yr);
        }
        (x, y)
    }

    fn stats_of(x: &[f32], y: &[f32], d: usize) -> crate::stats::SampleStats {
        let mut acc = GramAccumulator::new(d);
        acc.update(x, y).unwrap();
        acc.finalize().unwrap()
    }

    #[test]
    fn recovers_exact_affine_map() {
        let mut rng = Rng::new(1);
        let d = 6;
        let wt: Vec<f32> = (0..d * d).map(|_| rng.normal_f32() * 0.5).collect();
        let bt: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let wt2 = wt.clone();
        let bt2 = bt.clone();
        let (x, y) = make_xy(&mut rng, 3000, d, move |xr, _| {
            (0..d)
                .map(|j| bt2[j] + (0..d).map(|k| xr[k] * wt2[k * d + j]).sum::<f32>())
                .collect()
        });
        let layer = lmmse_fit(&stats_of(&x, &y, d), 0.0).unwrap();
        for (a, b) in layer.w.iter().zip(&wt) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        for (a, b) in layer.b.iter().zip(&bt) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn orthogonality_principle() {
        // E[(Y - Ŷ)(X - μx)^T] == 0 on the sample (Appendix A.2.1)
        let mut rng = Rng::new(2);
        let d = 5;
        let n = 2000;
        let (x, y) = make_xy(&mut rng, n, d, |xr, rng| {
            (0..d)
                .map(|j| (xr[j] * xr[(j + 1) % d]).tanh() + 0.3 * rng.normal_f32())
                .collect()
        });
        let st = stats_of(&x, &y, d);
        let layer = lmmse_fit(&st, 0.0).unwrap();
        let mut cross = vec![0.0f64; d * d];
        for r in 0..n {
            let xr = &x[r * d..(r + 1) * d];
            let yhat = layer.apply_row(xr);
            for i in 0..d {
                let err = (y[r * d + i] - yhat[i]) as f64;
                for j in 0..d {
                    cross[i * d + j] += err * (xr[j] as f64 - st.mean_x[j]);
                }
            }
        }
        let max = cross.iter().fold(0.0f64, |m, &v| m.max((v / n as f64).abs()));
        assert!(max < 5e-3, "orthogonality violated: {max}");
    }

    #[test]
    fn beats_any_perturbed_linear_map() {
        // LMMSE minimizes MSE among linear estimators: perturbing W must
        // not decrease the sample MSE (up to sampling noise).
        let mut rng = Rng::new(3);
        let d = 4;
        let n = 3000;
        let (x, y) = make_xy(&mut rng, n, d, |xr, rng| {
            (0..d).map(|j| xr[j].sin() + 0.2 * rng.normal_f32()).collect()
        });
        let layer = lmmse_fit(&stats_of(&x, &y, d), 0.0).unwrap();
        let mse = |l: &LinearLayer| -> f64 {
            (0..n)
                .map(|r| {
                    let yh = l.apply_row(&x[r * d..(r + 1) * d]);
                    yh.iter()
                        .zip(&y[r * d..(r + 1) * d])
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                })
                .sum::<f64>()
                / n as f64
        };
        let base = mse(&layer);
        for trial in 0..5 {
            let mut pert = layer.clone();
            let mut prng = Rng::new(100 + trial);
            for w in pert.w.iter_mut() {
                *w += 0.05 * prng.normal_f32();
            }
            assert!(mse(&pert) >= base - 1e-9, "perturbation improved MSE");
        }
    }

    #[test]
    fn residual_fit_matches_delta_fit_plus_identity() {
        // fitting on Y+ = X + Y should equal fitting on Y then adding I
        let mut rng = Rng::new(4);
        let d = 4;
        let (x, y) = make_xy(&mut rng, 2000, d, |xr, rng| {
            (0..d).map(|j| 0.5 * xr[j] + 0.1 * rng.normal_f32()).collect()
        });
        let st = stats_of(&x, &y, d);
        let delta = lmmse_fit(&st, 0.0).unwrap();
        let resid = lmmse_fit_residual(&st, 0.0).unwrap();
        for i in 0..d {
            for j in 0..d {
                let want = delta.w[i * d + j] + if i == j { 1.0 } else { 0.0 };
                assert!((resid.w[i * d + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn apply_row_matches_manual() {
        let layer = LinearLayer {
            d_in: 2,
            d_out: 2,
            w: vec![1.0, 2.0, 3.0, 4.0], // [[1,2],[3,4]]
            b: vec![10.0, 20.0],
        };
        assert_eq!(layer.apply_row(&[1.0, 1.0]), vec![14.0, 26.0]);
    }
}
