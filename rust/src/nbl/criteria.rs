//! Layer-selection criteria (paper §3.2 + ablations F.3/F.4).
//!
//! Every criterion produces a per-layer *score* where lower = more
//! suitable for substitution; `select_lowest` then picks the m best.
//! - `CcaBound` — the paper's criterion: Thm 3.2 NMSE bound.
//! - `CosineDistance` — DROP's criterion: 1 - E[cos(x, y+)] between the
//!   block input and its residual output.
//! - Greedy re-ranking lives in `calibrate::greedy_select` (it needs to
//!   re-run calibration after each substitution).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    CcaBound,
    CosineDistance,
}

impl Criterion {
    pub fn name(self) -> &'static str {
        match self {
            Criterion::CcaBound => "cca-bound",
            Criterion::CosineDistance => "cosine-distance",
        }
    }
}

/// Indices of the `m` lowest-scoring layers (most substitutable first).
pub fn select_lowest(scores: &[f64], m: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    idx.truncate(m);
    idx
}

/// Full importance ranking: most substitutable (lowest score) LAST, i.e.
/// ordered from most- to least-important as in paper Table 20.
pub fn importance_ranking(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    idx
}

/// Streaming mean-cosine-similarity accumulator (DROP criterion).
#[derive(Clone, Default)]
pub struct CosineAccumulator {
    sum: f64,
    n: usize,
}

impl CosineAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// x, yplus: [rows, d] row-major; yplus is the residual output.
    pub fn update(&mut self, x: &[f32], yplus: &[f32], d: usize) {
        let rows = x.len() / d;
        for r in 0..rows {
            let a = &x[r * d..(r + 1) * d];
            let b = &yplus[r * d..(r + 1) * d];
            let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
            for (xa, xb) in a.iter().zip(b) {
                dot += (*xa as f64) * (*xb as f64);
                na += (*xa as f64) * (*xa as f64);
                nb += (*xb as f64) * (*xb as f64);
            }
            let denom = (na.sqrt() * nb.sqrt()).max(1e-30);
            self.sum += dot / denom;
            self.n += 1;
        }
    }

    /// Distance = 1 - mean cosine similarity (lower = more redundant).
    pub fn distance(&self) -> f64 {
        if self.n == 0 {
            return 1.0;
        }
        1.0 - self.sum / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_lowest_picks_minimums() {
        let scores = [5.0, 1.0, 3.0, 0.5, 4.0];
        assert_eq!(select_lowest(&scores, 2), vec![3, 1]);
        assert_eq!(select_lowest(&scores, 0), Vec::<usize>::new());
        assert_eq!(select_lowest(&scores, 5), vec![3, 1, 2, 4, 0]);
    }

    #[test]
    fn ranking_is_reverse_of_selection() {
        let scores = [5.0, 1.0, 3.0];
        assert_eq!(importance_ranking(&scores), vec![0, 2, 1]);
    }

    #[test]
    fn cosine_identical_rows_is_zero_distance() {
        let mut acc = CosineAccumulator::new();
        let x = [1.0f32, 2.0, 3.0, -1.0, 0.5, 2.0];
        acc.update(&x, &x, 3);
        assert!(acc.distance().abs() < 1e-9);
    }

    #[test]
    fn cosine_orthogonal_rows_is_one() {
        let mut acc = CosineAccumulator::new();
        acc.update(&[1.0, 0.0], &[0.0, 1.0], 2);
        assert!((acc.distance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_opposite_rows_is_two() {
        let mut acc = CosineAccumulator::new();
        acc.update(&[1.0, 0.0], &[-1.0, 0.0], 2);
        assert!((acc.distance() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_accumulator_is_max_distance() {
        assert_eq!(CosineAccumulator::new().distance(), 1.0);
    }
}
