//! Algorithm 1 + 2: calibrate every attention layer, compute the CCA
//! bound and LMMSE weights, and build substitution plans.
//!
//! The calibration data flow is decoupled from the execution engine via
//! [`ActivationSource`]: the production implementation is the executor's
//! capture mode (one forward pass per calibration sequence, streaming
//! per-layer (X, Y) token rows into this module); tests drive synthetic
//! sources. Activations are consumed chunk-wise — memory stays
//! O(chunk · d), not O(s·t·d).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::nbl::cca::{cca_bound, CcaAnalysis};
use crate::nbl::criteria::{select_lowest, CosineAccumulator, Criterion};
use crate::nbl::lmmse::{lmmse_fit, lmmse_fit_residual, LinearLayer, DEFAULT_RIDGE};
use crate::nbl::plan::{ModelPlan, PlanKind};
use crate::stats::{GramAccumulator, SampleStats};

/// Anything that can stream per-layer calibration activations.
///
/// For every chunk of token rows, implementations call
/// `sink(layer_idx, x_rows, y_rows)` where `x` is the attention-block
/// input and `y` the attention *delta* (output before the residual add),
/// both `[rows, d]` row-major f32 (paper §3.1 token stacking).
pub trait ActivationSource {
    fn n_layers(&self) -> usize;
    fn d_model(&self) -> usize;
    fn stream(
        &mut self,
        sink: &mut dyn FnMut(usize, &[f32], &[f32]) -> Result<()>,
    ) -> Result<()>;
}

/// Per-layer calibration output (Alg. 2 for one layer).
pub struct LayerCalibration {
    pub layer: usize,
    pub stats: SampleStats,
    pub cca: CcaAnalysis,
    /// DROP-style cosine distance between X and Y+ (ablation F.3).
    pub cosine_distance: f64,
}

impl LayerCalibration {
    pub fn fit_linear(&self) -> Result<LinearLayer> {
        lmmse_fit(&self.stats, DEFAULT_RIDGE)
    }

    pub fn fit_linear_residual(&self) -> Result<LinearLayer> {
        lmmse_fit_residual(&self.stats, DEFAULT_RIDGE)
    }

    pub fn score(&self, criterion: Criterion) -> f64 {
        match criterion {
            Criterion::CcaBound => self.cca.nmse_bound,
            Criterion::CosineDistance => self.cosine_distance,
        }
    }
}

/// Full calibration result for a model (Alg. 1 input).
pub struct CalibrationReport {
    pub layers: Vec<LayerCalibration>,
}

impl CalibrationReport {
    pub fn scores(&self, criterion: Criterion) -> Vec<f64> {
        self.layers.iter().map(|l| l.score(criterion)).collect()
    }

    /// Paper Table 20: layer ids from most to least important.
    pub fn importance_ranking(&self, criterion: Criterion) -> Vec<usize> {
        crate::nbl::criteria::importance_ranking(&self.scores(criterion))
    }

    /// Build "Attn NBL-m": linearize the m most substitutable layers.
    pub fn plan_attn_nbl(&self, m: usize, criterion: Criterion) -> Result<ModelPlan> {
        let mut plan = ModelPlan::baseline(self.layers.len());
        plan.kind = PlanKind::AttnNbl(m);
        for idx in select_lowest(&self.scores(criterion), m) {
            let lin = self.layers[idx].fit_linear()?;
            plan.linearize_attn(idx, Arc::new(lin));
        }
        Ok(plan)
    }

    /// Build "Attn DROP-m" (He et al. 2024 baseline).
    pub fn plan_attn_drop(&self, m: usize, criterion: Criterion) -> ModelPlan {
        let mut plan = ModelPlan::baseline(self.layers.len());
        plan.kind = PlanKind::AttnDrop(m);
        for idx in select_lowest(&self.scores(criterion), m) {
            plan.drop_attn(idx);
        }
        plan
    }
}

/// The calibration driver (Alg. 2 over all layers in one streaming pass).
pub struct Calibrator {
    accs: Vec<GramAccumulator>,
    cosines: Vec<CosineAccumulator>,
    d: usize,
}

impl Calibrator {
    pub fn new(n_layers: usize, d: usize) -> Self {
        Calibrator {
            accs: (0..n_layers).map(|_| GramAccumulator::new(d)).collect(),
            cosines: vec![CosineAccumulator::new(); n_layers],
            d,
        }
    }

    /// Stream everything from `source` and finalize.
    pub fn run(source: &mut dyn ActivationSource) -> Result<CalibrationReport> {
        let mut cal = Calibrator::new(source.n_layers(), source.d_model());
        let d = cal.d;
        let accs = &mut cal.accs;
        let cosines = &mut cal.cosines;
        source.stream(&mut |layer, x, y| {
            if layer >= accs.len() {
                return Err(Error::Calibration(format!("layer {layer} out of range")));
            }
            accs[layer].update(x, y)?;
            // Y+ = X + Y for the cosine criterion
            let yplus: Vec<f32> = x.iter().zip(y).map(|(a, b)| a + b).collect();
            cosines[layer].update(x, &yplus, d);
            Ok(())
        })?;
        cal.finalize()
    }

    pub fn finalize(self) -> Result<CalibrationReport> {
        let d = self.d;
        let mut layers = Vec::with_capacity(self.accs.len());
        let mut any = false;
        for (i, (acc, cos)) in self.accs.into_iter().zip(self.cosines).enumerate() {
            if acc.n < 2 {
                // layer not captured (already substituted under the current
                // plan, e.g. during greedy re-calibration): mark it
                // non-selectable with an infinite bound.
                layers.push(LayerCalibration {
                    layer: i,
                    stats: degenerate_stats(d),
                    cca: CcaAnalysis {
                        rho: vec![],
                        nmse_bound: f64::INFINITY,
                        nmse_bound_per_dim: f64::INFINITY,
                    },
                    cosine_distance: f64::INFINITY,
                });
                continue;
            }
            any = true;
            let stats = acc
                .finalize()
                .map_err(|e| Error::Calibration(format!("layer {i}: {e}")))?;
            let cca = cca_bound(&stats)?;
            layers.push(LayerCalibration {
                layer: i,
                stats,
                cca,
                cosine_distance: cos.distance(),
            });
        }
        if !any {
            return Err(Error::Calibration("no layers captured".into()));
        }
        Ok(CalibrationReport { layers })
    }
}

fn degenerate_stats(d: usize) -> SampleStats {
    SampleStats {
        n: 0,
        mean_x: vec![0.0; d],
        mean_y: vec![0.0; d],
        cxx: crate::linalg::Mat::identity(d),
        cxy: crate::linalg::Mat::zeros(d, d),
        cyy: crate::linalg::Mat::identity(d),
    }
}

/// Greedy iterative selection (ablation F.4): repeatedly re-calibrate the
/// *current* compressed model and linearize the single best remaining
/// layer. `recalibrate(plan)` must run a fresh capture pass under `plan`.
pub fn greedy_select(
    n_layers: usize,
    m: usize,
    mut recalibrate: impl FnMut(&ModelPlan) -> Result<CalibrationReport>,
) -> Result<ModelPlan> {
    let mut plan = ModelPlan::baseline(n_layers);
    plan.kind = PlanKind::Custom(format!("Greedy-{m}"));
    let mut chosen: Vec<usize> = Vec::new();
    for _ in 0..m {
        let report = recalibrate(&plan)?;
        // best remaining layer under the CCA bound
        let mut best: Option<(usize, f64)> = None;
        for lc in &report.layers {
            if chosen.contains(&lc.layer) {
                continue;
            }
            let s = lc.cca.nmse_bound;
            if best.map_or(true, |(_, bs)| s < bs) {
                best = Some((lc.layer, s));
            }
        }
        let (idx, _) = best.ok_or_else(|| Error::Calibration("greedy: no layers left".into()))?;
        let lin = report.layers[idx].fit_linear()?;
        plan.linearize_attn(idx, Arc::new(lin));
        chosen.push(idx);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbl::plan::BlockOp;
    use crate::util::rng::Rng;

    /// Synthetic model: layer i's attention delta is (1-a_i)·linear + a_i·nonlinear.
    /// Higher a_i => less linearizable => higher bound.
    struct SynthSource {
        d: usize,
        alphas: Vec<f64>,
        chunks: usize,
        rows: usize,
        seed: u64,
    }

    impl ActivationSource for SynthSource {
        fn n_layers(&self) -> usize {
            self.alphas.len()
        }

        fn d_model(&self) -> usize {
            self.d
        }

        fn stream(
            &mut self,
            sink: &mut dyn FnMut(usize, &[f32], &[f32]) -> Result<()>,
        ) -> Result<()> {
            let d = self.d;
            for c in 0..self.chunks {
                for (li, &alpha) in self.alphas.iter().enumerate() {
                    let mut rng = Rng::new(self.seed + (c * 31 + li) as u64);
                    let mut wrng = Rng::new(900 + li as u64); // fixed per-layer map
                    let w: Vec<f32> =
                        (0..d * d).map(|_| wrng.normal_f32() * 0.4).collect();
                    let mut x = vec![0.0f32; self.rows * d];
                    let mut y = vec![0.0f32; self.rows * d];
                    for r in 0..self.rows {
                        for j in 0..d {
                            x[r * d + j] = rng.normal_f32();
                        }
                        for j in 0..d {
                            let lin: f32 = (0..d)
                                .map(|k| x[r * d + k] * w[k * d + j])
                                .sum();
                            let nonlin =
                                (x[r * d + j] * x[r * d + (j + 1) % d]).tanh();
                            y[r * d + j] = (1.0 - alpha as f32) * lin
                                + alpha as f32 * 2.0 * nonlin;
                        }
                    }
                    sink(li, &x, &y)?;
                }
            }
            Ok(())
        }
    }

    fn source(alphas: &[f64]) -> SynthSource {
        SynthSource { d: 8, alphas: alphas.to_vec(), chunks: 4, rows: 400, seed: 5 }
    }

    #[test]
    fn ranking_tracks_linearity() {
        let mut src = source(&[0.9, 0.1, 0.5, 0.0]);
        let report = Calibrator::run(&mut src).unwrap();
        let order = select_lowest(&report.scores(Criterion::CcaBound), 4);
        // most linearizable first: layer 3 (alpha 0), then 1, 2, 0
        assert_eq!(order, vec![3, 1, 2, 0]);
    }

    #[test]
    fn plan_attn_nbl_substitutes_lowest() {
        let mut src = source(&[0.9, 0.1, 0.5, 0.0]);
        let report = Calibrator::run(&mut src).unwrap();
        let plan = report.plan_attn_nbl(2, Criterion::CcaBound).unwrap();
        assert_eq!(plan.kv_layers(), 2);
        assert!(matches!(plan.layers[3].attn, BlockOp::Linear(_)));
        assert!(matches!(plan.layers[1].attn, BlockOp::Linear(_)));
        assert!(matches!(plan.layers[0].attn, BlockOp::Attention));
        assert_eq!(plan.kind.label(), "Attn NBL-2");
    }

    #[test]
    fn plan_attn_drop_drops() {
        let mut src = source(&[0.9, 0.0]);
        let report = Calibrator::run(&mut src).unwrap();
        let plan = report.plan_attn_drop(1, Criterion::CcaBound);
        assert!(matches!(plan.layers[1].attn, BlockOp::Identity));
        assert_eq!(plan.kv_layers(), 1);
    }

    #[test]
    fn fitted_linear_layer_has_low_error_on_linear_layer() {
        let mut src = source(&[0.0, 1.0]);
        let report = Calibrator::run(&mut src).unwrap();
        let lin = report.layers[0].fit_linear().unwrap();
        // replay a fresh sample through the fitted layer
        let mut rng = Rng::new(77);
        let mut wrng = Rng::new(900);
        let d = 8;
        let w: Vec<f32> = (0..d * d).map(|_| wrng.normal_f32() * 0.4).collect();
        let mut max_err = 0.0f32;
        for _ in 0..200 {
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want: Vec<f32> = (0..d)
                .map(|j| (0..d).map(|k| x[k] * w[k * d + j]).sum())
                .collect();
            let got = lin.apply_row(&x);
            for (g, wv) in got.iter().zip(&want) {
                max_err = max_err.max((g - wv).abs());
            }
        }
        assert!(max_err < 0.05, "max err {max_err}");
    }

    #[test]
    fn cosine_scores_are_valid_but_differ_from_cca() {
        // The two criteria measure different things (paper F.3): cosine
        // only sees how much the block *moves* the stream, CCA sees how
        // linearly predictable the move is. Both must produce valid
        // scores; only CCA is required to rank by linearizability.
        let mut src = source(&[0.95, 0.0]);
        let report = Calibrator::run(&mut src).unwrap();
        assert_eq!(select_lowest(&report.scores(Criterion::CcaBound), 2)[0], 1);
        for s in report.scores(Criterion::CosineDistance) {
            assert!((0.0..=2.0).contains(&s), "cosine distance {s}");
        }
    }

    #[test]
    fn greedy_selects_m_layers() {
        let plan = greedy_select(4, 2, |_plan| {
            let mut src = source(&[0.9, 0.1, 0.5, 0.0]);
            Calibrator::run(&mut src)
        })
        .unwrap();
        assert_eq!(plan.kv_layers(), 2);
        assert!(matches!(plan.layers[3].attn, BlockOp::Linear(_)));
        assert!(matches!(plan.layers[1].attn, BlockOp::Linear(_)));
    }

    #[test]
    fn empty_source_errors() {
        struct Empty;
        impl ActivationSource for Empty {
            fn n_layers(&self) -> usize {
                2
            }
            fn d_model(&self) -> usize {
                4
            }
            fn stream(
                &mut self,
                _sink: &mut dyn FnMut(usize, &[f32], &[f32]) -> Result<()>,
            ) -> Result<()> {
                Ok(())
            }
        }
        assert!(Calibrator::run(&mut Empty).is_err());
    }
}
