//! The paper's core contribution: Neural Block Linearization.
//!
//! - [`cca`]      — Theorem 3.2: the CCA-based NMSE upper bound.
//! - [`lmmse`]    — Proposition 3.1: the closed-form linear estimator.
//! - [`criteria`] — layer-selection criteria (CCA bound / cosine / greedy).
//! - [`plan`]     — per-layer substitution plans consumed by the executor.
//! - [`calibrate`]— Algorithm 1/2: drive capture → stats → bound + weights.

pub mod calibrate;
pub mod cca;
pub mod criteria;
pub mod lmmse;
pub mod plan;

pub use calibrate::{CalibrationReport, Calibrator, LayerCalibration};
pub use cca::{cca_bound, CcaAnalysis};
pub use criteria::Criterion;
pub use lmmse::{lmmse_fit, LinearLayer};
pub use plan::{BlockOp, LayerPlan, PlanKind};
