//! Substitution plans: what the executor runs per layer.
//!
//! A plan assigns every transformer layer an attention op and an MLP op.
//! NBL, the DROP/SLEB baselines and SliceGPT-like all reduce to plans, so
//! the serving engine, KV manager and eval harness are agnostic to *how*
//! a compression method was derived.

use std::sync::Arc;

use crate::nbl::lmmse::LinearLayer;

/// What runs in a layer's attention slot.
#[derive(Debug, Clone)]
pub enum BlockOp {
    /// Original softmax attention (allocates KV cache).
    Attention,
    /// NBL linear substitution: x + xW + b (no KV cache).
    Linear(Arc<LinearLayer>),
    /// Attn-DROP: the block is removed entirely (identity).
    Identity,
}

impl BlockOp {
    pub fn needs_kv(&self) -> bool {
        matches!(self, BlockOp::Attention)
    }

    pub fn short(&self) -> &'static str {
        match self {
            BlockOp::Attention => "attn",
            BlockOp::Linear(_) => "nbl",
            BlockOp::Identity => "drop",
        }
    }
}

/// What runs in a layer's MLP slot.
#[derive(Debug, Clone, PartialEq)]
pub enum MlpOp {
    Mlp,
    /// Removed (Block-DROP / SLEB / Block-NBL fold the whole block).
    Identity,
}

#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub attn: BlockOp,
    pub mlp: MlpOp,
}

/// Descriptor of how a plan was produced (report labels).
#[derive(Debug, Clone, PartialEq)]
pub enum PlanKind {
    Baseline,
    AttnNbl(usize),
    AttnDrop(usize),
    BlockNbl(usize),
    BlockDrop(usize),
    Sleb(usize),
    SliceGpt(u32), // percent
    Custom(String),
}

impl PlanKind {
    pub fn label(&self) -> String {
        match self {
            PlanKind::Baseline => "Baseline".into(),
            PlanKind::AttnNbl(m) => format!("Attn NBL-{m}"),
            PlanKind::AttnDrop(m) => format!("Attn DROP-{m}"),
            PlanKind::BlockNbl(m) => format!("Block NBL-{m}"),
            PlanKind::BlockDrop(m) => format!("Block DROP-{m}"),
            PlanKind::Sleb(m) => format!("SLEB-{m}"),
            PlanKind::SliceGpt(p) => format!("SliceGPT-{p}%"),
            PlanKind::Custom(s) => s.clone(),
        }
    }
}

/// A full per-model substitution plan.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub kind: PlanKind,
    pub layers: Vec<LayerPlan>,
}

impl ModelPlan {
    pub fn baseline(n_layers: usize) -> ModelPlan {
        ModelPlan {
            kind: PlanKind::Baseline,
            layers: (0..n_layers)
                .map(|_| LayerPlan { attn: BlockOp::Attention, mlp: MlpOp::Mlp })
                .collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layers that still need a KV cache.
    pub fn kv_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.attn.needs_kv()).count()
    }

    /// The paper's KV saving factor (K-m)/K (§4.2).
    pub fn kv_fraction(&self) -> f64 {
        self.kv_layers() as f64 / self.n_layers() as f64
    }

    /// Replace attention with a fitted linear layer at `idx`.
    pub fn linearize_attn(&mut self, idx: usize, layer: Arc<LinearLayer>) {
        self.layers[idx].attn = BlockOp::Linear(layer);
    }

    /// Remove the attention block at `idx` (Attn-DROP).
    pub fn drop_attn(&mut self, idx: usize) {
        self.layers[idx].attn = BlockOp::Identity;
    }

    /// Remove an entire transformer block (SLEB / Block-DROP).
    pub fn drop_block(&mut self, idx: usize) {
        self.layers[idx].attn = BlockOp::Identity;
        self.layers[idx].mlp = MlpOp::Identity;
    }

    /// Replace an entire block with a residual-fitted linear layer.
    pub fn linearize_block(&mut self, idx: usize, layer: Arc<LinearLayer>) {
        self.layers[idx].attn = BlockOp::Linear(layer);
        self.layers[idx].mlp = MlpOp::Identity;
    }

    /// Human-readable layer map, e.g. "attn attn nbl drop ...".
    pub fn describe(&self) -> String {
        self.layers
            .iter()
            .map(|l| {
                let mut s = l.attn.short().to_string();
                if l.mlp == MlpOp::Identity {
                    s.push_str("-nomlp");
                }
                s
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear(d: usize) -> Arc<LinearLayer> {
        Arc::new(LinearLayer { d_in: d, d_out: d, w: vec![0.0; d * d], b: vec![0.0; d] })
    }

    #[test]
    fn baseline_all_attention() {
        let p = ModelPlan::baseline(6);
        assert_eq!(p.kv_layers(), 6);
        assert_eq!(p.kv_fraction(), 1.0);
        assert_eq!(p.kind.label(), "Baseline");
    }

    #[test]
    fn kv_accounting_follows_substitutions() {
        let mut p = ModelPlan::baseline(6);
        p.linearize_attn(1, linear(4));
        p.drop_attn(3);
        assert_eq!(p.kv_layers(), 4);
        assert!((p.kv_fraction() - 4.0 / 6.0).abs() < 1e-12);
        p.drop_block(5);
        assert_eq!(p.kv_layers(), 3);
        assert_eq!(p.layers[5].mlp, MlpOp::Identity);
    }

    #[test]
    fn labels() {
        assert_eq!(PlanKind::AttnNbl(8).label(), "Attn NBL-8");
        assert_eq!(PlanKind::SliceGpt(25).label(), "SliceGPT-25%");
    }

    #[test]
    fn describe_is_stable() {
        let mut p = ModelPlan::baseline(3);
        p.linearize_block(2, linear(2));
        assert_eq!(p.describe(), "attn attn nbl-nomlp");
    }
}
