//! Canonical Correlation Analysis and the Theorem 3.2 error bound.
//!
//! For a layer with input X and residual output Y+ = Y + X, the canonical
//! correlations ρ_i are the singular values of the standardized
//! cross-correlation matrix
//!
//! ```text
//! C_W = C_{Y+Y+}^{-1/2} · C_{Y+X} · C_{XX}^{-1/2}
//! ```
//!
//! and the linearization NMSE obeys (Thm. 3.2, with h_in = h_out = d):
//!
//! ```text
//! NMSE(Y, Ŷ) ≤ Σ_i (1 - ρ_i²)
//! ```
//!
//! Following Alg. 2 the bound is computed on the *residual* output Y+
//! while the LMMSE weights are fitted on the raw delta Y (the residual
//! connection is kept in the substituted block).

use crate::error::Result;
use crate::linalg::{inv_sqrt_psd, singular_values, Mat};
use crate::stats::SampleStats;

/// Eigenvalue floor for the inverse square roots (ridge against
/// rank-deficient calibration covariance).
pub const EIG_FLOOR: f64 = 1e-9;

#[derive(Debug, Clone)]
pub struct CcaAnalysis {
    /// Canonical correlations, descending, clamped to [0, 1].
    pub rho: Vec<f64>,
    /// Theorem 3.2 upper bound on the NMSE: Σ (1 - ρ_i²).
    pub nmse_bound: f64,
    /// Bound normalized to [0, 1] by d (convenient for plots; Fig. 2).
    pub nmse_bound_per_dim: f64,
}

/// Run CCA between X and the residual output Y+ derived from `stats`.
pub fn cca_bound(stats: &SampleStats) -> Result<CcaAnalysis> {
    let (_mean_yp, cx_yp, cyp_yp) = stats.residual_output();
    cca_from_parts(&stats.cxx, &cx_yp, &cyp_yp)
}

/// CCA from explicit covariance blocks: C_XX, C_{X,Y}, C_{YY}.
pub fn cca_from_parts(cxx: &Mat, cxy: &Mat, cyy: &Mat) -> Result<CcaAnalysis> {
    let isq_x = inv_sqrt_psd(cxx, EIG_FLOOR)?;
    let isq_y = inv_sqrt_psd(cyy, EIG_FLOOR)?;
    // C_W = Cyy^-1/2 · Cyx · Cxx^-1/2  (cyx = cxy^T)
    let cw = isq_y.matmul(&cxy.transpose()).matmul(&isq_x);
    let mut rho = singular_values(&cw)?;
    for r in rho.iter_mut() {
        *r = r.clamp(0.0, 1.0);
    }
    let nmse_bound: f64 = rho.iter().map(|r| 1.0 - r * r).sum();
    let d = rho.len().max(1);
    Ok(CcaAnalysis {
        nmse_bound,
        nmse_bound_per_dim: nmse_bound / d as f64,
        rho,
    })
}

/// The *achieved* NMSE of the LMMSE estimator from covariance blocks
/// (Appendix C, Eq. 12): MSE = Tr(Cyy - Cyx Cxx^-1 Cxy), NMSE = MSE/Tr(Cyy).
/// Used by tests to verify bound ≥ achieved, and by the greedy ablation.
pub fn achieved_nmse(cxx: &Mat, cxy: &Mat, cyy: &Mat) -> Result<f64> {
    let w = crate::linalg::solve_psd(cxx, cxy, 1e-10)?; // Cxx^-1 Cxy
    let explained = cxy.transpose().matmul(&w); // Cyx Cxx^-1 Cxy
    let mse = cyy.trace() - explained.trace();
    Ok((mse / cyy.trace().max(1e-300)).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::GramAccumulator;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Rng;

    /// Build stats from synthetic rows y = x W + b + noise.
    fn synth_stats(rng: &mut Rng, n: usize, d: usize, noise: f32) -> SampleStats {
        let w: Vec<f32> = (0..d * d).map(|_| rng.normal_f32() * 0.4).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut acc = GramAccumulator::new(d);
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n * d];
        for r in 0..n {
            for j in 0..d {
                x[r * d + j] = rng.normal_f32();
            }
            for j in 0..d {
                let mut s = b[j];
                for k in 0..d {
                    s += x[r * d + k] * w[k * d + j];
                }
                y[r * d + j] = s + noise * rng.normal_f32();
            }
        }
        acc.update(&x, &y).unwrap();
        acc.finalize().unwrap()
    }

    #[test]
    fn perfectly_linear_gives_tiny_bound() {
        let mut rng = Rng::new(1);
        let st = synth_stats(&mut rng, 2000, 8, 0.0);
        let c = cca_bound(&st).unwrap();
        assert!(c.nmse_bound < 1e-4, "bound {}", c.nmse_bound);
        assert!(c.rho.iter().all(|&r| r > 0.999));
    }

    #[test]
    fn pure_noise_gives_large_bound() {
        // Y independent of X: Y+ = X + noise still correlates via the
        // residual, so test the raw (X, Y) pair instead.
        let mut rng = Rng::new(2);
        let d = 6;
        let n = 4000;
        let mut acc = GramAccumulator::new(d);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
        acc.update(&x, &y).unwrap();
        let st = acc.finalize().unwrap();
        let c = cca_from_parts(&st.cxx, &st.cxy, &st.cyy).unwrap();
        // each 1-ρ² near 1 → bound near d
        assert!(c.nmse_bound > 0.8 * d as f64, "bound {}", c.nmse_bound);
    }

    #[test]
    fn bound_dominates_achieved_nmse() {
        // Theorem 3.2: bound >= achieved, across noise levels
        check(
            5,
            10,
            |g: &mut Gen| {
                let d = g.usize_in(3, (10 >> g.shrink.min(2)).max(3));
                let noise = g.rng.range_f64(0.0, 2.0) as f32;
                (d, noise, g.rng.next_u64())
            },
            |&(d, noise, seed)| {
                let mut rng = Rng::new(seed);
                let st = synth_stats(&mut rng, 3000, d, noise);
                let c = cca_from_parts(&st.cxx, &st.cxy, &st.cyy)
                    .map_err(|e| e.to_string())?;
                let ach = achieved_nmse(&st.cxx, &st.cxy, &st.cyy)
                    .map_err(|e| e.to_string())?;
                // allow small sampling slack
                if c.nmse_bound + 1e-3 < ach {
                    return Err(format!("bound {} < achieved {}", c.nmse_bound, ach));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bound_monotone_in_noise() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let low = cca_from_parts_of(&synth_stats(&mut r1, 3000, 6, 0.1));
        let high = cca_from_parts_of(&synth_stats(&mut r2, 3000, 6, 1.5));
        assert!(low < high, "low {low} high {high}");
    }

    fn cca_from_parts_of(st: &SampleStats) -> f64 {
        cca_from_parts(&st.cxx, &st.cxy, &st.cyy).unwrap().nmse_bound
    }

    #[test]
    fn rho_clamped_and_bound_in_range() {
        let mut rng = Rng::new(11);
        let st = synth_stats(&mut rng, 500, 5, 0.5);
        let c = cca_bound(&st).unwrap();
        assert!(c.rho.iter().all(|&r| (0.0..=1.0).contains(&r)));
        assert!(c.nmse_bound >= 0.0 && c.nmse_bound <= 5.0 + 1e-9);
        assert!((c.nmse_bound_per_dim - c.nmse_bound / 5.0).abs() < 1e-12);
    }
}
