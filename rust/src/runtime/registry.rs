//! The PJRT client + lazy executable registry.
//!
//! Executables compile on first use and are cached for the process
//! lifetime; `warm_up` pre-compiles a given op list (the serving engine
//! warms the decode-critical set at startup so TTFT is not polluted by
//! compilation).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::model::artifacts::Artifacts;
use crate::util::timer::Timer;

/// Argument to `run_mixed`: host literal (uploaded per call) or a
/// pre-uploaded device buffer.
pub enum ArgRef<'a> {
    Lit(&'a xla::Literal),
    Buf(&'a HeldBuffer),
}

/// A device buffer plus the host literal backing its (asynchronous)
/// transfer — see [`Runtime::upload`].
pub struct HeldBuffer {
    _lit: xla::Literal,
    buf: xla::PjRtBuffer,
}

impl HeldBuffer {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// (op, compile_seconds) log for §Perf.
    compile_log: Mutex<Vec<(String, f64)>>,
}

// SAFETY: the PJRT client and executables are internally synchronized
// by the C runtime; the Rust wrapper just holds opaque pointers, and
// the mutable caches sit behind their own mutexes.
#[allow(unsafe_code)]
unsafe impl Send for Runtime {}
// SAFETY: see the Send impl above.
#[allow(unsafe_code)]
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn new(artifacts: Artifacts) -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Runtime {
            client,
            artifacts,
            cache: Mutex::new(HashMap::new()),
            compile_log: Mutex::new(Vec::new()),
        }))
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Get (compiling if needed) the executable for an op stem.
    pub fn executable(&self, op: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(op) {
            return Ok(exe.clone());
        }
        let path = self.artifacts.hlo_path(op)?;
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        let secs = t.elapsed_s();
        log::debug!("compiled {op} in {secs:.2}s");
        self.compile_log.lock().unwrap().push((op.to_string(), secs));
        // double-compile under race is harmless; last one wins
        self.cache.lock().unwrap().insert(op.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a list of ops (startup warm-up).
    pub fn warm_up(&self, ops: &[String]) -> Result<f64> {
        let t = Timer::start();
        for op in ops {
            self.executable(op)?;
        }
        Ok(t.elapsed_s())
    }

    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.lock().unwrap().clone()
    }

    /// Run an op with literal args; returns the decomposed output tuple.
    ///
    /// NOTE: this goes through `execute_b` with buffers we own, NOT
    /// `execute`: the crate's C-side `execute` leaks every input buffer
    /// (`BufferFromHostLiteral(...).release()` with no delete —
    /// xla_rs.cc:900), which grows the heap by ~1 MB per decode step and
    /// degrades throughput over the process lifetime (measured in
    /// EXPERIMENTS.md §Perf). Rust-owned `PjRtBuffer`s drop cleanly.
    pub fn run(&self, op: &str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(op)?;
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()?;
        self.run_buffers(op, &exe, &bufs)
    }

    /// Run an op with pre-uploaded device buffers (the hot path: weight
    /// buffers are cached per engine and reused across calls).
    pub fn run_buffers(
        &self,
        op: &str,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        let out = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let first = out
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| Error::Xla(format!("{op}: no output buffer")))?;
        let mut lit = first.to_literal_sync()?;
        // AOT lowering uses return_tuple=True: root is always a tuple
        lit.decompose_tuple().map_err(Into::into)
    }

    /// Upload a literal to a device buffer (cached-weights path).
    ///
    /// SAFETY NOTE: `BufferFromHostLiteral` transfers asynchronously on a
    /// worker thread — the source literal MUST outlive the transfer. We
    /// return a [`HeldBuffer`] that owns the literal for the buffer's
    /// whole lifetime (freeing it early is a use-after-free that
    /// manifests as a tfrt size-check abort).
    pub fn upload(&self, lit: xla::Literal) -> Result<HeldBuffer> {
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        // force the async transfer to complete: a buffer dropped (or a
        // literal freed) while its transfer is still in flight segfaults
        // in the tfrt worker. ToLiteralSync blocks on buffer readiness.
        let _ = buf.to_literal_sync()?;
        Ok(HeldBuffer { _lit: lit, buf })
    }

    /// Run with a mix of literal args (uploaded now) and pre-uploaded
    /// buffers (the engine's cached weights) — §Perf iteration 2: weights
    /// are uploaded once per engine instead of once per op call.
    pub fn run_mixed(&self, op: &str, args: &[ArgRef<'_>]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(op)?;
        let owned: Vec<Option<xla::PjRtBuffer>> = args
            .iter()
            .map(|a| match a {
                ArgRef::Lit(l) => self.client.buffer_from_host_literal(None, l).map(Some),
                ArgRef::Buf(_) => Ok(None),
            })
            .collect::<std::result::Result<_, _>>()?;
        let refs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .zip(&owned)
            .map(|(a, o)| match a {
                ArgRef::Lit(_) => o.as_ref().unwrap(),
                ArgRef::Buf(b) => b.buffer(),
            })
            .collect();
        let out = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let first = out
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| Error::Xla(format!("{op}: no output buffer")))?;
        let mut lit = first.to_literal_sync()?;
        lit.decompose_tuple().map_err(Into::into)
    }
}
