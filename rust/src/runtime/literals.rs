//! Tensor <-> xla::Literal conversions.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Upload a host tensor into an f32 literal with its shape.
pub fn lit_from_tensor(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(Into::into)
}

/// Upload a raw f32 slice with an explicit shape.
pub fn lit_from_slice(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Shape(format!(
            "literal: {} elems for shape {shape:?}",
            data.len()
        )));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(Into::into)
}

/// Scalar i32 literal (the `pos` argument of cached attention).
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// i32 vector literal (the per-row `pos` argument of the rows-decode op).
pub fn lit_i32_vec(vals: &[i32]) -> xla::Literal {
    xla::Literal::vec1(vals)
}

/// Download a literal into a Tensor (f32).
pub fn tensor_from_lit(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let t = Tensor::from_fn(vec![2, 3, 4], |i| i as f32 * 0.5);
        let lit = lit_from_tensor(&t).unwrap();
        let back = tensor_from_lit(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_from_slice(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn scalar_i32() {
        let l = lit_scalar_i32(42);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 42);
    }

    #[test]
    fn i32_vec() {
        let l = lit_i32_vec(&[3, 1, 4]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![3, 1, 4]);
    }
}
