//! PJRT runtime: client + lazily-compiled executable registry.
//!
//! Loads HLO-text artifacts (AOT-lowered by `python/compile/aot.py`),
//! compiles them on the PJRT CPU client on first use, and provides typed
//! helpers for Tensor <-> Literal conversion.
//!
//! Findings baked into the design (see rust/src/bin/probe_pjrt.rs):
//! - tuple-rooted executables return ONE tuple buffer on this PJRT build,
//!   so multi-output results round-trip through `Literal::decompose_tuple`
//!   (a host memcpy on the CPU backend — measured in §Perf);
//! - `execute::<&Literal>` lets us pass cached weight literals without
//!   cloning.

pub mod literals;
pub mod registry;

pub use literals::{lit_from_tensor, lit_i32_vec, lit_scalar_i32, tensor_from_lit};
pub use registry::Runtime;
