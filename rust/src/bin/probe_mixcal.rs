//! Does calibrating on the full pretraining mix (c4+wiki) improve NBL?
use nbl::data::corpus::{Corpus, CorpusId};
use nbl::executor::CaptureSource;
use nbl::nbl::calibrate::Calibrator;
use nbl::nbl::criteria::Criterion;
use nbl::bench::experiments::{ExpConfig, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::new("main", ExpConfig::full()).unwrap();
    let artifacts = nbl::model::Artifacts::discover().unwrap();
    let wiki = Corpus::load(&artifacts, CorpusId::TinyWiki, "train").unwrap();
    // mixed-token stream: interleave c4 + wiki
    let mut mixed = wb.calib.tokens.clone();
    mixed.extend(&wiki.tokens);
    let mut src = CaptureSource::new(&wb.engine, &mixed, 48, 128);
    let report = Calibrator::run(&mut src).unwrap();
    for m in [3usize, 4] {
        let plan = report.plan_attn_nbl(m, Criterion::CcaBound).unwrap();
        let e = wb.engine.with_plan(plan).unwrap();
        let acc = wb.accuracy(&e).unwrap();
        let per: Vec<String> = acc
            .tasks
            .iter()
            .map(|t| format!("{}:{:.2}", t.name, t.accuracy))
            .collect();
        println!("mixcal m={m} avg {:.3} [{}]", acc.avg_accuracy, per.join(" "));
    }
    Ok(())
}
