//! One-off probe: how does the PJRT CPU client hand back multi-output
//! (tuple-rooted) executables, and can outputs be chained via execute_b?
//! Kept as a diagnostic binary (`cargo run --bin probe_pjrt`).

use anyhow::Result;

fn main() -> Result<()> {
    let art = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let client = xla::PjRtClient::cpu()?;
    println!("platform={}", client.platform_name());

    // multi-output op: cache_init (k,v) -> (kcache, vcache)
    let proto =
        xla::HloModuleProto::from_text_file(format!("{art}/hlo/cache_init_b1_t32.hlo.txt"))?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let k = xla::Literal::vec1(&vec![1f32; 32 * 2 * 32]).reshape(&[1, 32, 2, 32])?;
    let v = xla::Literal::vec1(&vec![2f32; 32 * 2 * 32]).reshape(&[1, 32, 2, 32])?;
    let out = exe.execute::<xla::Literal>(&[k, v])?;
    println!("replicas={} buffers={}", out.len(), out[0].len());
    for (i, b) in out[0].iter().enumerate() {
        println!("  out[{i}] shape={:?}", b.on_device_shape()?);
    }

    // if single tuple buffer: decompose via literal
    if out[0].len() == 1 {
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        println!("tuple parts={}", parts.len());
        for p in &parts {
            println!("  part shape={:?}", p.array_shape()?);
        }
    }

    // chaining: feed an output buffer into execute_b of linear_block
    let proto2 =
        xla::HloModuleProto::from_text_file(format!("{art}/hlo/linear_block_b1_t1.hlo.txt"))?;
    let exe2 = client.compile(&xla::XlaComputation::from_proto(&proto2))?;
    let x = xla::Literal::vec1(&vec![0.5f32; 128]).reshape(&[1, 1, 128])?;
    let w = xla::Literal::vec1(&vec![0.0f32; 128 * 128]).reshape(&[128, 128])?;
    let b = xla::Literal::vec1(&vec![1.0f32; 128]).reshape(&[128])?;
    let out2 = exe2.execute::<xla::Literal>(&[x, w, b])?;
    println!("linear out buffers={}", out2[0].len());

    // re-run feeding buffers (chain)
    let devices = client.addressable_devices();
    let device = &devices[0];
    let xb = client.buffer_from_host_literal(
        Some(device),
        &xla::Literal::vec1(&vec![0.5f32; 128]).reshape(&[1, 1, 128])?,
    )?;
    let wb = client.buffer_from_host_literal(
        Some(device),
        &xla::Literal::vec1(&vec![0.0f32; 128 * 128]).reshape(&[128, 128])?,
    )?;
    let bb = client.buffer_from_host_literal(
        Some(device),
        &xla::Literal::vec1(&vec![1.0f32; 128]).reshape(&[128])?,
    )?;
    let out3 = exe2.execute_b(&[&xb, &wb, &bb])?;
    println!("execute_b ok, buffers={}", out3[0].len());
    let lit3 = out3[0][0].to_literal_sync()?;
    println!("result ty={:?}", lit3.shape()?);
    Ok(())
}
