//! Diagnostic: per-task accuracy of each model (chance vs signal).

use nbl::executor::Engine;
use nbl::model::Artifacts;
use nbl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::discover()?;
    let runtime = Runtime::new(artifacts)?;
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    for model in ["main"] {
        let engine = Engine::load(runtime.clone(), model)?;
        let summary = nbl::eval::evaluate_all(&engine, nbl::eval::all_tasks(), n, 99)?;
        println!("== {model} ==");
        for t in &summary.tasks {
            println!("  {:<12} {:.3}", t.name, t.accuracy);
        }
        println!("  avg {:.3} ± {:.3}", summary.avg_accuracy, summary.pooled_se);
    }
    Ok(())
}
