//! Compare NBL/DROP on matched layer sets: isolates criterion choice from
//! substitution quality.
use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::nbl::criteria::Criterion;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::new("main", ExpConfig::full()).unwrap();
    let cca: Vec<f64> = wb
        .report
        .scores(Criterion::CcaBound)
        .iter()
        .map(|x| (x * 100.0).round() / 100.0)
        .collect();
    let cos: Vec<f64> = wb
        .report
        .scores(Criterion::CosineDistance)
        .iter()
        .map(|x| (x * 1000.0).round() / 1000.0)
        .collect();
    println!("cca scores:    {cca:?}");
    println!("cosine scores: {cos:?}");
    for m in [3usize] {
        for (label, plan) in [
            ("NBL(cca)", wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap()),
            ("NBL(cos)", wb.report.plan_attn_nbl(m, Criterion::CosineDistance).unwrap()),
            ("DROP(cca)", wb.report.plan_attn_drop(m, Criterion::CcaBound)),
            ("DROP(cos)", wb.report.plan_attn_drop(m, Criterion::CosineDistance)),
        ] {
            let layers = plan.describe();
            let e = wb.engine.with_plan(plan).unwrap();
            let acc = wb.accuracy(&e).unwrap();
            let per: Vec<String> = acc
                .tasks
                .iter()
                .map(|t| format!("{}:{:.2}", t.name, t.accuracy))
                .collect();
            println!(
                "m={m} {label:<10} avg {:.3} [{}] ({})",
                acc.avg_accuracy,
                per.join(" "),
                layers
            );
        }
    }
    Ok(())
}
