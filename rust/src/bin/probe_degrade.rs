//! Diagnose process-lifetime slowdown: measure baseline speed, run work,
//! measure again.
use nbl::bench::experiments::{measure_speed, ExpConfig, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::new("main", ExpConfig::fast()).unwrap();
    let s0 = measure_speed(&wb.engine, &wb.calib.tokens, 128, 32, 3).unwrap();
    println!("before: prefill {:.0} decode {:.0}", s0.prefill_tok_s, s0.decode_tok_s);
    for i in 0..4 {
        let _ = wb.accuracy(&wb.engine).unwrap();
        let s = measure_speed(&wb.engine, &wb.calib.tokens, 128, 32, 3).unwrap();
        println!("after eval {}: prefill {:.0} decode {:.0}", i, s.prefill_tok_s, s.decode_tok_s);
    }
    Ok(())
}
