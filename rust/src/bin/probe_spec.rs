use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::executor::Engine;
use nbl::nbl::criteria::Criterion;
use nbl::runtime::Runtime;
use nbl::spec::SpeculativeDecoder;
use nbl::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::new("main", ExpConfig::fast()).unwrap();
    let artifacts = nbl::model::Artifacts::discover().unwrap();
    let runtime = Runtime::new(artifacts).unwrap();
    let draft = Engine::load(runtime, "draft").unwrap();
    let prompt = &wb.calib.tokens[..64];
    for m in [2usize, 3] {
        let plan = wb.report.plan_attn_nbl(m, Criterion::CcaBound).unwrap();
        let target = wb.engine.with_plan(plan).unwrap();
        for rep in 0..4 {
            let dec = SpeculativeDecoder::new(&target, &draft, 4);
            let t = Timer::start();
            let (_, stats) = dec.generate(prompt, 48).unwrap();
            println!(
                "m={m} rep={rep} {:.3}s rounds={} draft={} acc={:.2}",
                t.elapsed_s(),
                stats.rounds,
                stats.draft_steps,
                stats.acceptance_rate()
            );
        }
    }
    Ok(())
}
