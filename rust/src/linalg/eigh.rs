//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Jacobi is O(d^3) per sweep with quadratic convergence once nearly
//! diagonal — robust and simple, which matters here because the CCA chain
//! (Alg. 2) feeds it covariance matrices with eigenvalue spreads of 1e8+.
//! For the d <= 1024 sizes of Table 1/7 this is fast enough on one core
//! (bench_calibration measures the scaling the paper reports).

use crate::error::{Error, Result};
use crate::linalg::Mat;

pub struct EighResult {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Column j of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Mat,
}

const MAX_SWEEPS: usize = 64;

pub fn eigh(a: &Mat) -> Result<EighResult> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Linalg("eigh: not square".into()));
    }
    if n == 0 {
        return Ok(EighResult { values: vec![], vectors: Mat::zeros(0, 0) });
    }
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::identity(n);
    let scale = m.max_abs().max(1e-300);
    let tol = 1e-14 * scale;

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off = off.max(m[(i, j)].abs());
            }
        }
        if off <= tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol * 1e-2 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // extract + sort descending
    let mut idx: Vec<usize> = (0..n).collect();
    let vals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| vals[i]).collect();
    let vectors = Mat::from_fn(n, n, |i, j| v[(i, idx[j])]);
    Ok(EighResult { values, vectors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_property() {
        check(
            23,
            15,
            |g: &mut Gen| {
                let n = g.usize_in(1, (20 >> g.shrink.min(3)).max(1));
                let a = Mat::from_fn(n, n, |_, _| g.rng.normal());
                let mut s = a.add(&a.transpose());
                s.symmetrize();
                s
            },
            |a| {
                let EighResult { values, vectors } = eigh(a).map_err(|e| e.to_string())?;
                let n = a.rows();
                // A v_j == λ_j v_j
                for j in 0..n {
                    for i in 0..n {
                        let av: f64 = (0..n).map(|k| a[(i, k)] * vectors[(k, j)]).sum();
                        if (av - values[j] * vectors[(i, j)]).abs() > 1e-7 {
                            return Err(format!("eigpair {j} row {i}"));
                        }
                    }
                }
                // orthonormal columns
                let vtv = vectors.transpose().matmul(&vectors);
                if vtv.sub(&Mat::identity(n)).max_abs() > 1e-9 {
                    return Err("not orthonormal".into());
                }
                // descending order
                for w in values.windows(2) {
                    if w[0] < w[1] - 1e-12 {
                        return Err("not sorted".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn diagonal_is_fixed_point() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let r = eigh(&a).unwrap();
        assert_eq!(r.values, vec![4.0, 3.0, 2.0, 1.0]);
    }

    #[test]
    fn trace_equals_eigsum() {
        let mut rng = Rng::new(8);
        let a = Mat::from_fn(12, 12, |_, _| rng.normal());
        let mut s = a.add(&a.transpose());
        s.symmetrize();
        let r = eigh(&s).unwrap();
        let sum: f64 = r.values.iter().sum();
        assert!((sum - s.trace()).abs() < 1e-8);
    }

    #[test]
    fn huge_condition_number() {
        // diag(1e8, 1) rotated: must still recover both eigenvalues
        let c = std::f64::consts::FRAC_1_SQRT_2;
        let q = Mat::from_rows(vec![vec![c, -c], vec![c, c]]);
        let d = Mat::from_rows(vec![vec![1e8, 0.0], vec![0.0, 1.0]]);
        let a = q.matmul(&d).matmul(&q.transpose());
        let r = eigh(&a).unwrap();
        assert!((r.values[0] - 1e8).abs() / 1e8 < 1e-10);
        assert!((r.values[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn empty_and_one() {
        assert!(eigh(&Mat::zeros(0, 0)).unwrap().values.is_empty());
        let r = eigh(&Mat::from_rows(vec![vec![3.0]])).unwrap();
        assert_eq!(r.values, vec![3.0]);
    }
}
