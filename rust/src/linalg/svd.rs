//! Singular value decomposition via one-sided Jacobi.
//!
//! The CCA step (Alg. 2 line 30) needs the singular values of the
//! standardized cross-correlation matrix C_W = Cyy^-1/2 Cyx Cxx^-1/2 —
//! those are the canonical correlations ρ_i. One-sided Jacobi rotates
//! column pairs of A until they are mutually orthogonal; the column norms
//! are then the singular values. It is accurate for the small singular
//! values too (unlike eigh of A^T A), which matters because the bound
//! Σ(1-ρ_i²) is dominated by ρ near 1 where cancellation hurts.

use crate::error::Result;
use crate::linalg::Mat;

pub struct SvdResult {
    /// Left singular vectors (columns), m x k.
    pub u: Mat,
    /// Singular values, descending, length k = min(m, n).
    pub s: Vec<f64>,
    /// Right singular vectors (columns), n x k.
    pub v: Mat,
}

const MAX_SWEEPS: usize = 60;

/// Full thin SVD. For m < n we factor the transpose and swap U/V.
pub fn svd(a: &Mat) -> Result<SvdResult> {
    if a.rows() < a.cols() {
        let r = svd(&a.transpose())?;
        return Ok(SvdResult { u: r.v, s: r.s, v: r.u });
    }
    let (m, n) = (a.rows(), a.cols());
    if n == 0 {
        return Ok(SvdResult { u: Mat::zeros(m, 0), s: vec![], v: Mat::zeros(0, 0) });
    }
    // work on columns of U (copy of A), accumulate V
    let mut u = a.clone();
    let mut v = Mat::identity(n);
    let scale = u.max_abs().max(1e-300);
    let tol = 1e-15 * scale * scale * m as f64;

    for _ in 0..MAX_SWEEPS {
        let mut rotated = false;
        for p in 0..n {
            for q in p + 1..n {
                // gram entries of columns p, q
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= tol || apq.abs() <= 1e-15 * (app * aqq).sqrt() {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // column norms -> singular values; normalize U columns
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let s: Vec<f64> = svals.iter().map(|(x, _)| *x).collect();
    let u_out = Mat::from_fn(m, n, |i, jj| {
        let (norm, j) = svals[jj];
        if norm > 1e-300 {
            u[(i, j)] / norm
        } else {
            0.0
        }
    });
    let v_out = Mat::from_fn(n, n, |i, jj| v[(i, svals[jj].1)]);
    Ok(SvdResult { u: u_out, s, v: v_out })
}

/// Singular values only (descending).
pub fn singular_values(a: &Mat) -> Result<Vec<f64>> {
    Ok(svd(a)?.s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn reconstruct(r: &SvdResult) -> Mat {
        let k = r.s.len();
        let us = Mat::from_fn(r.u.rows(), k, |i, j| r.u[(i, j)] * r.s[j]);
        us.matmul_nt(&r.v)
    }

    #[test]
    fn reconstruction_property() {
        check(
            31,
            15,
            |g: &mut Gen| {
                let m = g.usize_in(1, (16 >> g.shrink.min(3)).max(1));
                let n = g.usize_in(1, (16 >> g.shrink.min(3)).max(1));
                Mat::from_fn(m, n, |_, _| g.rng.normal())
            },
            |a| {
                let r = svd(a).map_err(|e| e.to_string())?;
                let rec = reconstruct(&r);
                if rec.sub(a).max_abs() > 1e-8 {
                    return Err(format!("recon err {}", rec.sub(a).max_abs()));
                }
                // orthonormal U,V columns
                let k = r.s.len();
                let utu = r.u.transpose().matmul(&r.u);
                let vtv = r.v.transpose().matmul(&r.v);
                for i in 0..k {
                    for j in 0..k {
                        let want = if i == j { 1.0 } else { 0.0 };
                        // zero singular directions may be non-orthonormal
                        if r.s[i] > 1e-12 && r.s[j] > 1e-12 {
                            if (utu[(i, j)] - want).abs() > 1e-8 {
                                return Err(format!("U^T U ({i},{j})"));
                            }
                            if (vtv[(i, j)] - want).abs() > 1e-8 {
                                return Err(format!("V^T V ({i},{j})"));
                            }
                        }
                    }
                }
                // nonneg + descending
                for w in r.s.windows(2) {
                    if w[0] < w[1] - 1e-12 {
                        return Err("not sorted".into());
                    }
                }
                if r.s.iter().any(|&x| x < 0.0) {
                    return Err("negative singular value".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2) embedded in 3x2
        let a = Mat::from_rows(vec![
            vec![3.0, 0.0],
            vec![0.0, 2.0],
            vec![0.0, 0.0],
        ]);
        let s = singular_values(&a).unwrap();
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_matrix_has_unit_singulars() {
        let c = std::f64::consts::FRAC_1_SQRT_2;
        let q = Mat::from_rows(vec![vec![c, -c], vec![c, c]]);
        let s = singular_values(&q).unwrap();
        assert!((s[0] - 1.0).abs() < 1e-12 && (s[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_deficient() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 4.0]]);
        let s = singular_values(&a).unwrap();
        assert!(s[1].abs() < 1e-10, "{s:?}");
    }

    #[test]
    fn wide_matrix() {
        let a = Mat::from_rows(vec![vec![1.0, 0.0, 0.0], vec![0.0, 5.0, 0.0]]);
        let s = singular_values(&a).unwrap();
        assert!((s[0] - 5.0).abs() < 1e-12 && (s[1] - 1.0).abs() < 1e-12);
    }
}
