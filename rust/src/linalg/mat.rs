//! Row-major dense f64 matrix with the operations the calibration math
//! needs. The matmuls use ikj loop order (cache-friendly on the row-major
//! layout); sizes here are d x d with d <= ~1024 so this is plenty on the
//! single-core testbed (bench_calibration measures it for Table 1/7).

use std::ops::{Index, IndexMut};

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// From a row-major f32 slice (activations from the executor).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// self (r x k) @ other (k x c) — ikj order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul inner dims");
        let (r, k, c) = (self.rows, self.cols, other.cols);
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * c..(i + 1) * c];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * c..(kk + 1) * c];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Mat { rows: r, cols: c, data: out }
    }

    /// self (r x k) @ other^T (c x k) — contiguous dot products.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dims");
        let (r, k, c) = (self.rows, self.cols, other.rows);
        Mat::from_fn(r, c, |i, j| {
            let a = &self.data[i * k..(i + 1) * k];
            let b = &other.data[j * k..(j + 1) * k];
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        })
    }

    /// self^T @ self (Gram), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let (n, d) = (self.rows, self.cols);
        let mut out = Mat::zeros(d, d);
        for r in 0..n {
            let row = self.row(r);
            for i in 0..d {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..d {
                    out[(i, j)] += xi * row[j];
                }
            }
        }
        for i in 0..d {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Symmetrize in place: (A + A^T)/2 (kills accumulation asymmetry
    /// before eigh).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..i {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        let a = Mat::from_fn(5, 7, |_, _| rng.normal());
        let b = Mat::from_fn(7, 4, |_, _| rng.normal());
        let c = a.matmul(&b);
        for i in 0..5 {
            for j in 0..4 {
                let want: f64 = (0..7).map(|k| a[(i, k)] * b[(k, j)]).sum();
                assert!((c[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmul_nt_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = Mat::from_fn(6, 5, |_, _| rng.normal());
        let b = Mat::from_fn(3, 5, |_, _| rng.normal());
        let c1 = a.matmul(&b.transpose());
        let c2 = a.matmul_nt(&b);
        assert!(c1.sub(&c2).max_abs() < 1e-12);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Mat::from_fn(10, 6, |_, _| rng.normal());
        let g1 = a.transpose().matmul(&a);
        let g2 = a.gram();
        assert!(g1.sub(&g2).max_abs() < 1e-12);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = Mat::from_fn(4, 4, |_, _| rng.normal());
        assert!(a.matmul(&Mat::identity(4)).sub(&a).max_abs() < 1e-15);
    }

    #[test]
    fn trace_and_transpose() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.trace(), 5.0);
        assert_eq!(a.transpose()[(0, 1)], 3.0);
    }
}
