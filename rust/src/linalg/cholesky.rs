//! Cholesky factorization + triangular solves (the LMMSE normal-equation
//! path, Prop. 3.1: `Cxx W = Cxy` with Cxx symmetric PSD).

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Lower-triangular factor L with A = L L^T.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    pub fn factor(a: &Mat) -> Result<Cholesky> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Linalg("cholesky: not square".into()));
        }
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(Error::Linalg(format!(
                            "cholesky: non-PD pivot {s:.3e} at {i}"
                        )));
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve A x = b for one RHS vector.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        // backward: L^T x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Solve A X = B column-by-column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        let mut col = vec![0.0; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log det(A) = 2 * sum log L_ii (used by tests / diagnostics).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn reconstruction_property() {
        check(
            13,
            25,
            |g: &mut Gen| {
                let n = g.usize_in(1, (16 >> g.shrink.min(3)).max(1));
                let a = Mat::from_fn(n, n, |_, _| g.rng.normal());
                let mut p = a.matmul_nt(&a); // A A^T PSD
                for i in 0..n {
                    p[(i, i)] += 0.5;
                }
                p
            },
            |a| {
                let ch = Cholesky::factor(a).map_err(|e| e.to_string())?;
                let rec = ch.l().matmul(&ch.l().transpose());
                if rec.sub(a).max_abs() > 1e-9 {
                    return Err(format!("reconstruction err {}", rec.sub(a).max_abs()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn solve_property() {
        check(
            17,
            25,
            |g: &mut Gen| {
                let n = g.usize_in(1, (12 >> g.shrink.min(3)).max(1));
                let a = Mat::from_fn(n, n, |_, _| g.rng.normal());
                let mut p = a.matmul_nt(&a);
                for i in 0..n {
                    p[(i, i)] += 1.0;
                }
                let x: Vec<f64> = (0..n).map(|_| g.rng.normal()).collect();
                (p, x)
            },
            |(a, x)| {
                let b: Vec<f64> = (0..a.rows())
                    .map(|i| (0..a.cols()).map(|j| a[(i, j)] * x[j]).sum())
                    .collect();
                let got = Cholesky::factor(a).map_err(|e| e.to_string())?.solve(&b);
                for (g, w) in got.iter().zip(x) {
                    if (g - w).abs() > 1e-7 {
                        return Err(format!("{g} vs {w}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1, 3
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(Cholesky::factor(&Mat::zeros(2, 3)).is_err());
    }
}
