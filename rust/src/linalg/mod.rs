//! Dense linear algebra built from scratch (no LAPACK/nalgebra offline).
//!
//! This is the O(d^3) core of the paper's calibration (App. D.1):
//! covariance → eigendecomposition → inverse square roots → canonical
//! correlation SVD → LMMSE solve. Matrices are row-major f64 (the paper
//! runs calibration in f32; we use f64 internally because the CCA chain
//! multiplies three near-singular factors and f32 loses the top
//! correlations ρ≈1 that drive layer selection).

mod cholesky;
mod eigh;
mod mat;
mod svd;

pub use cholesky::Cholesky;
pub use eigh::{eigh, EighResult};
pub use mat::Mat;
pub use svd::{singular_values, svd};

use crate::error::{Error, Result};

/// Symmetric inverse square root via eigendecomposition, clamping
/// eigenvalues below `floor` (ridge against rank deficiency — the paper's
/// calibration hits this when s*t < d or activations are collinear).
pub fn inv_sqrt_psd(a: &Mat, floor: f64) -> Result<Mat> {
    let EighResult { values, vectors } = eigh(a)?;
    let mut scaled = vectors.clone(); // columns scaled by λ^-1/2
    for (j, &l) in values.iter().enumerate() {
        let s = 1.0 / l.max(floor).sqrt();
        for i in 0..scaled.rows() {
            scaled[(i, j)] *= s;
        }
    }
    // V diag(λ^-1/2) V^T
    Ok(scaled.matmul_nt(&vectors))
}

/// Symmetric square root (for tests / SliceGPT whitening).
pub fn sqrt_psd(a: &Mat, floor: f64) -> Result<Mat> {
    let EighResult { values, vectors } = eigh(a)?;
    let mut scaled = vectors.clone();
    for (j, &l) in values.iter().enumerate() {
        let s = l.max(floor).sqrt();
        for i in 0..scaled.rows() {
            scaled[(i, j)] *= s;
        }
    }
    Ok(scaled.matmul_nt(&vectors))
}

/// Solve A X = B for PSD A (Cholesky with escalating ridge).
///
/// Returns X. Used for the LMMSE normal equations `Cxx W = Cxy`
/// (Prop. 3.1, row-vector orientation).
pub fn solve_psd(a: &Mat, b: &Mat, ridge: f64) -> Result<Mat> {
    if a.rows() != a.cols() || a.rows() != b.rows() {
        return Err(Error::Linalg(format!(
            "solve_psd shapes: a {}x{}, b {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let mut lam = ridge;
    for _ in 0..8 {
        let mut aa = a.clone();
        if lam > 0.0 {
            for i in 0..aa.rows() {
                aa[(i, i)] += lam;
            }
        }
        if let Ok(ch) = Cholesky::factor(&aa) {
            return Ok(ch.solve_mat(b));
        }
        lam = if lam == 0.0 { 1e-10 } else { lam * 100.0 };
    }
    Err(Error::Linalg("solve_psd: matrix not PSD even with ridge".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Rng;

    fn random_psd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        // A^T A + n*I: comfortably PSD
        let mut p = a.transpose().matmul(&a);
        for i in 0..n {
            p[(i, i)] += n as f64 * 0.1;
        }
        p
    }

    #[test]
    fn inv_sqrt_property() {
        // (A^-1/2) A (A^-1/2) == I
        check(
            7,
            20,
            |g: &mut Gen| {
                let n = g.usize_in(2, 24 >> g.shrink.min(3));
                random_psd(g.rng, n.max(2))
            },
            |a| {
                let isq = inv_sqrt_psd(a, 1e-12).map_err(|e| e.to_string())?;
                let ident = isq.matmul(a).matmul(&isq);
                for i in 0..a.rows() {
                    for j in 0..a.cols() {
                        let want = if i == j { 1.0 } else { 0.0 };
                        if (ident[(i, j)] - want).abs() > 1e-6 {
                            return Err(format!(
                                "({i},{j}) = {} want {want}",
                                ident[(i, j)]
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn solve_psd_recovers_solution() {
        check(
            9,
            20,
            |g: &mut Gen| {
                let n = g.usize_in(2, 20 >> g.shrink.min(3)).max(2);
                let a = random_psd(g.rng, n);
                let x = Mat::from_fn(n, 3, |_, _| g.rng.normal());
                (a, x)
            },
            |(a, x)| {
                let b = a.matmul(x);
                let got = solve_psd(a, &b, 0.0).map_err(|e| e.to_string())?;
                for i in 0..x.rows() {
                    for j in 0..x.cols() {
                        if (got[(i, j)] - x[(i, j)]).abs() > 1e-6 {
                            return Err(format!("({i},{j})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn solve_psd_singular_falls_back_to_ridge() {
        // rank-1 matrix: plain Cholesky fails, ridge path must succeed
        let mut a = Mat::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                a[(i, j)] = ((i + 1) * (j + 1)) as f64;
            }
        }
        let b = Mat::from_fn(4, 1, |i, _| i as f64);
        assert!(solve_psd(&a, &b, 1e-8).is_ok());
    }

    #[test]
    fn sqrt_matches_inv_sqrt() {
        let mut rng = Rng::new(4);
        let a = random_psd(&mut rng, 8);
        let s = sqrt_psd(&a, 1e-12).unwrap();
        let isq = inv_sqrt_psd(&a, 1e-12).unwrap();
        let ident = s.matmul(&isq);
        for i in 0..8 {
            assert!((ident[(i, i)] - 1.0).abs() < 1e-7);
        }
    }
}
