//! XLA-free slot lifecycle bookkeeping for [`super::SlotArena`].
//!
//! The Free/Reserved/Occupied state machine, the incrementally
//! maintained occupied-index list, and the free-head hint live here,
//! with no literal or runtime types in sight. That split exists for the
//! dynamic back-stops (DESIGN.md §Static analysis): the bounded-
//! exhaustive model checker in `rust/tests/model_slot_ledger.rs` and
//! the nightly Miri job drive this struct directly, where the arena's
//! PJRT cache literals would be out of reach.
//!
//! Every method is total: out-of-range slots are reported (`false` /
//! `Err`), never panicked on — the serving loop must survive a
//! malformed slot index (nbl-lint pass `panic`).

use crate::error::{Error, Result};

/// Lifecycle of one arena row. `Reserved` is the partial-prefill state:
/// a chunked admission has claimed the row (so later admissions cannot
/// strand its finished prefill without a slot) but the row holds no
/// decodable cache yet — the decode iteration skips it exactly like a
/// free row, and adoption overwrites it whole.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Free,
    Reserved,
    Occupied(usize),
}

/// Slot bookkeeping: which rows are free/reserved/occupied, the
/// ascending occupied-index list the decode hot path borrows each
/// iteration, and the O(1) free-head hint.
///
/// Invariants (the model checker's oracle re-derives these from a naive
/// rescan after every operation):
///   - `occ` holds exactly the Occupied indices, strictly ascending
///   - `n_free` equals the number of Free rows
///   - `free_head` is the smallest Free index, or `rows` when none
#[derive(Debug, Clone)]
pub struct SlotLedger {
    rows: usize,
    slots: Vec<SlotState>,
    occ: Vec<usize>,
    n_free: usize,
    free_head: usize,
}

impl SlotLedger {
    pub fn new(rows: usize) -> SlotLedger {
        SlotLedger {
            rows,
            slots: vec![SlotState::Free; rows],
            occ: Vec::with_capacity(rows),
            n_free: rows,
            free_head: 0,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Lowest-index free slot, if any (reserved rows are not free).
    /// O(1): reads the incrementally maintained free head.
    pub fn free_slot(&self) -> Option<usize> {
        if self.n_free == 0 {
            None
        } else {
            Some(self.free_head)
        }
    }

    /// Number of free slots (reserved rows count as taken). O(1).
    pub fn free_slots(&self) -> usize {
        self.n_free
    }

    /// Indices of occupied slots (ascending); reserved rows are not
    /// occupied — they hold no decodable cache yet. O(1): borrows the
    /// incrementally maintained index list.
    pub fn occupied(&self) -> &[usize] {
        &self.occ
    }

    pub fn occupancy(&self) -> usize {
        self.occ.len()
    }

    /// State of `slot`, or None when out of range.
    pub fn state(&self, slot: usize) -> Option<SlotState> {
        self.slots.get(slot).copied()
    }

    /// Tokens cached in `slot` (None if free, reserved or out of range).
    pub fn pos(&self, slot: usize) -> Option<usize> {
        match self.slots.get(slot) {
            Some(SlotState::Occupied(p)) => Some(*p),
            _ => None,
        }
    }

    pub fn is_reserved(&self, slot: usize) -> bool {
        matches!(self.slots.get(slot), Some(SlotState::Reserved))
    }

    /// Bookkeeping for a slot leaving the Free state: when the free
    /// head itself is claimed, advance it to the next free row
    /// (amortized O(1) over a claim/release cycle).
    fn note_unfree(&mut self, slot: usize) {
        self.n_free -= 1;
        if self.n_free == 0 {
            self.free_head = self.rows;
        } else if slot == self.free_head {
            self.free_head = (slot + 1..self.rows)
                .find(|&s| self.state(s) == Some(SlotState::Free))
                .unwrap_or(self.rows);
        }
    }

    /// Mark `slot` occupied at `pos` (claiming it from Free or Reserved
    /// if needed). Returns false — with no state change — when the slot
    /// is out of range.
    pub fn set_pos(&mut self, slot: usize, pos: usize) -> bool {
        let Some(&was) = self.slots.get(slot) else {
            return false;
        };
        match was {
            SlotState::Occupied(_) => {}
            SlotState::Free | SlotState::Reserved => {
                if was == SlotState::Free {
                    self.note_unfree(slot);
                }
                let i = self.occ.partition_point(|&s| s < slot);
                self.occ.insert(i, slot);
            }
        }
        if let Some(s) = self.slots.get_mut(slot) {
            *s = SlotState::Occupied(pos);
        }
        true
    }

    /// Claim a free row for an in-flight chunked prefill: the row stops
    /// being admissible but does not join decode iterations until the
    /// finished prefill is adopted into it.
    pub fn reserve(&mut self, slot: usize) -> Result<()> {
        match self.slots.get(slot) {
            Some(SlotState::Free) => {
                self.note_unfree(slot);
                if let Some(s) = self.slots.get_mut(slot) {
                    *s = SlotState::Reserved;
                }
                Ok(())
            }
            Some(_) => Err(Error::Serving(format!("slot {slot} is not free"))),
            None => Err(Error::Serving(format!(
                "slot {slot} out of range ({} rows)",
                self.rows
            ))),
        }
    }

    /// Mark a slot free (from any state); out-of-range indices are a
    /// no-op. Returns whether the slot was in range.
    pub fn release(&mut self, slot: usize) -> bool {
        let Some(&was) = self.slots.get(slot) else {
            return false;
        };
        match was {
            SlotState::Free => return true,
            SlotState::Occupied(_) => {
                let i = self.occ.partition_point(|&s| s < slot);
                if self.occ.get(i) == Some(&slot) {
                    self.occ.remove(i);
                }
            }
            SlotState::Reserved => {}
        }
        if let Some(s) = self.slots.get_mut(slot) {
            *s = SlotState::Free;
        }
        self.n_free += 1;
        if slot < self.free_head {
            self.free_head = slot;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_free_reserve_occupy_release() {
        let mut l = SlotLedger::new(3);
        assert_eq!(l.free_slot(), Some(0));
        l.reserve(0).unwrap();
        assert!(l.is_reserved(0));
        assert_eq!(l.free_slot(), Some(1));
        assert!(l.set_pos(0, 7));
        assert_eq!(l.pos(0), Some(7));
        assert_eq!(l.occupied(), &[0]);
        assert!(l.release(0));
        assert_eq!(l.free_slot(), Some(0));
        assert_eq!(l.free_slots(), 3);
    }

    #[test]
    fn out_of_range_is_reported_not_panicked() {
        let mut l = SlotLedger::new(2);
        assert!(!l.set_pos(5, 1));
        assert!(!l.release(5));
        assert!(l.reserve(5).is_err());
        assert_eq!(l.pos(5), None);
        assert_eq!(l.free_slots(), 2);
    }

    #[test]
    fn occ_list_stays_sorted_under_churn() {
        let mut l = SlotLedger::new(4);
        for s in [2, 0, 3, 1] {
            assert!(l.set_pos(s, s + 10));
        }
        assert_eq!(l.occupied(), &[0, 1, 2, 3]);
        l.release(1);
        l.release(3);
        assert_eq!(l.occupied(), &[0, 2]);
        assert_eq!(l.free_slot(), Some(1));
    }
}
