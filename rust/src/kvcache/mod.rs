//! KV-cache management: contiguous prefill cache state, the per-request
//! slot arena used by the continuous-batching scheduler, a capacity-
//! tracked pool, and the paper's §H.2 sizing formulas (Table 21).
//!
//! NBL's KV saving is structural: layers whose attention was linearized
//! or dropped simply have no cache entry, so a plan with m of K layers
//! substituted allocates (K-m)/K of the baseline bytes — the executor
//! and this module enforce that invariant per slot (see DESIGN.md
//! §Serving for the slot layout and admission rules).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::config::ModelConfig;
use crate::nbl::plan::ModelPlan;
use crate::runtime::literals::{lit_from_tensor, tensor_from_lit};
use crate::tensor::Tensor;

pub mod ledger;
pub mod paged;
pub mod prefix;

use ledger::{SlotLedger, SlotState};

/// Device-side KV cache produced by one prefill call (literals stay
/// attached to the PJRT runtime; on the CPU backend these are host
/// buffers). Also the run-to-completion group state of the legacy
/// exact-length protocol; under continuous batching a batch-1 `KvState`
/// is migrated into a [`SlotArena`] row right after prefill.
pub struct KvState {
    /// Logical batch (requests in the group).
    pub batch: usize,
    /// Executable batch bucket (>= batch; rows beyond batch are padding).
    pub bucket_batch: usize,
    /// Tokens cached so far (shared by the group — see DESIGN.md).
    pub pos: usize,
    /// Cache capacity (Tmax baked into the executables).
    pub max_ctx: usize,
    /// Per layer: Some((k, v)) iff the plan keeps attention there.
    pub caches: Vec<Option<(xla::Literal, xla::Literal)>>,
    /// Bytes accounted against the pool.
    bytes: usize,
}

// SAFETY: literals are plain host allocations on the CPU PJRT backend;
// nothing in KvState aliases thread-local runtime state.
#[allow(unsafe_code)]
unsafe impl Send for KvState {}

impl KvState {
    pub fn empty(
        plan: &ModelPlan,
        cfg: &ModelConfig,
        batch: usize,
        bucket_batch: usize,
    ) -> KvState {
        let caches = plan
            .layers
            .iter()
            .map(|_| None)
            .collect();
        KvState {
            batch,
            bucket_batch,
            pos: 0,
            max_ctx: cfg.max_ctx,
            caches,
            bytes: kv_bytes(cfg, plan.kv_layers(), bucket_batch, cfg.max_ctx, 4),
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_ctx.saturating_sub(self.pos)
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Per-request KV slot arena for the continuous-batching decode group.
///
/// One fixed batch bucket of rows; row r of every layer cache literal is
/// slot r's private segment with its own position (the rows-decode op
/// consumes the positions as an i32 vector). Requests join by adopting a
/// freshly prefilled batch-1 [`KvState`] into a free (or reserved) row
/// and leave by releasing the row — the batch never restarts.
/// Substituted layers hold `None`, so NBL's structural KV saving applies
/// per slot. A multi-chunk admission reserves its row up front
/// (DESIGN.md §Chunked prefill) and adopts on the final chunk.
pub struct SlotArena {
    /// Rows in the arena (an executable batch bucket).
    pub bucket_batch: usize,
    /// Cache capacity per row (Tmax baked into the executables).
    pub max_ctx: usize,
    /// Per layer: Some((k, v)) [Bb, Tmax, Hkv, dh] iff the plan keeps
    /// attention there.
    pub caches: Vec<Option<(xla::Literal, xla::Literal)>>,
    /// Slot lifecycle bookkeeping (Free/Reserved/Occupied, occupied
    /// list, free head) — XLA-free so the model checker and Miri can
    /// drive it directly; see [`ledger::SlotLedger`].
    ledger: SlotLedger,
}

// SAFETY: literals are plain host allocations on the CPU PJRT backend;
// the ledger is plain owned data.
#[allow(unsafe_code)]
unsafe impl Send for SlotArena {}

impl SlotArena {
    /// Allocate an all-free arena (zero-initialized caches for every
    /// layer that keeps attention under `plan`).
    pub fn new(plan: &ModelPlan, cfg: &ModelConfig, bucket_batch: usize) -> Result<SlotArena> {
        let shape = vec![bucket_batch, cfg.max_ctx, cfg.n_kv_heads, cfg.head_dim];
        let mut caches = Vec::with_capacity(plan.layers.len());
        for lp in &plan.layers {
            if lp.attn.needs_kv() {
                let k = lit_from_tensor(&Tensor::zeros(shape.clone()))?;
                let v = lit_from_tensor(&Tensor::zeros(shape.clone()))?;
                caches.push(Some((k, v)));
            } else {
                caches.push(None);
            }
        }
        Ok(SlotArena {
            bucket_batch,
            max_ctx: cfg.max_ctx,
            caches,
            ledger: SlotLedger::new(bucket_batch),
        })
    }

    /// Lowest-index free slot, if any (reserved rows are not free).
    /// O(1): reads the incrementally maintained free head.
    pub fn free_slot(&self) -> Option<usize> {
        self.ledger.free_slot()
    }

    /// Number of free slots (reserved rows count as taken). O(1).
    pub fn free_slots(&self) -> usize {
        self.ledger.free_slots()
    }

    /// Indices of occupied slots (ascending); reserved rows are not
    /// occupied — they hold no decodable cache yet. O(1): borrows the
    /// incrementally maintained index list (no per-iteration rescan or
    /// allocation on the decode hot path).
    pub fn occupied(&self) -> &[usize] {
        self.ledger.occupied()
    }

    pub fn occupancy(&self) -> usize {
        self.ledger.occupancy()
    }

    /// Tokens cached in `slot` (None if free or reserved).
    pub fn pos(&self, slot: usize) -> Option<usize> {
        self.ledger.pos(slot)
    }

    pub fn set_pos(&mut self, slot: usize, pos: usize) {
        let in_range = self.ledger.set_pos(slot, pos);
        debug_assert!(in_range, "set_pos: slot {slot} out of range");
    }

    /// Claim a free row for an in-flight chunked prefill: the row stops
    /// being admissible but does not join decode iterations until the
    /// finished prefill is adopted into it.
    pub fn reserve(&mut self, slot: usize) -> Result<()> {
        self.ledger.reserve(slot)
    }

    pub fn is_reserved(&self, slot: usize) -> bool {
        self.ledger.is_reserved(slot)
    }

    /// Mark a slot free (from any state); its rows become garbage and
    /// are fully overwritten by the next `adopt` into the same slot.
    /// Out-of-range indices are a no-op (the serving loop must survive
    /// a malformed slot index rather than panic).
    pub fn release(&mut self, slot: usize) {
        self.ledger.release(slot);
    }

    /// Migrate a freshly prefilled batch-1 `KvState` into row `slot`
    /// (free, or reserved by the chunked-admission machine): copy row 0
    /// of each layer cache and claim the slot at `state.pos`.
    pub fn adopt(&mut self, slot: usize, state: &KvState) -> Result<()> {
        if slot >= self.bucket_batch {
            return Err(Error::Serving(format!(
                "slot {slot} out of range ({} rows)",
                self.bucket_batch
            )));
        }
        if matches!(self.ledger.state(slot), Some(SlotState::Occupied(_))) {
            return Err(Error::Serving(format!("slot {slot} is occupied")));
        }
        put_row_state(&mut self.caches, state, slot)?;
        self.set_pos(slot, state.pos);
        Ok(())
    }
}

/// Write the row-0 caches of batch-1 `state` into row `row` of `caches`
/// — the restore half of the slot row-transfer protocol shared by
/// [`SlotArena::adopt`], the fallback decode path
/// (`Engine::decode_rows_fallback`), and the prefix snapshot store.
pub fn put_row_state(
    caches: &mut [Option<(xla::Literal, xla::Literal)>],
    state: &KvState,
    row: usize,
) -> Result<()> {
    if state.caches.len() != caches.len() {
        return Err(Error::Serving(format!(
            "plan mismatch: {} vs {} layers",
            state.caches.len(),
            caches.len()
        )));
    }
    for (dst, src) in caches.iter_mut().zip(&state.caches) {
        match (dst, src) {
            (Some((dk, dv)), Some((sk, sv))) => {
                copy_cache_row(dk, row, sk, 0)?;
                copy_cache_row(dv, row, sv, 0)?;
            }
            (None, None) => {}
            _ => {
                return Err(Error::Serving(
                    "plan mismatch: KV layers differ between prefill and arena".into(),
                ))
            }
        }
    }
    Ok(())
}

/// Extract row `row` of `caches` as a batch-1 [`KvState`] at position
/// `pos` — the save half of the slot row-transfer protocol (the
/// fallback decode slices a slot out, decodes it solo, and writes it
/// back; the prefix snapshot store exports rows the same way).
pub fn take_row_state(
    plan: &ModelPlan,
    cfg: &ModelConfig,
    caches: &[Option<(xla::Literal, xla::Literal)>],
    row: usize,
    pos: usize,
) -> Result<KvState> {
    let mut state = KvState::empty(plan, cfg, 1, 1);
    if caches.len() != state.caches.len() {
        return Err(Error::Serving(format!(
            "plan mismatch: {} vs {} layers",
            caches.len(),
            state.caches.len()
        )));
    }
    for (dst, src) in state.caches.iter_mut().zip(caches) {
        if let Some((k, v)) = src {
            *dst = Some((take_cache_row(k, row)?, take_cache_row(v, row)?));
        }
    }
    state.pos = pos;
    Ok(state)
}

/// Copy row `src_row` of `src` into row `dst_row` of `dst`. Both literals
/// must share trailing dims (host-side memcpy; literals are host buffers
/// on the CPU backend).
pub fn copy_cache_row(
    dst: &mut xla::Literal,
    dst_row: usize,
    src: &xla::Literal,
    src_row: usize,
) -> Result<()> {
    let mut d = tensor_from_lit(dst)?;
    let s = tensor_from_lit(src)?;
    if d.shape()[1..] != s.shape()[1..] {
        return Err(Error::Shape(format!(
            "cache row copy: {:?} vs {:?}",
            d.shape(),
            s.shape()
        )));
    }
    if dst_row >= d.shape()[0] || src_row >= s.shape()[0] {
        return Err(Error::Shape(format!(
            "cache row copy: rows {dst_row}/{src_row} out of range"
        )));
    }
    let stride: usize = d.shape()[1..].iter().product();
    d.data_mut()[dst_row * stride..(dst_row + 1) * stride]
        .copy_from_slice(&s.data()[src_row * stride..(src_row + 1) * stride]);
    *dst = lit_from_tensor(&d)?;
    Ok(())
}

/// Extract one row of a cache literal as a batch-1 literal [1, ...]
/// (the per-row fallback decode path when the rows op is not in the AOT
/// grid — see `Engine::decode_rows`).
pub fn take_cache_row(src: &xla::Literal, row: usize) -> Result<xla::Literal> {
    let s = tensor_from_lit(src)?;
    if row >= s.shape()[0] {
        return Err(Error::Shape(format!("cache row {row} out of range")));
    }
    let stride: usize = s.shape()[1..].iter().product();
    let mut shape = s.shape().to_vec();
    shape[0] = 1;
    let data = s.data()[row * stride..(row + 1) * stride].to_vec();
    lit_from_tensor(&Tensor::new(shape, data)?)
}

/// Extract the first `tokens` cache entries of row `row` as a host
/// tensor [1, tokens, ...] — the prefix-snapshot export: entries past
/// `tokens` belong to a longer context (or are padding garbage) and are
/// dropped, so a snapshot's byte cost scales with the prefix it covers,
/// not with Tmax.
pub fn take_cache_row_prefix(src: &xla::Literal, row: usize, tokens: usize) -> Result<Tensor> {
    take_cache_row_range(src, row, 0, tokens)
}

/// Extract cache entries `[start, end)` of row `row` as a host tensor
/// [1, end-start, ...] — the block-granular generalization of
/// [`take_cache_row_prefix`] the paged block pool captures with (a
/// block is a mid-row token range, not a prefix).
pub fn take_cache_row_range(
    src: &xla::Literal,
    row: usize,
    start: usize,
    end: usize,
) -> Result<Tensor> {
    let s = tensor_from_lit(src)?;
    if row >= s.shape()[0] || start >= end || end > s.shape()[1] {
        return Err(Error::Shape(format!(
            "cache row range: row {row} / tokens [{start}, {end}) out of range {:?}",
            s.shape()
        )));
    }
    let row_stride: usize = s.shape()[1..].iter().product();
    let tok_stride: usize = s.shape()[2..].iter().product();
    let mut shape = s.shape().to_vec();
    shape[0] = 1;
    shape[1] = end - start;
    let base = row * row_stride + start * tok_stride;
    let data = s.data()[base..base + (end - start) * tok_stride].to_vec();
    Tensor::new(shape, data)
}

/// §H.2 bytes for ONE request slot under `plan` (batch 1, full context):
/// the unit of the scheduler's slot-granular admission control.
pub fn slot_bytes(cfg: &ModelConfig, plan: &ModelPlan) -> usize {
    kv_bytes(cfg, plan.kv_layers(), 1, cfg.max_ctx, 4)
}

/// §H.2 grouped-query KV size: 2 * bs * n * d * (g/h) * bytes, per layer
/// summed over layers that keep attention. (g/h == n_kv_heads/n_heads, so
/// 2*bs*n*d*g/h == 2*bs*n*d_kv.)
pub fn kv_bytes(
    cfg: &ModelConfig,
    kv_layers: usize,
    batch: usize,
    ctx: usize,
    bytes_per_elem: usize,
) -> usize {
    2 * batch * ctx * cfg.d_kv() * bytes_per_elem * kv_layers
}

/// Capacity-tracked allocator for batch groups: admission control for the
/// scheduler (requests wait when the cache budget is exhausted).
pub struct KvPool {
    capacity_bytes: usize,
    in_use: std::sync::atomic::AtomicUsize,
}

impl KvPool {
    pub fn new(capacity_bytes: usize) -> KvPool {
        KvPool { capacity_bytes, in_use: 0.into() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_bytes
    }

    pub fn in_use(&self) -> usize {
        self.in_use.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// True if `bytes` more could be reserved right now (the scheduler's
    /// admission check; single-writer, so check-then-reserve is safe in
    /// the worker loop and a racing reserve just fails cleanly).
    pub fn would_fit(&self, bytes: usize) -> bool {
        self.in_use() + bytes <= self.capacity_bytes
    }

    /// Try to reserve bytes for a new group; Err if over budget.
    pub fn reserve(&self, bytes: usize) -> Result<KvLease<'_>> {
        self.try_take(bytes)?;
        Ok(KvLease { pool: self, bytes })
    }

    /// Owned variant of [`reserve`](Self::reserve) for long-lived
    /// reservations: the per-slot leases the scheduler holds across
    /// decode iterations.
    pub fn reserve_owned(pool: &Arc<KvPool>, bytes: usize) -> Result<KvLeaseOwned> {
        pool.try_take(bytes)?;
        Ok(KvLeaseOwned { pool: pool.clone(), bytes })
    }

    fn try_take(&self, bytes: usize) -> Result<()> {
        use std::sync::atomic::Ordering;
        let mut cur = self.in_use.load(Ordering::Relaxed);
        loop {
            if cur + bytes > self.capacity_bytes {
                return Err(Error::Serving(format!(
                    "KV pool exhausted: {} + {} > {}",
                    cur, bytes, self.capacity_bytes
                )));
            }
            match self.in_use.compare_exchange(
                cur,
                cur + bytes,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(()),
                Err(actual) => cur = actual,
            }
        }
    }

    fn give_back(&self, bytes: usize) {
        self.in_use
            .fetch_sub(bytes, std::sync::atomic::Ordering::AcqRel);
    }
}

/// RAII lease; returns bytes to the pool on drop.
pub struct KvLease<'a> {
    pool: &'a KvPool,
    bytes: usize,
}

impl KvLease<'_> {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for KvLease<'_> {
    fn drop(&mut self) {
        self.pool.give_back(self.bytes);
    }
}

/// Owned RAII lease (holds the pool by Arc): per-slot reservation held
/// for a request's whole residency in the decode group.
pub struct KvLeaseOwned {
    pool: Arc<KvPool>,
    bytes: usize,
}

impl KvLeaseOwned {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for KvLeaseOwned {
    fn drop(&mut self) {
        self.pool.give_back(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 256,
            max_ctx: 512,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn kv_bytes_matches_paper_formula() {
        let c = cfg();
        // 2 * bs * n * d * g/h * bytes * K
        let d = c.d_model;
        let g_over_h = c.n_kv_heads as f64 / c.n_heads as f64;
        let want = (2.0 * 64.0 * 512.0 * d as f64 * g_over_h * 2.0 * 6.0) as usize;
        assert_eq!(kv_bytes(&c, 6, 64, 512, 2), want);
    }

    #[test]
    fn nbl_scaling_is_k_minus_m_over_k() {
        let c = cfg();
        let full = kv_bytes(&c, 6, 1, 512, 4);
        for m in 0..=6 {
            let got = kv_bytes(&c, 6 - m, 1, 512, 4);
            assert_eq!(got * 6, full * (6 - m));
        }
    }

    #[test]
    fn pool_reserve_and_release() {
        let pool = KvPool::new(1000);
        let a = pool.reserve(600).unwrap();
        assert_eq!(pool.in_use(), 600);
        assert!(pool.reserve(500).is_err());
        drop(a);
        assert_eq!(pool.in_use(), 0);
        let _b = pool.reserve(1000).unwrap();
    }

    #[test]
    fn empty_state_accounts_plan_layers() {
        let c = cfg();
        let mut plan = crate::nbl::plan::ModelPlan::baseline(6);
        plan.drop_attn(0);
        plan.drop_attn(1);
        let st = KvState::empty(&plan, &c, 1, 1);
        assert_eq!(st.bytes(), kv_bytes(&c, 4, 1, 512, 4));
        assert_eq!(st.remaining(), 512);
    }

    #[test]
    fn owned_lease_returns_bytes_on_drop() {
        let pool = std::sync::Arc::new(KvPool::new(1000));
        let a = KvPool::reserve_owned(&pool, 400).unwrap();
        let b = KvPool::reserve_owned(&pool, 400).unwrap();
        assert!(KvPool::reserve_owned(&pool, 400).is_err());
        assert!(!pool.would_fit(400));
        assert!(pool.would_fit(200));
        drop(a);
        assert_eq!(pool.in_use(), 400);
        drop(b);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn slot_bytes_is_batch1_full_ctx() {
        let c = cfg();
        let mut plan = crate::nbl::plan::ModelPlan::baseline(6);
        plan.drop_attn(2);
        assert_eq!(slot_bytes(&c, &plan), kv_bytes(&c, 5, 1, 512, 4));
    }

    #[test]
    fn arena_slot_lifecycle() {
        let c = cfg();
        let mut plan = crate::nbl::plan::ModelPlan::baseline(6);
        plan.drop_attn(0);
        let mut arena = SlotArena::new(&plan, &c, 4).unwrap();
        // substituted layer has no cache, kept layers do
        assert!(arena.caches[0].is_none());
        assert!(arena.caches[1].is_some());
        assert_eq!(arena.occupancy(), 0);
        assert_eq!(arena.free_slot(), Some(0));
        arena.set_pos(0, 10);
        arena.set_pos(2, 7);
        assert_eq!(arena.occupancy(), 2);
        assert_eq!(arena.occupied(), vec![0, 2]);
        assert_eq!(arena.free_slot(), Some(1));
        assert_eq!(arena.pos(2), Some(7));
        arena.release(0);
        assert_eq!(arena.free_slot(), Some(0));
        assert_eq!(arena.occupied(), vec![2]);
        assert_eq!(arena.pos(0), None);
    }

    #[test]
    fn arena_reservation_lifecycle() {
        let c = cfg();
        let plan = crate::nbl::plan::ModelPlan::baseline(2);
        let mut arena = SlotArena::new(&plan, &c, 4).unwrap();
        // a reserved row is neither free nor occupied
        arena.reserve(0).unwrap();
        assert!(arena.is_reserved(0));
        assert_eq!(arena.free_slot(), Some(1));
        assert_eq!(arena.free_slots(), 3);
        assert_eq!(arena.occupancy(), 0);
        assert!(arena.occupied().is_empty());
        assert_eq!(arena.pos(0), None);
        // cannot double-reserve, reserve an occupied row, or reserve
        // out of range
        assert!(arena.reserve(0).is_err());
        arena.set_pos(1, 5);
        assert!(arena.reserve(1).is_err());
        assert!(arena.reserve(9).is_err());
        // release returns a reserved row to the free pool
        arena.release(0);
        assert!(!arena.is_reserved(0));
        assert_eq!(arena.free_slot(), Some(0));
    }

    #[test]
    fn cache_row_copy_round_trip() {
        use crate::runtime::literals::{lit_from_tensor, tensor_from_lit};
        use crate::tensor::Tensor;
        let src = lit_from_tensor(&Tensor::from_fn(vec![2, 3, 4], |i| i as f32)).unwrap();
        let mut dst = lit_from_tensor(&Tensor::zeros(vec![4, 3, 4])).unwrap();
        copy_cache_row(&mut dst, 2, &src, 1).unwrap();
        let d = tensor_from_lit(&dst).unwrap();
        // row 2 of dst == row 1 of src, other rows untouched
        assert_eq!(d.at2(2, 0)[0], 12.0);
        assert_eq!(d.at2(2, 2)[3], 23.0);
        assert_eq!(d.at2(0, 0)[0], 0.0);
        assert_eq!(d.at2(3, 2)[3], 0.0);
        // extract the row back out as a batch-1 literal
        let row = take_cache_row(&dst, 2).unwrap();
        let r = tensor_from_lit(&row).unwrap();
        assert_eq!(r.shape(), &[1, 3, 4]);
        assert_eq!(r.at2(0, 0)[0], 12.0);
        // shape-mismatched copies are rejected
        let bad = lit_from_tensor(&Tensor::zeros(vec![1, 2, 4])).unwrap();
        assert!(copy_cache_row(&mut dst, 0, &bad, 0).is_err());
        assert!(take_cache_row(&dst, 9).is_err());
    }

    /// Batch-1 KvState with deterministic literal caches for every
    /// layer the plan keeps (the shape `SlotArena::adopt` expects).
    fn batch1_state(plan: &crate::nbl::plan::ModelPlan, c: &ModelConfig, pos: usize) -> KvState {
        let mut st = KvState::empty(plan, c, 1, 1);
        for (li, lp) in plan.layers.iter().enumerate() {
            if lp.attn.needs_kv() {
                let t = Tensor::from_fn(vec![1, c.max_ctx, c.n_kv_heads, c.head_dim], |i| {
                    (li * 100_000 + i) as f32 * 1e-3
                });
                let lit = || lit_from_tensor(&t).unwrap();
                st.caches[li] = Some((lit(), lit()));
            }
        }
        st.pos = pos;
        st
    }

    #[test]
    fn arena_bookkeeping_matches_naive_scan() {
        // the incremental free list / occupied index must agree with a
        // full rescan after ANY transition sequence (the hot-path
        // structures are redundant state; drift would mis-admit)
        let c = cfg();
        let plan = crate::nbl::plan::ModelPlan::baseline(2);
        let mut arena = SlotArena::new(&plan, &c, 8).unwrap();
        let mut x = 0x12345678u64;
        for step in 0..600 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let slot = (x >> 33) as usize % 8;
            match (x >> 8) % 3 {
                0 => arena.set_pos(slot, step),
                1 => {
                    let _ = arena.reserve(slot);
                }
                _ => arena.release(slot),
            }
            let occ_naive: Vec<usize> = (0..8).filter(|&s| arena.pos(s).is_some()).collect();
            let free_naive: Vec<usize> = (0..8)
                .filter(|&s| arena.pos(s).is_none() && !arena.is_reserved(s))
                .collect();
            assert_eq!(arena.occupied(), occ_naive, "occupied drift at step {step}");
            assert_eq!(arena.occupancy(), occ_naive.len());
            assert_eq!(arena.free_slots(), free_naive.len(), "free count drift at {step}");
            assert_eq!(arena.free_slot(), free_naive.first().copied(), "free head at {step}");
        }
    }

    #[test]
    fn reserve_release_adopt_under_pool_exhaustion() {
        // the chunked-admission lifecycle against a one-slot KV budget:
        // reserve the row, lose the budget, release, then re-reserve and
        // adopt at a NONZERO position once the budget frees
        let c = cfg();
        let plan = crate::nbl::plan::ModelPlan::baseline(6);
        let per_slot = slot_bytes(&c, &plan);
        let mut arena = SlotArena::new(&plan, &c, 2).unwrap();
        let pool = Arc::new(KvPool::new(per_slot));
        let lease = KvPool::reserve_owned(&pool, per_slot).unwrap();
        // pool exhausted: the admission lease fails and the reserved row
        // must return to the free pool untouched
        arena.reserve(0).unwrap();
        assert!(KvPool::reserve_owned(&pool, per_slot).is_err());
        arena.release(0);
        assert_eq!(arena.free_slots(), 2);
        assert_eq!(arena.free_slot(), Some(0));
        drop(lease);
        // budget free again: reserve -> adopt lands mid-context
        let l2 = KvPool::reserve_owned(&pool, per_slot).unwrap();
        arena.reserve(0).unwrap();
        let st = batch1_state(&plan, &c, 37);
        arena.adopt(0, &st).unwrap();
        assert_eq!(arena.pos(0), Some(37));
        assert_eq!(arena.occupied(), vec![0]);
        // departure returns both the row and (via the lease) the bytes
        arena.release(0);
        drop(l2);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(arena.free_slot(), Some(0));
    }

    #[test]
    fn row_state_transfer_round_trip() {
        // take_row_state/put_row_state are the shared save/restore
        // halves of the fallback decode and the snapshot store: a row
        // sliced out and written back elsewhere must carry its data
        let c = cfg();
        let mut plan = crate::nbl::plan::ModelPlan::baseline(6);
        plan.drop_attn(0);
        let mut arena = SlotArena::new(&plan, &c, 4).unwrap();
        let st = batch1_state(&plan, &c, 21);
        arena.adopt(2, &st).unwrap();
        let out = take_row_state(&plan, &c, &arena.caches, 2, 21).unwrap();
        assert_eq!(out.pos, 21);
        assert!(out.caches[0].is_none(), "substituted layer must stay empty");
        let (k_src, _) = st.caches[1].as_ref().unwrap();
        let (k_out, _) = out.caches[1].as_ref().unwrap();
        assert_eq!(
            tensor_from_lit(k_out).unwrap().data(),
            tensor_from_lit(k_src).unwrap().data()
        );
        // write the slice into a different row of a fresh arena
        let mut other = SlotArena::new(&plan, &c, 4).unwrap();
        put_row_state(&mut other.caches, &out, 3).unwrap();
        let (k_dst, _) = other.caches[1].as_ref().unwrap();
        let dst = tensor_from_lit(k_dst).unwrap();
        let src = tensor_from_lit(k_src).unwrap();
        let stride: usize = dst.shape()[1..].iter().product();
        assert_eq!(&dst.data()[3 * stride..4 * stride], &src.data()[..stride]);
        assert!(dst.data()[..stride].iter().all(|&v| v == 0.0), "other rows untouched");
        // layer-count mismatch is rejected on both halves
        let short = crate::nbl::plan::ModelPlan::baseline(2);
        assert!(take_row_state(&short, &c, &arena.caches, 0, 0).is_err());
    }

    #[test]
    fn cache_row_prefix_extraction() {
        let src = lit_from_tensor(&Tensor::from_fn(vec![2, 4, 3], |i| i as f32)).unwrap();
        let t = take_cache_row_prefix(&src, 1, 2).unwrap();
        assert_eq!(t.shape(), &[1, 2, 3]);
        // row 1 starts at 12; first two token entries are 12..18
        assert_eq!(t.data(), &[12.0, 13.0, 14.0, 15.0, 16.0, 17.0]);
        assert!(take_cache_row_prefix(&src, 2, 1).is_err());
        assert!(take_cache_row_prefix(&src, 0, 5).is_err());
    }

    #[test]
    fn cache_row_range_extraction() {
        let src = lit_from_tensor(&Tensor::from_fn(vec![2, 4, 3], |i| i as f32)).unwrap();
        // a mid-row block: tokens [1, 3) of row 1 are entries 15..21
        let t = take_cache_row_range(&src, 1, 1, 3).unwrap();
        assert_eq!(t.shape(), &[1, 2, 3]);
        assert_eq!(t.data(), &[15.0, 16.0, 17.0, 18.0, 19.0, 20.0]);
        // a prefix block agrees with take_cache_row_prefix
        assert_eq!(
            take_cache_row_range(&src, 0, 0, 2).unwrap().data(),
            take_cache_row_prefix(&src, 0, 2).unwrap().data()
        );
        // empty, reversed, and out-of-range windows are rejected
        assert!(take_cache_row_range(&src, 0, 2, 2).is_err());
        assert!(take_cache_row_range(&src, 0, 3, 2).is_err());
        assert!(take_cache_row_range(&src, 0, 2, 5).is_err());
        assert!(take_cache_row_range(&src, 2, 0, 1).is_err());
    }

    #[test]
    fn arena_adopt_checks_plan_shape() {
        let c = cfg();
        let plan = crate::nbl::plan::ModelPlan::baseline(2);
        let mut arena = SlotArena::new(&plan, &c, 2).unwrap();
        let mut st = KvState::empty(&plan, &c, 1, 1);
        st.pos = 5;
        // empty KvState has no cache literals yet -> layer count matches
        // but (Some, None) per-layer pairing must be rejected
        assert!(arena.adopt(0, &st).is_err());
        // occupied slot is rejected outright
        arena.set_pos(1, 3);
        assert!(arena.adopt(1, &st).is_err());
        // out-of-range slot is rejected
        assert!(arena.adopt(7, &st).is_err());
    }
}
