//! KV-cache management: per-group device cache state, a capacity-tracked
//! pool, and the paper's §H.2 sizing formulas (Table 21).
//!
//! NBL's KV saving is structural: layers whose attention was linearized
//! or dropped simply have no cache entry, so a plan with m of K layers
//! substituted allocates (K-m)/K of the baseline bytes — the executor
//! and this module enforce that invariant (`bytes_allocated`).

use crate::error::{Error, Result};
use crate::model::config::ModelConfig;
use crate::nbl::plan::ModelPlan;

/// Device-side KV cache for one batch group (literals stay attached to
/// the PJRT runtime; on the CPU backend these are host buffers).
pub struct KvState {
    /// Logical batch (requests in the group).
    pub batch: usize,
    /// Executable batch bucket (>= batch; rows beyond batch are padding).
    pub bucket_batch: usize,
    /// Tokens cached so far (shared by the group — see DESIGN.md).
    pub pos: usize,
    /// Cache capacity (Tmax baked into the executables).
    pub max_ctx: usize,
    /// Per layer: Some((k, v)) iff the plan keeps attention there.
    pub caches: Vec<Option<(xla::Literal, xla::Literal)>>,
    /// Bytes accounted against the pool.
    bytes: usize,
}

// Literals are plain host allocations on the CPU PJRT backend.
unsafe impl Send for KvState {}

impl KvState {
    pub fn empty(plan: &ModelPlan, cfg: &ModelConfig, batch: usize, bucket_batch: usize) -> KvState {
        let caches = plan
            .layers
            .iter()
            .map(|_| None)
            .collect();
        KvState {
            batch,
            bucket_batch,
            pos: 0,
            max_ctx: cfg.max_ctx,
            caches,
            bytes: kv_bytes(cfg, plan.kv_layers(), bucket_batch, cfg.max_ctx, 4),
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_ctx.saturating_sub(self.pos)
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// §H.2 grouped-query KV size: 2 * bs * n * d * (g/h) * bytes, per layer
/// summed over layers that keep attention. (g/h == n_kv_heads/n_heads, so
/// 2*bs*n*d*g/h == 2*bs*n*d_kv.)
pub fn kv_bytes(
    cfg: &ModelConfig,
    kv_layers: usize,
    batch: usize,
    ctx: usize,
    bytes_per_elem: usize,
) -> usize {
    2 * batch * ctx * cfg.d_kv() * bytes_per_elem * kv_layers
}

/// Capacity-tracked allocator for batch groups: admission control for the
/// scheduler (requests wait when the cache budget is exhausted).
pub struct KvPool {
    capacity_bytes: usize,
    in_use: std::sync::atomic::AtomicUsize,
}

impl KvPool {
    pub fn new(capacity_bytes: usize) -> KvPool {
        KvPool { capacity_bytes, in_use: 0.into() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_bytes
    }

    pub fn in_use(&self) -> usize {
        self.in_use.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Try to reserve bytes for a new group; Err if over budget.
    pub fn reserve(&self, bytes: usize) -> Result<KvLease<'_>> {
        use std::sync::atomic::Ordering;
        let mut cur = self.in_use.load(Ordering::Relaxed);
        loop {
            if cur + bytes > self.capacity_bytes {
                return Err(Error::Serving(format!(
                    "KV pool exhausted: {} + {} > {}",
                    cur, bytes, self.capacity_bytes
                )));
            }
            match self.in_use.compare_exchange(
                cur,
                cur + bytes,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(KvLease { pool: self, bytes }),
                Err(actual) => cur = actual,
            }
        }
    }
}

/// RAII lease; returns bytes to the pool on drop.
pub struct KvLease<'a> {
    pool: &'a KvPool,
    bytes: usize,
}

impl KvLease<'_> {
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for KvLease<'_> {
    fn drop(&mut self) {
        self.pool
            .in_use
            .fetch_sub(self.bytes, std::sync::atomic::Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 256,
            max_ctx: 512,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn kv_bytes_matches_paper_formula() {
        let c = cfg();
        // 2 * bs * n * d * g/h * bytes * K
        let d = c.d_model;
        let g_over_h = c.n_kv_heads as f64 / c.n_heads as f64;
        let want = (2.0 * 64.0 * 512.0 * d as f64 * g_over_h * 2.0 * 6.0) as usize;
        assert_eq!(kv_bytes(&c, 6, 64, 512, 2), want);
    }

    #[test]
    fn nbl_scaling_is_k_minus_m_over_k() {
        let c = cfg();
        let full = kv_bytes(&c, 6, 1, 512, 4);
        for m in 0..=6 {
            let got = kv_bytes(&c, 6 - m, 1, 512, 4);
            assert_eq!(got * 6, full * (6 - m));
        }
    }

    #[test]
    fn pool_reserve_and_release() {
        let pool = KvPool::new(1000);
        let a = pool.reserve(600).unwrap();
        assert_eq!(pool.in_use(), 600);
        assert!(pool.reserve(500).is_err());
        drop(a);
        assert_eq!(pool.in_use(), 0);
        let _b = pool.reserve(1000).unwrap();
    }

    #[test]
    fn empty_state_accounts_plan_layers() {
        let c = cfg();
        let mut plan = crate::nbl::plan::ModelPlan::baseline(6);
        plan.drop_attn(0);
        plan.drop_attn(1);
        let st = KvState::empty(&plan, &c, 1, 1);
        assert_eq!(st.bytes(), kv_bytes(&c, 4, 1, 512, 4));
        assert_eq!(st.remaining(), 512);
    }
}
