//! Paged KV block pool (DESIGN.md §Paged KV): fixed-size token blocks,
//! per-request block tables, refcounted prefix sharing, copy-on-write,
//! and the accounting behind scheduler preemption.
//!
//! The engine's AOT executables decode against full-context arena rows,
//! so the pool here is the *admission-control* layer: blocks are the
//! unit in which a request charges the [`KvPool`] byte budget, and a
//! block shared from the prefix cache charges its adopters NOTHING —
//! the bytes were paid once when the block was captured (they live in
//! the prefix cache's own budget). That models exactly the physical
//! sharing PagedAttention gets from block-indexed device memory: N
//! requests over a common prefix cost one copy of its blocks, so the
//! same KvPool budget admits strictly more concurrent requests than
//! the contiguous worst-case-row accounting (`serve_bench
//! --paged-compare` measures the ratio and CI gates it).
//!
//! Sharing is safe because shared blocks are immutable host captures
//! ([`CapturedBlock`]): a request never writes into one. The only block
//! a request writes is the partial tail of an adopted run, and
//! [`PagedKv::mark_shared`] keeps a *private* frame for it — that
//! private tail IS the copy-on-write (counted in `cow_copies`); full
//! shared blocks stay behind `Arc`s and drop when the last table and
//! the prefix cache let go.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kvcache::{take_cache_row_range, KvPool, KvState};
use crate::model::config::ModelConfig;
use crate::nbl::plan::ModelPlan;
use crate::runtime::literals::lit_from_tensor;
use crate::tensor::Tensor;

/// One immutable block of captured KV: per layer, host tensors
/// [1, filled, Hkv, dh] for the tokens `[start, start+filled)` of the
/// request that captured it (substituted layers hold `None`, so NBL's
/// structural saving applies per block). Shared between block tables
/// and the prefix cache by `Arc` — never mutated after capture.
pub struct CapturedBlock {
    /// Tokens this block holds (== block_tokens except a run's tail).
    pub filled: usize,
    /// Per layer: Some((k, v)) iff the capturing plan kept attention.
    layers: Vec<Option<(Tensor, Tensor)>>,
    /// Host bytes of the capture (f32).
    bytes: usize,
}

impl CapturedBlock {
    /// Capture tokens `[start, end)` of batch-1 `state` (row 0).
    pub fn capture(state: &KvState, start: usize, end: usize) -> Result<CapturedBlock> {
        if start >= end || end > state.pos {
            return Err(Error::Serving(format!(
                "block capture [{start}, {end}) outside prefilled range 0..{}",
                state.pos
            )));
        }
        let mut layers = Vec::with_capacity(state.caches.len());
        let mut bytes = 0usize;
        for c in &state.caches {
            match c {
                Some((k, v)) => {
                    let kt = take_cache_row_range(k, 0, start, end)?;
                    let vt = take_cache_row_range(v, 0, start, end)?;
                    bytes += 4 * (kt.len() + vt.len());
                    layers.push(Some((kt, vt)));
                }
                None => layers.push(None),
            }
        }
        Ok(CapturedBlock { filled: end - start, layers, bytes })
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// A captured block run: the leading `tokens` of one request's KV as a
/// sequence of blocks on absolute boundaries (block i covers tokens
/// [i*block_tokens, ...)). All runs start at position 0, so two runs
/// over a common prefix share block indices — capture with `reuse`
/// Arc-clones every full block already resident instead of re-copying
/// it (the incremental-publication half of zero-copy sharing).
pub struct PagedRun {
    /// Tokens covered: blocks concatenate to exactly this many.
    pub tokens: usize,
    /// Block size the run was captured at.
    pub block_tokens: usize,
    blocks: Vec<Arc<CapturedBlock>>,
    bytes: usize,
}

impl PagedRun {
    /// Capture the first `tokens` of batch-1 `state` as a block run.
    /// Returns the run and the bytes of *newly captured* blocks — block
    /// i is Arc-cloned from `reuse` when resident there as a full block
    /// (full blocks are immutable and position-aligned, so identity
    /// holds; a partial tail is never reused because the newer run may
    /// extend past it). `new_bytes` is what an incremental publication
    /// charges its budget: re-publishing a resident prefix costs 0.
    pub fn capture(
        state: &KvState,
        tokens: usize,
        block_tokens: usize,
        reuse: Option<&PagedRun>,
    ) -> Result<(PagedRun, usize)> {
        if block_tokens == 0 || tokens == 0 || tokens > state.pos {
            return Err(Error::Serving(format!(
                "paged capture of {tokens} tokens (block {block_tokens}) from state at {}",
                state.pos
            )));
        }
        if let Some(r) = reuse {
            if r.block_tokens != block_tokens {
                return Err(Error::Serving(format!(
                    "paged capture: reuse run has block size {} != {block_tokens}",
                    r.block_tokens
                )));
            }
        }
        let n = tokens.div_ceil(block_tokens);
        let mut blocks = Vec::with_capacity(n);
        let mut bytes = 0usize;
        let mut new_bytes = 0usize;
        for i in 0..n {
            let start = i * block_tokens;
            let end = (start + block_tokens).min(tokens);
            let full = end - start == block_tokens;
            let resident = if full {
                reuse.and_then(|r| {
                    r.blocks.get(i).filter(|b| b.filled == block_tokens).cloned()
                })
            } else {
                None
            };
            let b = match resident {
                Some(b) => b,
                None => {
                    let b = Arc::new(CapturedBlock::capture(state, start, end)?);
                    new_bytes += b.bytes;
                    b
                }
            };
            bytes += b.bytes;
            blocks.push(b);
        }
        Ok((PagedRun { tokens, block_tokens, blocks, bytes }, new_bytes))
    }

    /// Total host bytes of the run (shared + newly captured).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn blocks(&self) -> &[Arc<CapturedBlock>] {
        &self.blocks
    }

    /// Materialize a fresh batch-1 [`KvState`] at `self.tokens`: every
    /// kept layer gets a full-context row with the run's blocks laid at
    /// their absolute offsets (zero-padded past the run), ready for
    /// suffix prefill / decode. This is the ONE host pass a paged
    /// adoption performs (gauged as a splice) — no per-layer
    /// KvSnapshot expansion copy happens on this path.
    pub fn materialize(&self, plan: &ModelPlan, cfg: &ModelConfig) -> Result<KvState> {
        let mut state = KvState::empty(plan, cfg, 1, 1);
        let tok_stride = cfg.n_kv_heads * cfg.head_dim;
        for (li, lp) in plan.layers.iter().enumerate() {
            if !lp.attn.needs_kv() {
                continue;
            }
            let mut k_full = Tensor::zeros(vec![1, cfg.max_ctx, cfg.n_kv_heads, cfg.head_dim]);
            let mut v_full = Tensor::zeros(vec![1, cfg.max_ctx, cfg.n_kv_heads, cfg.head_dim]);
            for (bi, b) in self.blocks.iter().enumerate() {
                let Some((bk, bv)) = b.layers.get(li).and_then(|l| l.as_ref()) else {
                    return Err(Error::Serving(
                        "plan mismatch: KV layers differ between block run and plan".into(),
                    ));
                };
                let at = bi * self.block_tokens * tok_stride;
                k_full.data_mut()[at..at + bk.len()].copy_from_slice(bk.data());
                v_full.data_mut()[at..at + bv.len()].copy_from_slice(bv.data());
            }
            state.caches[li] = Some((lit_from_tensor(&k_full)?, lit_from_tensor(&v_full)?));
        }
        state.pos = self.tokens;
        Ok(state)
    }
}

/// One prefix-cache value in paged mode: the target's block run and, in
/// lockstep under speculation, the draft's (stored together so eviction
/// can never separate the pair — the PR 4 invariant carried over).
pub struct PagedEntry {
    /// Prompt tokens covered (== target.tokens).
    pub tokens: usize,
    pub target: PagedRun,
    pub draft: Option<PagedRun>,
}

impl PagedEntry {
    /// Total host bytes held by the entry's runs.
    pub fn bytes(&self) -> usize {
        self.target.bytes + self.draft.as_ref().map_or(0, |d| d.bytes)
    }
}

/// One logical block frame in a slot's table: a private (writable)
/// block charged to the pool, or a shared (immutable, zero-charge)
/// block adopted from the prefix cache.
enum Frame {
    Private,
    Shared(Arc<CapturedBlock>),
}

/// One side (target or draft) of a slot's block table.
struct Side {
    frames: Vec<Frame>,
    /// Tokens this side's cache actually covers (<= frames * block).
    tokens: usize,
}

impl Side {
    fn private_frames(&self) -> usize {
        self.frames.iter().filter(|f| matches!(f, Frame::Private)).count()
    }

    fn shared_frames(&self) -> usize {
        self.frames.len() - self.private_frames()
    }
}

struct SlotTables {
    target: Side,
    draft: Option<Side>,
}

/// Point-in-time block-pool counters the serving gauges mirror.
#[derive(Debug, Clone, Default)]
pub struct PagedStats {
    /// Block size in tokens.
    pub block_tokens: usize,
    /// Pool capacity in target-block units (how many target-side
    /// blocks the whole budget could hold).
    pub capacity_blocks: usize,
    /// Remaining budget in target-block units.
    pub free_blocks: usize,
    /// Private frames resident across all tables (pool bytes held).
    pub used_blocks: usize,
    /// Shared frames resident across all tables (zero pool charge —
    /// paid once by the prefix cache).
    pub shared_blocks: usize,
    /// Tokens actually cached across all tables (fragmentation
    /// numerator: the rest of the allocated frames is slack).
    pub live_tokens: usize,
    /// Private tail frames kept at adoption so a request never writes
    /// into a shared block — the copy-on-write count.
    pub cow_copies: u64,
    /// Slots evicted under block pressure for later re-admission.
    pub preemptions: u64,
    /// Warm adoptions that spliced a shared block run into a table.
    pub splices: u64,
    /// Prompt tokens covered by spliced runs (prefill work skipped
    /// without a per-adopter snapshot expansion copy).
    pub splice_tokens: u64,
}

impl PagedStats {
    /// 1 - live/allocated: the token slack trapped in allocated frames
    /// (internal fragmentation; contiguous rows waste `max_ctx - live`
    /// per request instead).
    pub fn fragmentation(&self) -> f64 {
        let frames = self.used_blocks + self.shared_blocks;
        if frames == 0 || self.block_tokens == 0 {
            return 0.0;
        }
        1.0 - self.live_tokens as f64 / (frames * self.block_tokens) as f64
    }
}

/// The block-table manager for the continuous scheduler: per-slot block
/// tables (target + draft side) charged block-by-block against the
/// server's [`KvPool`], with zero-charge splicing of shared prefix runs
/// and the preemption counter the scheduler drives.
pub struct PagedKv {
    /// Block size in tokens (admission granularity).
    block_tokens: usize,
    /// Pool bytes per target-side block.
    t_bpb: usize,
    /// Pool bytes per draft-side block (0 without speculation).
    d_bpb: usize,
    pool: Arc<KvPool>,
    tables: Vec<Option<SlotTables>>,
    cow_copies: u64,
    preemptions: u64,
    splices: u64,
    splice_tokens: u64,
}

impl PagedKv {
    /// `t_bpb`/`d_bpb`: §H.2 bytes of one block of the target / draft
    /// plan's KV (d_bpb = 0 disables the draft side).
    pub fn new(
        block_tokens: usize,
        t_bpb: usize,
        d_bpb: usize,
        pool: Arc<KvPool>,
        n_slots: usize,
    ) -> PagedKv {
        PagedKv {
            block_tokens,
            t_bpb: t_bpb.max(1),
            d_bpb,
            pool,
            tables: (0..n_slots).map(|_| None).collect(),
            cow_copies: 0,
            preemptions: 0,
            splices: 0,
            splice_tokens: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks needed to cover `tokens`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Pool bytes an all-private attach at these token counts charges —
    /// the scheduler's admission unit (replaces the contiguous
    /// worst-case `slot_bytes`).
    pub fn admit_bytes(&self, t_tokens: usize, d_tokens: Option<usize>) -> usize {
        self.blocks_for(t_tokens) * self.t_bpb
            + d_tokens.map_or(0, |d| self.blocks_for(d) * self.d_bpb)
    }

    /// True if `t_tokens`/`d_tokens` could EVER be resident (vs the
    /// whole capacity) — the never-fits drain check.
    pub fn would_ever_fit(&self, t_tokens: usize, d_tokens: Option<usize>) -> bool {
        self.admit_bytes(t_tokens, d_tokens) <= self.pool.capacity()
    }

    /// Build slot `slot`'s table with all-private frames covering the
    /// given token counts, charging the pool. Fails without side
    /// effects when the budget does not hold.
    pub fn attach(&mut self, slot: usize, t_tokens: usize, d_tokens: Option<usize>) -> Result<()> {
        match self.tables.get(slot) {
            Some(Some(_)) => {
                return Err(Error::Serving(format!("paged slot {slot} already attached")))
            }
            None => {
                return Err(Error::Serving(format!(
                    "paged slot {slot} out of range ({} rows)",
                    self.tables.len()
                )))
            }
            Some(None) => {}
        }
        let bytes = self.admit_bytes(t_tokens, d_tokens);
        let t_frames = self.blocks_for(t_tokens);
        let d_frames = d_tokens.map(|d| self.blocks_for(d));
        self.pool.try_take(bytes)?;
        let side = |frames: usize, tokens: usize| Side {
            frames: (0..frames).map(|_| Frame::Private).collect(),
            tokens,
        };
        let table = SlotTables {
            target: side(t_frames, t_tokens),
            draft: d_tokens.map(|d| side(d_frames.unwrap_or(0), d)),
        };
        if let Some(entry) = self.tables.get_mut(slot) {
            // nbl-lint: settles(charge): the installed table owns the debit; release() refunds it
            *entry = Some(table);
        }
        Ok(())
    }

    /// Splice `entry`'s shared runs into slot `slot`'s table: every
    /// full block the entry covers swaps the slot's private frame for
    /// the shared `Arc` and returns the private block's bytes to the
    /// pool — N adopters of one prefix hold its blocks once. The
    /// entry's partial tail block (if any) stays PRIVATE in the table:
    /// the request will write into that block as it decodes, and the
    /// kept private frame is the copy-on-write that protects the shared
    /// capture (counted in `cow_copies`). Infallible: only releases
    /// budget, never takes.
    pub fn mark_shared(&mut self, slot: usize, entry: &PagedEntry) {
        let Some(t) = self.tables.get_mut(slot).and_then(|t| t.as_mut()) else { return };
        let mut freed = 0usize;
        let mut splice_one = |side: &mut Side, run: &PagedRun, bpb: usize| {
            let mut cow = 0u64;
            for (i, b) in run.blocks.iter().enumerate() {
                if i >= side.frames.len() {
                    break;
                }
                if b.filled == run.block_tokens {
                    if matches!(side.frames[i], Frame::Private) {
                        freed += bpb;
                    }
                    side.frames[i] = Frame::Shared(b.clone());
                } else {
                    // partial tail: keep the private frame (CoW)
                    cow += 1;
                }
            }
            cow
        };
        let mut cow = splice_one(&mut t.target, &entry.target, self.t_bpb);
        if let (Some(ds), Some(dr)) = (t.draft.as_mut(), entry.draft.as_ref()) {
            cow += splice_one(ds, dr, self.d_bpb);
        }
        self.pool.give_back(freed);
        self.cow_copies += cow;
        self.splices += 1;
        self.splice_tokens += entry.tokens as u64;
    }

    /// Extend slot `slot`'s table to cover the new token counts,
    /// appending private frames as block boundaries are crossed. False
    /// (no side effects) when the pool cannot fund the growth — the
    /// scheduler then preempts a victim and retries. Token counts are
    /// monotonic (a rollback below a boundary keeps the frame: it will
    /// be rewritten, and giving it back mid-flight would thrash).
    pub fn grow(&mut self, slot: usize, t_tokens: usize, d_tokens: Option<usize>) -> bool {
        let Some(t) = self.tables.get(slot).and_then(|t| t.as_ref()) else { return false };
        let t_new = self
            .blocks_for(t_tokens.max(t.target.tokens))
            .saturating_sub(t.target.frames.len());
        let d_new = match (t.draft.as_ref(), d_tokens) {
            (Some(ds), Some(dt)) => self
                .blocks_for(dt.max(ds.tokens))
                .saturating_sub(ds.frames.len()),
            _ => 0,
        };
        let bytes = t_new * self.t_bpb + d_new * self.d_bpb;
        if self.pool.try_take(bytes).is_err() {
            return false;
        }
        let Some(t) = self.tables.get_mut(slot).and_then(|t| t.as_mut()) else {
            // unreachable (the table was read just above) — but if a
            // refactor ever breaks that, refund instead of leaking the
            // charge, so the pool identity holds
            self.pool.give_back(bytes);
            return false;
        };
        // nbl-lint: settles(charge): appended frames own the debit; release() refunds them
        t.target.frames.extend((0..t_new).map(|_| Frame::Private));
        t.target.tokens = t.target.tokens.max(t_tokens);
        if let (Some(ds), Some(dt)) = (t.draft.as_mut(), d_tokens) {
            ds.frames.extend((0..d_new).map(|_| Frame::Private));
            ds.tokens = ds.tokens.max(dt);
        }
        true
    }

    /// Drop slot `slot`'s table, returning its private frames' bytes to
    /// the pool (shared frames were never charged here; their `Arc`s
    /// drop and the data lives while the prefix cache or other tables
    /// still hold it).
    pub fn release(&mut self, slot: usize) {
        let Some(t) = self.tables.get_mut(slot).and_then(|t| t.take()) else { return };
        let mut bytes = t.target.private_frames() * self.t_bpb;
        if let Some(ds) = &t.draft {
            bytes += ds.private_frames() * self.d_bpb;
        }
        self.pool.give_back(bytes);
    }

    /// Evict slot `slot`'s blocks for later re-admission (the
    /// scheduler snapshots the row state first).
    pub fn preempt(&mut self, slot: usize) {
        self.release(slot);
        self.preemptions += 1;
    }

    pub fn is_attached(&self, slot: usize) -> bool {
        self.tables.get(slot).is_some_and(|t| t.is_some())
    }

    pub fn stats(&self) -> PagedStats {
        let mut used = 0usize;
        let mut shared = 0usize;
        let mut live = 0usize;
        for t in self.tables.iter().flatten() {
            used += t.target.private_frames();
            shared += t.target.shared_frames();
            live += t.target.tokens;
            if let Some(ds) = &t.draft {
                used += ds.private_frames();
                shared += ds.shared_frames();
                live += ds.tokens;
            }
        }
        PagedStats {
            block_tokens: self.block_tokens,
            capacity_blocks: self.pool.capacity() / self.t_bpb,
            free_blocks: (self.pool.capacity() - self.pool.in_use().min(self.pool.capacity()))
                / self.t_bpb,
            used_blocks: used,
            shared_blocks: shared,
            live_tokens: live,
            cow_copies: self.cow_copies,
            preemptions: self.preemptions,
            splices: self.splices,
            splice_tokens: self.splice_tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::kv_bytes;
    use crate::nbl::plan::ModelPlan;
    use crate::runtime::literals::tensor_from_lit;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 4,
            d_ff: 16,
            max_ctx: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// Batch-1 state with recognizable per-position cache values.
    fn state_at(plan: &ModelPlan, c: &ModelConfig, pos: usize) -> KvState {
        let mut st = KvState::empty(plan, c, 1, 1);
        for (li, lp) in plan.layers.iter().enumerate() {
            if lp.attn.needs_kv() {
                let t = Tensor::from_fn(vec![1, c.max_ctx, c.n_kv_heads, c.head_dim], |i| {
                    (li * 1000 + i) as f32
                });
                let lit = || crate::runtime::literals::lit_from_tensor(&t).unwrap();
                st.caches[li] = Some((lit(), lit()));
            }
        }
        st.pos = pos;
        st
    }

    #[test]
    fn capture_blocks_and_materialize_round_trip() {
        let c = cfg();
        let mut plan = ModelPlan::baseline(2);
        plan.drop_attn(0);
        let st = state_at(&plan, &c, 10);
        let (run, new_bytes) = PagedRun::capture(&st, 10, 4, None).unwrap();
        assert_eq!(run.blocks().len(), 3); // 4 + 4 + 2
        assert_eq!(run.blocks()[2].filled, 2);
        assert_eq!(run.bytes(), new_bytes);
        // one kept layer, k+v, 10 tokens of Hkv*dh f32s
        assert_eq!(run.bytes(), 2 * 10 * c.n_kv_heads * c.head_dim * 4);
        let back = run.materialize(&plan, &c).unwrap();
        assert_eq!(back.pos, 10);
        assert!(back.caches[0].is_none());
        let (k, _) = back.caches[1].as_ref().unwrap();
        let t = tensor_from_lit(k).unwrap();
        let stride = c.n_kv_heads * c.head_dim;
        assert_eq!(t.data()[0], 1000.0);
        assert_eq!(t.data()[10 * stride - 1], 1000.0 + (10 * stride - 1) as f32);
        assert!(t.data()[10 * stride..].iter().all(|&v| v == 0.0));
        // materializing under a different kept-layer pattern is rejected
        assert!(run.materialize(&ModelPlan::baseline(2), &c).is_err());
        // out-of-range captures are rejected
        assert!(PagedRun::capture(&st, 0, 4, None).is_err());
        assert!(PagedRun::capture(&st, 11, 4, None).is_err());
        assert!(PagedRun::capture(&st, 4, 0, None).is_err());
    }

    #[test]
    fn capture_reuses_resident_full_blocks() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let st8 = state_at(&plan, &c, 8);
        let (run8, b8) = PagedRun::capture(&st8, 8, 4, None).unwrap();
        assert!(b8 > 0);
        // extending the run: the two resident full blocks are Arc-cloned,
        // only the new tail is captured
        let st12 = state_at(&plan, &c, 12);
        let (run12, b12) = PagedRun::capture(&st12, 12, 4, Some(&run8)).unwrap();
        assert_eq!(run12.blocks().len(), 3);
        assert!(Arc::ptr_eq(&run12.blocks()[0], &run8.blocks()[0]));
        assert!(Arc::ptr_eq(&run12.blocks()[1], &run8.blocks()[1]));
        assert_eq!(b12, run12.blocks()[2].bytes());
        // re-publishing the exact resident prefix costs zero new bytes
        let (_, b_again) = PagedRun::capture(&st8, 8, 4, Some(&run8)).unwrap();
        assert_eq!(b_again, 0);
        // a PARTIAL tail is never reused: the 10-token run's tail block
        // holds 2 tokens and a 12-token capture must re-capture block 2
        let (run10, _) = PagedRun::capture(&st12, 10, 4, None).unwrap();
        let (run12b, b12b) = PagedRun::capture(&st12, 12, 4, Some(&run10)).unwrap();
        assert!(!Arc::ptr_eq(&run12b.blocks()[2], &run10.blocks()[2]));
        assert_eq!(b12b, run12b.blocks()[2].bytes());
        // mismatched block size is rejected
        assert!(PagedRun::capture(&st12, 12, 8, Some(&run8)).is_err());
    }

    #[test]
    fn attach_grow_release_account_the_pool() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let bpb = kv_bytes(&c, plan.kv_layers(), 1, 4, 4);
        let pool = Arc::new(KvPool::new(6 * bpb));
        let mut pk = PagedKv::new(4, bpb, 0, pool.clone(), 4);
        assert_eq!(pk.admit_bytes(7, None), 2 * bpb);
        pk.attach(0, 7, None).unwrap();
        assert_eq!(pool.in_use(), 2 * bpb);
        assert!(pk.is_attached(0));
        assert!(pk.attach(0, 1, None).is_err(), "double attach");
        // growth within the covered blocks is free; crossing a boundary
        // charges one more block
        assert!(pk.grow(0, 8, None));
        assert_eq!(pool.in_use(), 2 * bpb);
        assert!(pk.grow(0, 9, None));
        assert_eq!(pool.in_use(), 3 * bpb);
        // second table exhausts the budget mid-growth: refused with no
        // side effects, then preemption of slot 0 frees the blocks
        pk.attach(1, 12, None).unwrap();
        assert_eq!(pool.in_use(), 6 * bpb);
        assert!(!pk.grow(1, 13, None));
        assert_eq!(pool.in_use(), 6 * bpb);
        pk.preempt(0);
        assert!(!pk.is_attached(0));
        assert_eq!(pool.in_use(), 3 * bpb);
        assert!(pk.grow(1, 13, None));
        let s = pk.stats();
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.used_blocks, 4);
        assert_eq!(s.live_tokens, 13);
        assert_eq!(s.capacity_blocks, 6);
        assert_eq!(s.free_blocks, 2);
        pk.release(1);
        assert_eq!(pool.in_use(), 0);
        // release is idempotent
        pk.release(1);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn draft_side_charges_its_own_block_bytes() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let mut draft = ModelPlan::baseline(2);
        draft.drop_attn(1);
        let t_bpb = kv_bytes(&c, plan.kv_layers(), 1, 4, 4);
        let d_bpb = kv_bytes(&c, draft.kv_layers(), 1, 4, 4);
        let pool = Arc::new(KvPool::new(100 * t_bpb));
        let mut pk = PagedKv::new(4, t_bpb, d_bpb, pool.clone(), 2);
        pk.attach(0, 5, Some(5)).unwrap();
        assert_eq!(pool.in_use(), 2 * t_bpb + 2 * d_bpb);
        // lockstep growth extends both sides
        assert!(pk.grow(0, 9, Some(9)));
        assert_eq!(pool.in_use(), 3 * t_bpb + 3 * d_bpb);
        pk.release(0);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn mark_shared_swaps_full_blocks_and_keeps_cow_tail() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let bpb = kv_bytes(&c, plan.kv_layers(), 1, 4, 4);
        let pool = Arc::new(KvPool::new(100 * bpb));
        let st = state_at(&plan, &c, 10);
        let (run, _) = PagedRun::capture(&st, 10, 4, None).unwrap();
        let entry = PagedEntry { tokens: 10, target: run, draft: None };
        let mut pk = PagedKv::new(4, bpb, 0, pool.clone(), 2);
        // prompt of 14 tokens, 10 covered by the entry: 4 frames total,
        // blocks 0-1 become shared (bytes returned), block 2 stays
        // private (the entry's partial tail = the CoW copy), block 3 is
        // the request's own private growth
        pk.attach(0, 14, None).unwrap();
        assert_eq!(pool.in_use(), 4 * bpb);
        pk.mark_shared(0, &entry);
        assert_eq!(pool.in_use(), 2 * bpb, "shared blocks charge nothing");
        let s = pk.stats();
        assert_eq!(s.shared_blocks, 2);
        assert_eq!(s.used_blocks, 2);
        assert_eq!(s.cow_copies, 1);
        assert_eq!(s.splices, 1);
        assert_eq!(s.splice_tokens, 10);
        // the shared capture is refcounted: entry + one table
        assert_eq!(Arc::strong_count(&entry.target.blocks()[0]), 2);
        // a second adopter of the same entry shares the same Arcs
        pk.attach(1, 12, None).unwrap();
        pk.mark_shared(1, &entry);
        assert_eq!(Arc::strong_count(&entry.target.blocks()[0]), 3);
        assert_eq!(pool.in_use(), 3 * bpb);
        // release drops only private bytes and the Arc refs
        pk.release(0);
        assert_eq!(Arc::strong_count(&entry.target.blocks()[0]), 2);
        assert_eq!(pool.in_use(), bpb);
        pk.release(1);
        assert_eq!(Arc::strong_count(&entry.target.blocks()[0]), 1);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn cow_divergence_leaves_shared_capture_untouched() {
        // two adopters splice the same run, then each "writes" its own
        // divergent continuation by re-capturing its private state —
        // the shared blocks' contents must be bit-identical throughout
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let st = state_at(&plan, &c, 8);
        let (run, _) = PagedRun::capture(&st, 8, 4, None).unwrap();
        let before: Vec<f32> = run.blocks()[0].layers[0].as_ref().unwrap().0.data().to_vec();
        // adopter A materializes and extends with its own values
        let mut a = run.materialize(&plan, &c).unwrap();
        for cache in a.caches.iter_mut().flatten() {
            let mut kt = tensor_from_lit(&cache.0).unwrap();
            let stride = c.n_kv_heads * c.head_dim;
            for x in kt.data_mut()[8 * stride..10 * stride].iter_mut() {
                *x = -1.0;
            }
            cache.0 = crate::runtime::literals::lit_from_tensor(&kt).unwrap();
        }
        a.pos = 10;
        // adopter B likewise, different values
        let mut b = run.materialize(&plan, &c).unwrap();
        for cache in b.caches.iter_mut().flatten() {
            let mut kt = tensor_from_lit(&cache.0).unwrap();
            let stride = c.n_kv_heads * c.head_dim;
            for x in kt.data_mut()[8 * stride..12 * stride].iter_mut() {
                *x = -2.0;
            }
            cache.0 = crate::runtime::literals::lit_from_tensor(&kt).unwrap();
        }
        b.pos = 12;
        let (ra, _) = PagedRun::capture(&a, 10, 4, Some(&run)).unwrap();
        let (rb, _) = PagedRun::capture(&b, 12, 4, Some(&run)).unwrap();
        // divergent tails are independent...
        let ka = ra.blocks()[2].layers[0].as_ref().unwrap().0.data().to_vec();
        let kb = rb.blocks()[2].layers[0].as_ref().unwrap().0.data().to_vec();
        assert!(ka.iter().all(|&v| v == -1.0));
        assert!(kb.iter().all(|&v| v == -2.0));
        // ...while the shared prefix blocks are the SAME Arcs, unchanged
        assert!(Arc::ptr_eq(&ra.blocks()[0], &run.blocks()[0]));
        assert!(Arc::ptr_eq(&rb.blocks()[0], &run.blocks()[0]));
        assert_eq!(
            run.blocks()[0].layers[0].as_ref().unwrap().0.data(),
            before.as_slice()
        );
    }

    #[test]
    fn paged_budget_admits_more_than_contiguous_rows() {
        // the tentpole arithmetic: under one KvPool budget sized for two
        // contiguous worst-case rows, block-granular admission at short
        // prompt lengths fits strictly more concurrent requests
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let per_row = kv_bytes(&c, plan.kv_layers(), 1, c.max_ctx, 4);
        let bpb = kv_bytes(&c, plan.kv_layers(), 1, 4, 4);
        let pool = Arc::new(KvPool::new(2 * per_row));
        let mut pk = PagedKv::new(4, bpb, 0, pool.clone(), 8);
        // short requests: prompt 3 + a few decode tokens -> 1-2 blocks
        let mut admitted = 0;
        for s in 0..8 {
            if pk.admit_bytes(3, None) <= pool.capacity() - pool.in_use()
                && pk.attach(s, 3, None).is_ok()
            {
                admitted += 1;
            }
        }
        assert!(
            admitted > 2,
            "paged admitted {admitted}, contiguous accounting caps at 2"
        );
        assert!(pk.stats().fragmentation() > 0.0);
    }
}
