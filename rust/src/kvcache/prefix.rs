//! Prefix-aware KV reuse (DESIGN.md §Prefix cache): a token-ID radix
//! tree mapping prompt prefixes to host-side KV snapshots.
//!
//! Multi-tenant traffic repeats prompt prefixes constantly — system
//! prompts, few-shot headers, chat history — and recomputing their
//! prefill burns the compute NBL just saved. The serving path snapshots
//! the per-request KV cache at snap-aligned prefill boundaries
//! (insert-on-miss, so the tree warms itself under churn), and later
//! admissions adopt the longest cached prefix and prefill only the
//! uncovered suffix through the cache-appending chunk ops.
//!
//! Budgeting: snapshots are host tensors truncated to the prefix they
//! cover, accounted against a dedicated [`KvPool`] byte budget and
//! LRU-evicted under pressure. Lookups hand out `Arc` references, so an
//! eviction never invalidates an in-flight adoption — the bytes return
//! to the budget at eviction, the data lives until the last reader
//! drops it.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kvcache::paged::PagedEntry;
use crate::kvcache::{take_cache_row_prefix, KvLeaseOwned, KvPool, KvState};
use crate::model::config::ModelConfig;
use crate::nbl::plan::ModelPlan;
use crate::runtime::literals::lit_from_tensor;
use crate::tensor::Tensor;

/// Host-side copy of one request's KV cache truncated to a prompt
/// prefix: the value a radix-tree entry stores and a warm admission
/// restores. Substituted layers hold `None`, so NBL's structural KV
/// saving applies to snapshots too.
pub struct KvSnapshot {
    /// Prompt tokens covered: cache entries [0, pos) are valid.
    pub pos: usize,
    /// Per layer: Some((k, v)) host tensors [1, pos, Hkv, dh] iff the
    /// plan kept attention there.
    caches: Vec<Option<(Tensor, Tensor)>>,
    bytes: usize,
}

impl KvSnapshot {
    /// Snapshot the first `pos` cached tokens of batch-1 `state`
    /// (row 0). Taken at prefill/chunk boundaries, so `pos` never
    /// exceeds `state.pos`; entries past `pos` (padding garbage or a
    /// longer context) are dropped.
    pub fn from_state(state: &KvState, pos: usize) -> Result<KvSnapshot> {
        if pos == 0 || pos > state.pos {
            return Err(Error::Serving(format!(
                "snapshot at {pos} outside prefilled range 1..={}",
                state.pos
            )));
        }
        let mut caches = Vec::with_capacity(state.caches.len());
        let mut bytes = 0usize;
        for c in &state.caches {
            match c {
                Some((k, v)) => {
                    let kt = take_cache_row_prefix(k, 0, pos)?;
                    let vt = take_cache_row_prefix(v, 0, pos)?;
                    bytes += 4 * (kt.len() + vt.len());
                    caches.push(Some((kt, vt)));
                }
                None => caches.push(None),
            }
        }
        Ok(KvSnapshot { pos, caches, bytes })
    }

    /// Host bytes of the truncated copy — the unit the prefix pool's
    /// budget accounts (scales with the covered prefix, not Tmax).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Materialize a fresh batch-1 [`KvState`] at `self.pos`: every
    /// kept layer gets a full-context row holding the snapshot prefix
    /// (zero-padded past it), ready for suffix-only chunk prefill.
    pub fn restore_state(&self, plan: &ModelPlan, cfg: &ModelConfig) -> Result<KvState> {
        let mut state = KvState::empty(plan, cfg, 1, 1);
        if state.caches.len() != self.caches.len() {
            return Err(Error::Serving(format!(
                "plan mismatch: snapshot has {} layers, plan {}",
                self.caches.len(),
                state.caches.len()
            )));
        }
        for ((dst, src), lp) in state.caches.iter_mut().zip(&self.caches).zip(&plan.layers) {
            match (src, lp.attn.needs_kv()) {
                (Some((k, v)), true) => {
                    *dst = Some((expand_row(k, cfg, self.pos)?, expand_row(v, cfg, self.pos)?));
                }
                (None, false) => {}
                _ => {
                    return Err(Error::Serving(
                        "plan mismatch: KV layers differ between snapshot and plan".into(),
                    ))
                }
            }
        }
        state.pos = self.pos;
        Ok(state)
    }
}

/// Zero-padded full-context literal [1, Tmax, Hkv, dh] holding a
/// snapshot row [1, pos, Hkv, dh] in its leading entries.
fn expand_row(src: &Tensor, cfg: &ModelConfig, pos: usize) -> Result<xla::Literal> {
    if src.shape() != [1, pos, cfg.n_kv_heads, cfg.head_dim].as_slice() {
        return Err(Error::Shape(format!(
            "snapshot row {:?} vs model [1, {pos}, {}, {}]",
            src.shape(),
            cfg.n_kv_heads,
            cfg.head_dim
        )));
    }
    let mut full = Tensor::zeros(vec![1, cfg.max_ctx, cfg.n_kv_heads, cfg.head_dim]);
    full.data_mut()[..src.len()].copy_from_slice(src.data());
    lit_from_tensor(&full)
}

/// One radix-tree value: legacy whole-prefix host snapshots (the
/// target's and, under speculation, the draft's) or — in paged mode —
/// a refcounted block-run entry whose full blocks adopters splice
/// without any per-adopter expansion copy. Lookup hands out `Arc`
/// clones either way, so eviction never invalidates a reader.
#[derive(Clone)]
pub enum PrefixValue {
    Snaps(Arc<Vec<KvSnapshot>>),
    Paged(Arc<PagedEntry>),
}

impl PrefixValue {
    /// Prompt tokens the value covers.
    pub fn tokens(&self) -> usize {
        match self {
            PrefixValue::Snaps(s) => s.first().map_or(0, |x| x.pos),
            PrefixValue::Paged(e) => e.tokens,
        }
    }

    pub fn snaps(&self) -> Option<&Arc<Vec<KvSnapshot>>> {
        match self {
            PrefixValue::Snaps(s) => Some(s),
            PrefixValue::Paged(_) => None,
        }
    }

    pub fn paged(&self) -> Option<&Arc<PagedEntry>> {
        match self {
            PrefixValue::Snaps(_) => None,
            PrefixValue::Paged(e) => Some(e),
        }
    }
}

/// Point-in-time counters the serving gauges mirror.
#[derive(Debug, Clone, Default)]
pub struct PrefixStats {
    /// Probes whose cached prefix was actually ADOPTED into a slot
    /// (reported by the caller via [`PrefixCache::note_adopted`] once
    /// the adoption really happened — a probe alone proves nothing).
    pub hits: u64,
    /// Probes that found nothing, plus probes whose hit proved unusable
    /// and fell back to cold prefill ([`PrefixCache::note_fallback`]).
    pub misses: u64,
    /// Prompt tokens served from adopted prefixes (prefill work
    /// actually skipped).
    pub hit_tokens: u64,
    /// Entries published into the tree.
    pub inserts: u64,
    /// Entries LRU-evicted under the byte budget.
    pub evictions: u64,
    /// Publication rounds skipped because the covered run/snapshot was
    /// already resident (the small-fix gauge: no host copy was built).
    pub publish_skips: u64,
    /// Live entries.
    pub entries: usize,
    /// Snapshot bytes resident (budget accounting, not Arc liveness).
    pub bytes_in_use: usize,
    /// Byte budget.
    pub capacity_bytes: usize,
}

/// One radix-tree value: the snapshots for a prefix (the target's and,
/// under speculation, the draft's — stored together so the pair can
/// never fall out of lockstep) plus LRU/budget bookkeeping.
struct Entry {
    value: PrefixValue,
    last_used: u64,
    /// Budget reservation; returns the bytes at eviction (the Arc'd
    /// data itself lives until the last in-flight adoption drops it).
    _lease: KvLeaseOwned,
}

/// Radix-tree node: `edge` labels the path from the parent (nonempty
/// except at the root); an entry, when present, covers exactly the
/// concatenated path from the root.
struct Node {
    edge: Vec<u32>,
    children: Vec<Node>,
    entry: Option<Entry>,
}

impl Node {
    fn leaf(edge: Vec<u32>) -> Node {
        Node { edge, children: Vec::new(), entry: None }
    }
}

/// The prompt-prefix radix tree: token-ID edges, compressed (edges are
/// split lazily on insert), snapshots at node boundaries, LRU eviction
/// under a dedicated byte budget.
pub struct PrefixCache {
    root: Node,
    pool: Arc<KvPool>,
    /// Monotonic LRU clock (bumped per probe and per insert).
    clock: u64,
    entries: usize,
    hits: u64,
    misses: u64,
    hit_tokens: u64,
    inserts: u64,
    evictions: u64,
    publish_skips: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize) -> PrefixCache {
        PrefixCache {
            root: Node::leaf(Vec::new()),
            pool: Arc::new(KvPool::new(budget_bytes)),
            clock: 0,
            entries: 0,
            hits: 0,
            misses: 0,
            hit_tokens: 0,
            inserts: 0,
            evictions: 0,
            publish_skips: 0,
        }
    }

    /// Longest cached prefix of `tokens` no longer than `cap` (callers
    /// cap at len-1 so a nonempty suffix remains to produce first-token
    /// logits). Touches every matched ancestor entry for LRU purposes —
    /// a prefix of a useful prompt is itself useful.
    ///
    /// Accounting: an empty result counts a miss immediately; a found
    /// prefix counts NOTHING until the caller resolves it with
    /// [`note_adopted`](Self::note_adopted) (it was restored into a
    /// slot) or [`note_fallback`](Self::note_fallback) (it proved
    /// unusable — e.g. the padded suffix bucket would cross the context
    /// boundary — and the admission prefilled cold). Counting at probe
    /// time would let the hit-rate gauge stay green while every
    /// adoption silently fell back.
    pub fn lookup(&mut self, tokens: &[u32], cap: usize) -> Option<PrefixValue> {
        self.clock += 1;
        let best = descend(&mut self.root, tokens, 0, cap, self.clock);
        if best.is_none() {
            self.misses += 1;
        }
        best
    }

    /// A probed prefix of `tokens_covered` tokens was restored into a
    /// slot: the prefill work was really skipped.
    pub fn note_adopted(&mut self, tokens_covered: usize) {
        self.hits += 1;
        self.hit_tokens += tokens_covered as u64;
    }

    /// A probed prefix went unused (cold fallback): count it as a miss
    /// so the hit rate reflects adoptions, not tree contents.
    pub fn note_fallback(&mut self) {
        self.misses += 1;
    }

    /// A publication round found its covered prefix already resident
    /// and skipped the host copies it would have built (the gauge for
    /// the skip-when-resident small fix).
    pub fn note_publish_skip(&mut self) {
        self.publish_skips += 1;
    }

    /// Longest cached prefix length (<= cap) WITHOUT touching LRU order
    /// or the probe counters — the admission guard peeks the queue head
    /// every scheduler iteration while a chunked machine runs, and a
    /// waiting head must not distort stats or recency.
    pub fn covered(&self, tokens: &[u32], cap: usize) -> usize {
        let mut node = &self.root;
        let mut depth = 0;
        let mut best = 0;
        loop {
            if depth > 0 && node.entry.is_some() {
                best = depth;
            }
            let rest = &tokens[depth..];
            let next = node
                .children
                .iter()
                .find(|c| depth + c.edge.len() <= cap && rest.starts_with(&c.edge));
            match next {
                Some(c) => {
                    depth += c.edge.len();
                    node = c;
                }
                None => return best,
            }
        }
    }

    /// The deepest cached value for a prefix of `tokens` (<= cap),
    /// WITHOUT touching LRU order or the probe counters — the paged
    /// publication path reuses the resident run's blocks to capture
    /// only the delta, and that read must not distort stats.
    pub fn peek_value(&self, tokens: &[u32], cap: usize) -> Option<PrefixValue> {
        let mut node = &self.root;
        let mut depth = 0;
        let mut best = None;
        loop {
            if depth > 0 {
                if let Some(e) = node.entry.as_ref() {
                    best = Some(e.value.clone());
                }
            }
            let rest = &tokens[depth..];
            let next = node
                .children
                .iter()
                .find(|c| depth + c.edge.len() <= cap && rest.starts_with(&c.edge));
            match next {
                Some(c) => {
                    depth += c.edge.len();
                    node = c;
                }
                None => return best,
            }
        }
    }

    /// LRU-touch the entry at exactly `tokens`, if present — the
    /// publish path's cheap dedup: building a snapshot is a multi-layer
    /// host copy of the whole covered prefix, so callers check-and-touch
    /// BEFORE constructing one that insert would only throw away.
    pub fn touch(&mut self, tokens: &[u32]) -> bool {
        self.clock += 1;
        match find_exact(&mut self.root, tokens) {
            Some(e) => {
                e.last_used = self.clock;
                true
            }
            None => false,
        }
    }

    /// Publish snapshots covering exactly `tokens` (every snapshot's
    /// `pos` must equal `tokens.len()`). Dedups against an existing
    /// entry (touch, keep the resident copy), LRU-evicts under the byte
    /// budget, and returns false when the entry cannot be stored (still
    /// over budget with an empty tree, or malformed).
    pub fn insert(&mut self, tokens: &[u32], snaps: Vec<KvSnapshot>) -> bool {
        if tokens.is_empty()
            || snaps.is_empty()
            || snaps.iter().any(|s| s.pos != tokens.len())
        {
            return false;
        }
        let bytes: usize = snaps.iter().map(|s| s.bytes()).sum();
        self.insert_value(tokens, PrefixValue::Snaps(Arc::new(snaps)), bytes)
    }

    /// Publish a paged block-run entry covering exactly `tokens`.
    /// `new_bytes` is the bytes of blocks captured fresh for this entry
    /// — blocks Arc-shared from an already-resident run were charged
    /// when first published, so an incremental publication (and a
    /// re-publication of a fully resident prefix) charges only the
    /// delta. The shared blocks stay alive through the `Arc`s even if
    /// the entry that introduced them is LRU-evicted first; the budget
    /// therefore tracks what was *charged*, not exact liveness (see
    /// DESIGN.md §Paged KV).
    pub fn insert_paged(&mut self, tokens: &[u32], entry: Arc<PagedEntry>, new_bytes: usize) -> bool {
        if tokens.is_empty() || entry.tokens != tokens.len() {
            return false;
        }
        self.insert_value(tokens, PrefixValue::Paged(entry), new_bytes)
    }

    /// Shared insert tail: dedup-touch, never-fits refusal, LRU
    /// eviction, budget lease, radix insert.
    fn insert_value(&mut self, tokens: &[u32], value: PrefixValue, bytes: usize) -> bool {
        self.clock += 1;
        if let Some(e) = find_exact(&mut self.root, tokens) {
            e.last_used = self.clock;
            return false;
        }
        if bytes > self.pool.capacity() {
            // an entry that can NEVER fit must be refused before the
            // eviction loop, which would otherwise drain every resident
            // (useful) entry as collateral and only then give up
            return false;
        }
        while !self.pool.would_fit(bytes) {
            if !self.evict_lru() {
                return false;
            }
        }
        let Ok(lease) = KvPool::reserve_owned(&self.pool, bytes) else {
            return false;
        };
        let node = insert_node(&mut self.root, tokens);
        node.entry = Some(Entry {
            value,
            last_used: self.clock,
            _lease: lease,
        });
        self.entries += 1;
        self.inserts += 1;
        true
    }

    /// Drop the least-recently-used entry and prune newly bare
    /// subtrees; false when the tree holds no entries.
    fn evict_lru(&mut self) -> bool {
        let Some(oldest) = min_used(&self.root) else {
            return false;
        };
        remove_entry(&mut self.root, oldest);
        prune(&mut self.root);
        self.entries -= 1;
        self.evictions += 1;
        true
    }

    pub fn entries(&self) -> usize {
        self.entries
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            hit_tokens: self.hit_tokens,
            inserts: self.inserts,
            evictions: self.evictions,
            publish_skips: self.publish_skips,
            entries: self.entries,
            bytes_in_use: self.pool.in_use(),
            capacity_bytes: self.pool.capacity(),
        }
    }
}

/// Walk matched edges collecting the deepest entry at depth <= cap.
fn descend(
    node: &mut Node,
    rest: &[u32],
    depth: usize,
    cap: usize,
    clock: u64,
) -> Option<PrefixValue> {
    let mut best = None;
    if depth > 0 {
        if let Some(e) = node.entry.as_mut() {
            e.last_used = clock;
            best = Some(e.value.clone());
        }
    }
    if let Some(c) = node
        .children
        .iter_mut()
        .find(|c| depth + c.edge.len() <= cap && rest.starts_with(&c.edge))
    {
        let el = c.edge.len();
        if let Some(deeper) = descend(c, &rest[el..], depth + el, cap, clock) {
            best = Some(deeper);
        }
    }
    best
}

/// The entry at exactly `rest` under `node`, if present (a prefix that
/// ends mid-edge has no entry by construction).
fn find_exact<'a>(node: &'a mut Node, rest: &[u32]) -> Option<&'a mut Entry> {
    if rest.is_empty() {
        return node.entry.as_mut();
    }
    let c = node.children.iter_mut().find(|c| rest.starts_with(&c.edge))?;
    let el = c.edge.len();
    find_exact(c, &rest[el..])
}

/// Radix insert: create (splitting edges as needed) and return the node
/// whose path from the root is exactly `rest` deeper than `node`.
fn insert_node<'a>(node: &'a mut Node, rest: &[u32]) -> &'a mut Node {
    if rest.is_empty() {
        return node;
    }
    let Some(i) = node.children.iter().position(|c| c.edge[0] == rest[0]) else {
        node.children.push(Node::leaf(rest.to_vec()));
        // nbl-lint: allow(panic): last_mut of the element pushed on the previous line
        return node.children.last_mut().unwrap();
    };
    let common = lcp(&node.children[i].edge, rest);
    if common == node.children[i].edge.len() {
        return insert_node(&mut node.children[i], &rest[common..]);
    }
    // split the edge: an intermediate node takes the shared prefix and
    // the old child keeps the remainder
    let mid = Node::leaf(rest[..common].to_vec());
    let mut old = std::mem::replace(&mut node.children[i], mid);
    old.edge.drain(..common);
    node.children[i].children.push(old);
    if common == rest.len() {
        &mut node.children[i]
    } else {
        node.children[i].children.push(Node::leaf(rest[common..].to_vec()));
        // nbl-lint: allow(panic): last_mut of the element pushed on the previous line
        node.children[i].children.last_mut().unwrap()
    }
}

fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

fn min_used(node: &Node) -> Option<u64> {
    let mut best = node.entry.as_ref().map(|e| e.last_used);
    for c in &node.children {
        if let Some(m) = min_used(c) {
            best = Some(best.map_or(m, |b| b.min(m)));
        }
    }
    best
}

fn remove_entry(node: &mut Node, used: u64) -> bool {
    if node.entry.as_ref().is_some_and(|e| e.last_used == used) {
        node.entry = None;
        return true;
    }
    node.children.iter_mut().any(|c| remove_entry(c, used))
}

/// Drop subtrees that carry no entries (post-eviction cleanup; chains
/// of entry-less intermediate nodes above a surviving entry stay —
/// harmless, and re-merging edges is not worth the churn).
fn prune(node: &mut Node) {
    for c in &mut node.children {
        prune(c);
    }
    node.children.retain(|c| c.entry.is_some() || !c.children.is_empty());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbl::plan::ModelPlan;
    use crate::runtime::literals::tensor_from_lit;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 4,
            d_ff: 16,
            max_ctx: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// Batch-1 state with recognizable per-position cache values.
    fn state_at(plan: &ModelPlan, c: &ModelConfig, pos: usize) -> KvState {
        let mut st = KvState::empty(plan, c, 1, 1);
        for (li, lp) in plan.layers.iter().enumerate() {
            if lp.attn.needs_kv() {
                let t = Tensor::from_fn(vec![1, c.max_ctx, c.n_kv_heads, c.head_dim], |i| {
                    (li * 1000 + i) as f32
                });
                let lit = || lit_from_tensor(&t).unwrap();
                st.caches[li] = Some((lit(), lit()));
            }
        }
        st.pos = pos;
        st
    }

    fn snap_for(plan: &ModelPlan, c: &ModelConfig, pos: usize) -> KvSnapshot {
        KvSnapshot::from_state(&state_at(plan, c, pos), pos).unwrap()
    }

    #[test]
    fn snapshot_truncates_and_restores() {
        let c = cfg();
        let mut plan = ModelPlan::baseline(2);
        plan.drop_attn(0);
        let st = state_at(&plan, &c, 10);
        let snap = KvSnapshot::from_state(&st, 6).unwrap();
        assert_eq!(snap.pos, 6);
        // one kept layer, k+v, 6 tokens of Hkv*dh floats, 4 bytes each
        assert_eq!(snap.bytes(), 2 * 6 * c.n_kv_heads * c.head_dim * 4);
        // out-of-range snapshots are rejected
        assert!(KvSnapshot::from_state(&st, 0).is_err());
        assert!(KvSnapshot::from_state(&st, 11).is_err());
        // restore: prefix carried, tail zero-padded, pos adopted
        let restored = snap.restore_state(&plan, &c).unwrap();
        assert_eq!(restored.pos, 6);
        assert!(restored.caches[0].is_none());
        let (k, _) = restored.caches[1].as_ref().unwrap();
        let t = tensor_from_lit(k).unwrap();
        assert_eq!(t.shape(), &[1, c.max_ctx, c.n_kv_heads, c.head_dim]);
        let stride = c.n_kv_heads * c.head_dim;
        assert_eq!(t.data()[0], 1000.0);
        assert_eq!(t.data()[6 * stride - 1], 1000.0 + (6 * stride - 1) as f32);
        assert!(t.data()[6 * stride..].iter().all(|&v| v == 0.0));
        // restoring under a different kept-layer pattern is rejected
        let full = ModelPlan::baseline(2);
        assert!(snap.restore_state(&full, &c).is_err());
    }

    #[test]
    fn radix_longest_match_with_edge_splits() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let mut cache = PrefixCache::new(1 << 20);
        let long: Vec<u32> = (0..12).collect();
        assert!(cache.insert(&long[..4], vec![snap_for(&plan, &c, 4)]));
        assert!(cache.insert(&long[..8], vec![snap_for(&plan, &c, 8)]));
        // diverging branch forces an edge split at depth 6
        let mut fork = long[..6].to_vec();
        fork.extend([90, 91, 92]);
        assert!(cache.insert(&fork, vec![snap_for(&plan, &c, 9)]));
        assert_eq!(cache.entries(), 3);
        // longest match wins; cap bounds the depth
        assert_eq!(cache.lookup(&long, 11).unwrap().tokens(), 8);
        assert_eq!(cache.lookup(&long, 7).unwrap().tokens(), 4);
        assert_eq!(cache.lookup(&fork, 8).unwrap().tokens(), 4);
        assert_eq!(cache.lookup(&fork, 9).unwrap().tokens(), 9);
        // no shared prefix at all -> miss
        assert!(cache.lookup(&[50, 51], 1).is_none());
        // accounting is ADOPTION-time: the four successful probes count
        // nothing until the caller resolves them (hit vs cold fallback)
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 1);
        cache.note_adopted(8);
        cache.note_adopted(4);
        cache.note_fallback();
        let s = cache.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 2);
        assert_eq!(s.hit_tokens, 12);
    }

    #[test]
    fn covered_and_touch_are_stat_free_dedup_paths() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let mut cache = PrefixCache::new(1 << 20);
        let toks: Vec<u32> = (0..8).collect();
        assert!(cache.insert(&toks[..4], vec![snap_for(&plan, &c, 4)]));
        // stat-free peek: longest coverage under the cap, no counters
        assert_eq!(cache.covered(&toks, 7), 4);
        assert_eq!(cache.covered(&toks, 3), 0);
        assert_eq!(cache.covered(&[9, 9], 1), 0);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        // touch dedups without building a snapshot; misses at non-entry
        // depths (mid-edge or unknown prefixes) report absent
        assert!(cache.touch(&toks[..4]));
        assert!(!cache.touch(&toks[..3]));
        assert!(!cache.touch(&toks));
        assert_eq!(cache.stats().inserts, 1);
    }

    #[test]
    fn insert_dedups_and_touches() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let mut cache = PrefixCache::new(1 << 20);
        let toks: Vec<u32> = (0..4).collect();
        assert!(cache.insert(&toks, vec![snap_for(&plan, &c, 4)]));
        assert!(!cache.insert(&toks, vec![snap_for(&plan, &c, 4)]));
        assert_eq!(cache.entries(), 1);
        assert_eq!(cache.stats().inserts, 1);
        // a mis-sized snapshot set is refused outright
        assert!(!cache.insert(&toks, vec![snap_for(&plan, &c, 3)]));
        assert!(!cache.insert(&toks, vec![]));
    }

    #[test]
    fn lru_eviction_frees_budget_but_not_readers() {
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let one = snap_for(&plan, &c, 4).bytes();
        // room for exactly two entries
        let mut cache = PrefixCache::new(2 * one + one / 2);
        let a: Vec<u32> = vec![1, 2, 3, 4];
        let b: Vec<u32> = vec![5, 6, 7, 8];
        let d: Vec<u32> = vec![9, 10, 11, 12];
        assert!(cache.insert(&a, vec![snap_for(&plan, &c, 4)]));
        assert!(cache.insert(&b, vec![snap_for(&plan, &c, 4)]));
        assert_eq!(cache.stats().bytes_in_use, 2 * one);
        // hold a reference to A, touch it, then overflow with D: the
        // LRU victim must be B, and the held Arc must stay readable
        let held = cache.lookup(&[1, 2, 3, 4, 99], 4).unwrap();
        assert!(cache.insert(&d, vec![snap_for(&plan, &c, 4)]));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert_eq!(s.bytes_in_use, 2 * one);
        assert!(cache.lookup(&b, 4).is_none(), "LRU victim must be B");
        assert_eq!(cache.lookup(&a, 4).unwrap().tokens(), 4);
        assert_eq!(cache.lookup(&d, 4).unwrap().tokens(), 4);
        assert_eq!(held.tokens(), 4, "evictions never invalidate readers");
        // an entry that can NEVER fit is refused up front — without
        // draining the resident entries as collateral
        let big: Vec<u32> = (0..12).collect();
        assert!(!cache.insert(&big, vec![snap_for(&plan, &c, 12)]));
        let s = cache.stats();
        assert_eq!(s.entries, 2, "oversized insert must not drain the tree");
        assert_eq!(s.evictions, 1);
        assert_eq!(s.bytes_in_use, 2 * one);
        // same refusal on an empty cache
        let mut tiny = PrefixCache::new(one / 2);
        assert!(!tiny.insert(&a, vec![snap_for(&plan, &c, 4)]));
        assert_eq!(tiny.stats().bytes_in_use, 0);
    }

    #[test]
    fn paired_snapshots_stay_in_lockstep() {
        // one entry carries the target AND draft snapshots, so eviction
        // can never separate them (the serving lockstep invariant)
        let c = cfg();
        let target = ModelPlan::baseline(2);
        let mut draft = ModelPlan::baseline(2);
        draft.drop_attn(1);
        let mut cache = PrefixCache::new(1 << 20);
        let toks: Vec<u32> = (0..4).collect();
        let pair = vec![snap_for(&target, &c, 4), snap_for(&draft, &c, 4)];
        assert!(cache.insert(&toks, pair));
        let got = cache.lookup(&toks, 4).unwrap();
        let snaps = got.snaps().unwrap();
        assert_eq!(snaps.len(), 2);
        assert!(snaps[0].restore_state(&target, &c).is_ok());
        assert!(snaps[1].restore_state(&draft, &c).is_ok());
        assert!(snaps[1].restore_state(&target, &c).is_err());
        assert!(got.paged().is_none());
    }

    #[test]
    fn paged_entries_charge_only_their_delta() {
        use crate::kvcache::paged::{PagedEntry, PagedRun};
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let st = state_at(&plan, &c, 8);
        let (run4, b4) = PagedRun::capture(&st, 4, 4, None).unwrap();
        // budget sized so both entries only fit if the extension is
        // delta-charged (full re-charge would need b4 + b8 > budget)
        let e4 = Arc::new(PagedEntry { tokens: 4, target: run4, draft: None });
        let (run8, b8_delta) =
            PagedRun::capture(&st, 8, 4, Some(&e4.target)).unwrap();
        assert_eq!(b8_delta, b4, "one new full block");
        let e8 = Arc::new(PagedEntry { tokens: 8, target: run8, draft: None });
        let toks: Vec<u32> = (0..8).collect();
        let mut cache = PrefixCache::new(2 * b4 + b4 / 2);
        assert!(cache.insert_paged(&toks[..4], e4.clone(), b4));
        assert!(cache.insert_paged(&toks, e8, b8_delta));
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 0, "delta charge must fit without eviction");
        assert_eq!(s.bytes_in_use, 2 * b4);
        // lookup returns the paged value; the snaps accessor is None
        let got = cache.lookup(&toks, 7).unwrap();
        assert_eq!(got.tokens(), 4);
        assert!(got.paged().is_some());
        assert!(got.snaps().is_none());
        // mis-sized or empty entries are refused
        let (bad, nb) = PagedRun::capture(&st, 4, 4, None).unwrap();
        let bad = Arc::new(PagedEntry { tokens: 4, target: bad, draft: None });
        assert!(!cache.insert_paged(&toks[..3], bad.clone(), nb));
        assert!(!cache.insert_paged(&[], bad, 0));
    }

    #[test]
    fn peek_value_is_stat_free_and_publish_skips_count() {
        use crate::kvcache::paged::{PagedEntry, PagedRun};
        let c = cfg();
        let plan = ModelPlan::baseline(2);
        let st = state_at(&plan, &c, 8);
        let (run, nb) = PagedRun::capture(&st, 4, 4, None).unwrap();
        let e = Arc::new(PagedEntry { tokens: 4, target: run, draft: None });
        let toks: Vec<u32> = (0..8).collect();
        let mut cache = PrefixCache::new(1 << 20);
        assert!(cache.insert_paged(&toks[..4], e, nb));
        // peek finds the deepest resident value without stats/LRU churn
        assert_eq!(cache.peek_value(&toks, 7).unwrap().tokens(), 4);
        assert!(cache.peek_value(&toks, 3).is_none());
        assert!(cache.peek_value(&[9, 9], 1).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        assert_eq!(s.publish_skips, 0);
        cache.note_publish_skip();
        cache.note_publish_skip();
        assert_eq!(cache.stats().publish_skips, 2);
    }
}
