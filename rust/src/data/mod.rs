//! Data plumbing: byte-level tokenizer + corpus loading + calibration and
//! eval window extraction.

pub mod corpus;
pub mod tokenizer;

pub use corpus::Corpus;
pub use tokenizer::ByteTokenizer;
