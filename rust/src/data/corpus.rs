//! Corpus loading + deterministic window extraction for calibration and
//! perplexity evaluation (the paper's 256-sample C4/WikiText protocol).

use crate::data::tokenizer::ByteTokenizer;
use crate::error::{Error, Result};
use crate::model::artifacts::Artifacts;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusId {
    TinyC4,
    TinyWiki,
    /// Concatenation of both — the models' pretraining distribution
    /// (default for calibration; single corpora are the F.1 ablation).
    Mix,
}

impl CorpusId {
    pub fn key(self, split: &str) -> String {
        match self {
            CorpusId::TinyC4 => format!("tinyc4_{split}"),
            CorpusId::TinyWiki => format!("tinywiki_{split}"),
            CorpusId::Mix => format!("mix_{split}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CorpusId::TinyC4 => "tiny-c4",
            CorpusId::TinyWiki => "tiny-wiki",
            CorpusId::Mix => "mix",
        }
    }
}

pub struct Corpus {
    pub id: CorpusId,
    pub split: String,
    pub tokens: Vec<u32>,
}

impl Corpus {
    pub fn load(artifacts: &Artifacts, id: CorpusId, split: &str) -> Result<Corpus> {
        if id == CorpusId::Mix {
            let a = Corpus::load(artifacts, CorpusId::TinyC4, split)?;
            let b = Corpus::load(artifacts, CorpusId::TinyWiki, split)?;
            let mut tokens = a.tokens;
            tokens.extend(b.tokens);
            return Ok(Corpus { id, split: split.to_string(), tokens });
        }
        let path = artifacts.corpus_path(&id.key(split))?;
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        let tokens = ByteTokenizer::new().encode(&text);
        if tokens.is_empty() {
            return Err(Error::Artifact(format!("empty corpus {}", path.display())));
        }
        Ok(Corpus { id, split: split.to_string(), tokens })
    }

    /// `n` deterministic windows of `len` tokens (seeded; reproducible).
    pub fn windows(&self, n: usize, len: usize, seed: u64) -> Vec<&[u32]> {
        let mut rng = Rng::new(seed);
        let span = self.tokens.len().saturating_sub(len + 1).max(1);
        (0..n)
            .map(|_| {
                let start = rng.below(span);
                &self.tokens[start..start + len]
            })
            .collect()
    }

    /// Sequential non-overlapping windows (perplexity protocol).
    pub fn sequential_windows(&self, len: usize, max_n: usize) -> Vec<&[u32]> {
        self.tokens
            .chunks_exact(len)
            .take(max_n)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake() -> Corpus {
        Corpus {
            id: CorpusId::TinyC4,
            split: "val".into(),
            tokens: (0..10_000).map(|i| (i % 256) as u32).collect(),
        }
    }

    #[test]
    fn windows_are_deterministic_and_sized() {
        let c = fake();
        let w1 = c.windows(5, 64, 42);
        let w2 = c.windows(5, 64, 42);
        assert_eq!(w1, w2);
        assert!(w1.iter().all(|w| w.len() == 64));
        assert_ne!(c.windows(5, 64, 43), w1);
    }

    #[test]
    fn sequential_windows_do_not_overlap() {
        let c = fake();
        let ws = c.sequential_windows(100, 7);
        assert_eq!(ws.len(), 7);
        assert_eq!(ws[0][99], 99);
        assert_eq!(ws[1][0], 100);
    }

    #[test]
    fn corpus_keys() {
        assert_eq!(CorpusId::TinyWiki.key("train"), "tinywiki_train");
        assert_eq!(CorpusId::TinyC4.name(), "tiny-c4");
    }
}
