//! Activation-aware weight quantization (AWQ-like; paper §4.3 + App E.6).
//!
//! Symmetric per-output-channel int-N quantization of every projection
//! matrix, with AWQ's activation-aware trick: per-input-channel scales
//! s_k = a_k^alpha (a_k = mean |activation_k| from calibration) are
//! applied before rounding and folded back after, shrinking relative
//! error exactly where activations are large. Weights are stored
//! de-quantized (fake quant) because the CPU PJRT path computes in f32 —
//! the *accuracy* effect of quantization is what Table 5 measures;
//! memory/speed effects at 4-bit are reported analytically.

use crate::error::Result;
use crate::model::weights::Weights;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub bits: u32,
    /// AWQ exponent on activation scales (0 = plain round-to-nearest).
    pub alpha: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { bits: 8, alpha: 0.5 }
    }
}

/// Quantize one [in, out] matrix with optional per-input-channel
/// activation scales.
pub fn quantize_matrix(w: &Tensor, act_scale: Option<&[f32]>, cfg: &QuantConfig) -> Tensor {
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let qmax = ((1i64 << (cfg.bits - 1)) - 1) as f32;
    let data = w.data();

    // AWQ scaling: s_k per input row
    let s: Vec<f32> = match act_scale {
        Some(a) => a
            .iter()
            .map(|&x| x.abs().max(1e-4).powf(cfg.alpha as f32))
            .collect(),
        None => vec![1.0; rows],
    };

    // scaled weights: w'_kj = w_kj * s_k
    let mut scaled = vec![0.0f32; rows * cols];
    for k in 0..rows {
        for j in 0..cols {
            scaled[k * cols + j] = data[k * cols + j] * s[k];
        }
    }
    // per-output-channel symmetric scale
    let mut out = vec![0.0f32; rows * cols];
    for j in 0..cols {
        let mut maxabs = 0.0f32;
        for k in 0..rows {
            maxabs = maxabs.max(scaled[k * cols + j].abs());
        }
        let delta = (maxabs / qmax).max(1e-12);
        for k in 0..rows {
            let q = (scaled[k * cols + j] / delta).round().clamp(-qmax, qmax);
            // dequantize and undo the AWQ scale
            out[k * cols + j] = q * delta / s[k];
        }
    }
    Tensor::new(vec![rows, cols], out).unwrap()
}

/// Quantize a full model. `act_scales` gives the residual-stream
/// per-channel mean |activation| (from calibration); None = plain RTN.
pub fn quantize_weights(
    weights: &Weights,
    act_scales: Option<&[f32]>,
    cfg: &QuantConfig,
) -> Result<Weights> {
    let mut out = weights.clone();
    for l in out.layers.iter_mut() {
        l.wq = quantize_matrix(&l.wq, act_scales, cfg);
        l.wk = quantize_matrix(&l.wk, act_scales, cfg);
        l.wv = quantize_matrix(&l.wv, act_scales, cfg);
        l.wo = quantize_matrix(&l.wo, None, cfg); // input = attn out, not stream
        l.w1 = quantize_matrix(&l.w1, act_scales, cfg);
        l.w3 = quantize_matrix(&l.w3, act_scales, cfg);
        l.w2 = quantize_matrix(&l.w2, None, cfg);
    }
    out.w_head = quantize_matrix(&out.w_head, act_scales, cfg);
    Ok(out)
}

/// Quantize the LMMSE substitution layers too (App. E.6: "the linear
/// weights calculated by NBL were also quantized ... for compatibility").
pub fn quantize_linear_layer(
    lin: &crate::nbl::lmmse::LinearLayer,
    act_scales: Option<&[f32]>,
    cfg: &QuantConfig,
) -> crate::nbl::lmmse::LinearLayer {
    let w = Tensor::new(vec![lin.d_in, lin.d_out], lin.w.clone()).unwrap();
    let q = quantize_matrix(&w, act_scales, cfg);
    crate::nbl::lmmse::LinearLayer {
        d_in: lin.d_in,
        d_out: lin.d_out,
        w: q.into_data(),
        b: lin.b.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        Tensor::from_fn(vec![r, c], |_| rng.normal_f32() * 0.1)
    }

    fn rel_err(a: &Tensor, b: &Tensor) -> f64 {
        let num: f64 = a
            .data()
            .iter()
            .zip(b.data())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        let den: f64 = a.data().iter().map(|x| (*x as f64).powi(2)).sum();
        (num / den.max(1e-30)).sqrt()
    }

    #[test]
    fn int8_error_is_small() {
        let mut rng = Rng::new(1);
        let w = random_mat(&mut rng, 64, 32);
        let q = quantize_matrix(&w, None, &QuantConfig { bits: 8, alpha: 0.0 });
        assert!(rel_err(&w, &q) < 0.01, "{}", rel_err(&w, &q));
    }

    #[test]
    fn fewer_bits_more_error() {
        let mut rng = Rng::new(2);
        let w = random_mat(&mut rng, 64, 32);
        let e8 = rel_err(&w, &quantize_matrix(&w, None, &QuantConfig { bits: 8, alpha: 0.0 }));
        let e4 = rel_err(&w, &quantize_matrix(&w, None, &QuantConfig { bits: 4, alpha: 0.0 }));
        let e2 = rel_err(&w, &quantize_matrix(&w, None, &QuantConfig { bits: 2, alpha: 0.0 }));
        assert!(e8 < e4 && e4 < e2, "{e8} {e4} {e2}");
    }

    #[test]
    fn awq_scaling_reduces_salient_error() {
        // make channel 0's activations dominant; AWQ must cut the
        // *activation-weighted* output error vs plain RTN at 3 bits
        let mut rng = Rng::new(3);
        let (r, c) = (32, 16);
        let w = random_mat(&mut rng, r, c);
        let mut act = vec![0.05f32; r];
        act[0] = 10.0;
        act[1] = 8.0;
        let cfg_plain = QuantConfig { bits: 3, alpha: 0.0 };
        let cfg_awq = QuantConfig { bits: 3, alpha: 0.7 };
        let qp = quantize_matrix(&w, None, &cfg_plain);
        let qa = quantize_matrix(&w, Some(&act), &cfg_awq);
        // expected output error: sum_k act_k^2 * ||w_k - q_k||^2
        let werr = |q: &Tensor| -> f64 {
            (0..r)
                .map(|k| {
                    let row_err: f64 = (0..c)
                        .map(|j| {
                            ((w.data()[k * c + j] - q.data()[k * c + j]) as f64).powi(2)
                        })
                        .sum();
                    (act[k] as f64).powi(2) * row_err
                })
                .sum()
        };
        assert!(werr(&qa) < werr(&qp), "awq {} rtn {}", werr(&qa), werr(&qp));
    }

    #[test]
    fn quantize_preserves_shape_and_validates() {
        let mut rng = Rng::new(4);
        let w = random_mat(&mut rng, 8, 8);
        let q = quantize_matrix(&w, None, &QuantConfig::default());
        assert_eq!(q.shape(), w.shape());
    }
}
