//! Speculative decoding (paper §5 / Table 6: NBL composes with
//! draft-and-verify for compounding speed-ups).
//!
//! Greedy draft-and-verify (EAGLE-style protocol, simple draft): the
//! 2-layer draft model proposes `gamma = W-1` tokens autoregressively;
//! the (possibly NBL-compressed) target verifies them in ONE cached
//! forward of width W = the AOT verify bucket:
//!
//!   verify_ids = [last_committed, p1, .., p_{W-1}]
//!   logits[i]  = prediction after verify_ids[..=i]
//!     -> logits[i] verifies p_{i+1} for i < W-1
//!     -> logits[W-1] is the bonus token on full acceptance
//!
//! With greedy acceptance the output equals the target's own greedy
//! decoding exactly (asserted by rust/tests/test_serving.rs).
//!
//! Cache-rollback correctness: a partially-rejected round leaves stale
//! rows beyond the accepted position in both KV caches; those rows are
//! masked by `pos` and overwritten by later writes, so rollback is just
//! `state.pos = start + accepted + 1`.

use crate::error::Result;
use crate::executor::engine::Engine;
use crate::sampling::argmax;

#[derive(Debug, Default)]
pub struct SpecStats {
    pub proposed: usize,
    pub accepted: usize,
    /// Target verify passes.
    pub rounds: usize,
    /// Draft forward passes (proposal + sync).
    pub draft_steps: usize,
    pub generated: usize,
}

impl SpecStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }

    /// Mean tokens emitted per target forward pass (the speed-up driver).
    pub fn tokens_per_target_pass(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        self.generated as f64 / (self.rounds + 1) as f64 // +1 for prefill
    }
}

pub struct SpeculativeDecoder<'a> {
    pub target: &'a Engine,
    pub draft: &'a Engine,
    /// Verify width (must be an AOT cached bucket, e.g. 4).
    pub width: usize,
}

impl<'a> SpeculativeDecoder<'a> {
    pub fn new(target: &'a Engine, draft: &'a Engine, width: usize) -> Self {
        SpeculativeDecoder { target, draft, width }
    }

    /// Greedy speculative generation of exactly `max_new` tokens
    /// (or fewer on context exhaustion).
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Result<(Vec<u32>, SpecStats)> {
        let len = prompt.len();
        let mut stats = SpecStats::default();

        let tpre = self.target.prefill(prompt, 1, len, None)?;
        let mut tstate = tpre.state;
        let tlogits = self.target.head(&tpre.hidden)?;
        let mut next = argmax(tlogits.at2(0, len - 1));

        let dpre = self.draft.prefill(prompt, 1, len, None)?;
        let mut dstate = dpre.state;

        let mut out: Vec<u32> = vec![next];

        'outer: while out.len() < max_new {
            // width this round: full bucket, or 1 near the limits
            let room = tstate.remaining().min(dstate.remaining());
            if room == 0 {
                break;
            }
            let width = if room >= self.width && max_new - out.len() > 1 {
                self.width
            } else {
                1
            };
            let gamma = width - 1;

            // --- draft proposes gamma tokens after `next`
            let dstart = dstate.pos;
            let mut proposal: Vec<u32> = Vec::with_capacity(gamma);
            let mut dtok = next;
            for _ in 0..gamma {
                let dl = self.draft.decode(&mut dstate, &[dtok], 1)?;
                stats.draft_steps += 1;
                dtok = argmax(dl.at2(0, 0));
                proposal.push(dtok);
            }
            stats.proposed += gamma;

            // --- target verifies [next, proposal..] in one pass
            let tstart = tstate.pos;
            let mut verify_ids = Vec::with_capacity(width);
            verify_ids.push(next);
            verify_ids.extend_from_slice(&proposal);
            let vl = self.target.decode(&mut tstate, &verify_ids, width)?;
            stats.rounds += 1;

            let mut accepted = 0usize;
            for i in 0..gamma {
                let pred = argmax(vl.at2(0, i));
                if proposal[i] == pred && out.len() + accepted + 1 < max_new {
                    accepted += 1;
                } else {
                    // divergence (or budget): emit accepted prefix + target's token
                    out.extend_from_slice(&proposal[..accepted]);
                    out.push(pred);
                    stats.accepted += accepted;
                    tstate.pos = tstart + accepted + 1;
                    dstate.pos = dstart + accepted + 1;
                    next = pred;
                    continue 'outer;
                }
            }
            // full acceptance: bonus token from the last logits row
            let bonus = argmax(vl.at2(0, width - 1));
            out.extend_from_slice(&proposal);
            out.push(bonus);
            stats.accepted += gamma;
            // target cache holds all `width` rows; draft is missing the
            // row for the last proposal -> one sync step (output unused)
            if gamma > 0 {
                let _ = self.draft.decode(&mut dstate, &[proposal[gamma - 1]], 1)?;
                stats.draft_steps += 1;
            }
            next = bonus;
        }
        out.truncate(max_new);
        stats.generated = out.len();
        Ok((out, stats))
    }
}

/// Plain greedy generation with the target only (the baseline the
/// speculative path must match token-for-token).
pub fn greedy_generate(engine: &Engine, prompt: &[u32], max_new: usize) -> Result<Vec<u32>> {
    let len = prompt.len();
    let pre = engine.prefill(prompt, 1, len, None)?;
    let mut state = pre.state;
    let logits = engine.head(&pre.hidden)?;
    let mut next = argmax(logits.at2(0, len - 1));
    let mut out = vec![next];
    while out.len() < max_new && state.remaining() > 0 {
        let l = engine.decode(&mut state, &[next], 1)?;
        next = argmax(l.at2(0, 0));
        out.push(next);
    }
    Ok(out)
}
