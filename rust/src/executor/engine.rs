//! The execution engine: plan-dispatched layerwise forward passes.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kvcache::{put_row_state, take_row_state, KvState, SlotArena};
use crate::model::artifacts::Grid;
use crate::model::weights::Weights;
use crate::nbl::plan::{BlockOp, MlpOp, ModelPlan};
use crate::runtime::literals::{lit_from_tensor, lit_i32_vec, lit_scalar_i32, tensor_from_lit};
use crate::runtime::registry::{ArgRef, HeldBuffer};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Cached per-layer weight device buffers (uploaded once per engine —
/// §Perf iteration 2: weights never re-upload on the decode hot path).
struct LayerLits {
    attn_norm: HeldBuffer,
    wq: HeldBuffer,
    wk: HeldBuffer,
    wv: HeldBuffer,
    wo: HeldBuffer,
    mlp_norm: HeldBuffer,
    w1: HeldBuffer,
    w3: HeldBuffer,
    w2: HeldBuffer,
    /// LMMSE substitution weights when the plan says Linear.
    linear: Option<(HeldBuffer, HeldBuffer)>,
}

pub struct PrefillResult {
    pub state: KvState,
    /// Final hidden states at the bucket shape [Bb, Tb, D].
    pub hidden: Tensor,
    /// Bucket used.
    pub t_bucket: usize,
}

/// One row of a continuous-batching decode iteration: advance `slot` by
/// `token` (the token sampled for that request last iteration).
#[derive(Debug, Clone, Copy)]
pub struct RowDecode {
    pub slot: usize,
    pub token: u32,
}

/// One row of a speculative verify iteration: run `tokens` (the last
/// committed token followed by the draft proposals, width W) through
/// `slot`'s cache segment in a single pass. All rows of one call share
/// the width; positions stay per-row.
#[derive(Debug, Clone)]
pub struct RowSpecDecode {
    pub slot: usize,
    pub tokens: Vec<u32>,
}

pub struct Engine {
    pub runtime: Arc<Runtime>,
    pub weights: Arc<Weights>,
    pub plan: ModelPlan,
    grid: Grid,
    layers: Vec<LayerLits>,
    final_norm: HeldBuffer,
    w_head: HeldBuffer,
}

// SAFETY: literal members are plain host buffers on the CPU backend and
// the runtime serializes PJRT access internally, so sharing an Engine
// across threads cannot race device state.
#[allow(unsafe_code)]
unsafe impl Send for Engine {}
// SAFETY: see the Send impl above — all interior mutability lives
// behind the runtime's own synchronization.
#[allow(unsafe_code)]
unsafe impl Sync for Engine {}

impl Engine {
    /// Load a model by name from the artifacts with the baseline plan.
    pub fn load(runtime: Arc<Runtime>, model: &str) -> Result<Engine> {
        let (bin, json) = runtime.artifacts().weights_paths(model)?;
        let weights = Arc::new(Weights::load(model, &bin, &json)?);
        let plan = ModelPlan::baseline(weights.config.n_layers);
        Engine::new(runtime, weights, plan)
    }

    pub fn new(runtime: Arc<Runtime>, weights: Arc<Weights>, plan: ModelPlan) -> Result<Engine> {
        if plan.n_layers() != weights.config.n_layers {
            return Err(Error::Config(format!(
                "plan has {} layers, model has {}",
                plan.n_layers(),
                weights.config.n_layers
            )));
        }
        let grid = runtime.artifacts().grid()?;
        let mut layers = Vec::with_capacity(weights.layers.len());
        for (lw, lp) in weights.layers.iter().zip(&plan.layers) {
            let linear = match &lp.attn {
                BlockOp::Linear(lin) => {
                    let d = weights.config.d_model;
                    if lin.d_in != d || lin.d_out != d {
                        return Err(Error::Shape(format!(
                            "linear layer {}x{} vs d_model {d}",
                            lin.d_in, lin.d_out
                        )));
                    }
                    let w = crate::runtime::literals::lit_from_slice(&lin.w, &[d, d])?;
                    let b = crate::runtime::literals::lit_from_slice(&lin.b, &[d])?;
                    Some((runtime.upload(w)?, runtime.upload(b)?))
                }
                _ => None,
            };
            let up = |t: &crate::tensor::Tensor| -> Result<HeldBuffer> {
                runtime.upload(lit_from_tensor(t)?)
            };
            layers.push(LayerLits {
                attn_norm: up(&lw.attn_norm)?,
                wq: up(&lw.wq)?,
                wk: up(&lw.wk)?,
                wv: up(&lw.wv)?,
                wo: up(&lw.wo)?,
                mlp_norm: up(&lw.mlp_norm)?,
                w1: up(&lw.w1)?,
                w3: up(&lw.w3)?,
                w2: up(&lw.w2)?,
                linear,
            });
        }
        Ok(Engine {
            final_norm: runtime.upload(lit_from_tensor(&weights.final_norm)?)?,
            w_head: runtime.upload(lit_from_tensor(&weights.w_head)?)?,
            runtime,
            weights,
            plan,
            grid,
            layers,
        })
    }

    /// Same weights, different plan (NBL-m, DROP-m, ...).
    pub fn with_plan(&self, plan: ModelPlan) -> Result<Engine> {
        Engine::new(self.runtime.clone(), self.weights.clone(), plan)
    }

    pub fn config(&self) -> &crate::model::config::ModelConfig {
        &self.weights.config
    }

    // ------------------------------------------------------------- buckets

    pub fn batch_bucket(&self, batch: usize) -> Result<usize> {
        Grid::bucket(&self.grid.batches, batch).ok_or_else(|| {
            Error::Serving(format!(
                "batch {batch} exceeds grid {:?}",
                self.grid.batches
            ))
        })
    }

    pub fn prefill_bucket(&self, len: usize) -> Result<usize> {
        Grid::bucket(&self.grid.prefill_lens, len).ok_or_else(|| {
            Error::Serving(format!(
                "prompt length {len} exceeds grid {:?}",
                self.grid.prefill_lens
            ))
        })
    }

    pub fn cached_bucket(&self, s: usize) -> Result<usize> {
        Grid::bucket(&self.grid.cached_lens, s).ok_or_else(|| {
            Error::Serving(format!("step width {s} exceeds grid {:?}", self.grid.cached_lens))
        })
    }

    // ------------------------------------------------------------- prefill

    /// Prefill a batch of equal-length prompts.
    ///
    /// `ids` is row-major [batch, len]. Rows are padded to the bucket
    /// internally (causal attention makes right-padding invisible to the
    /// real positions). `capture` receives per-layer (X, Y_delta) at the
    /// *real* token rows — the calibration tap (paper §3.1).
    pub fn prefill(
        &self,
        ids: &[u32],
        batch: usize,
        len: usize,
        mut capture: Option<&mut dyn FnMut(usize, &Tensor, &Tensor) -> Result<()>>,
    ) -> Result<PrefillResult> {
        if len == 0 || batch == 0 || ids.len() != batch * len {
            return Err(Error::Shape(format!(
                "prefill: {} ids for {batch}x{len}",
                ids.len()
            )));
        }
        let bb = self.batch_bucket(batch)?;
        let tb = self.prefill_bucket(len)?;
        let d = self.config().d_model;

        // pad ids to [bb, tb] (token 0 as pad; garbage rows are ignored)
        let mut padded = vec![0u32; bb * tb];
        for b in 0..batch {
            padded[b * tb..b * tb + len].copy_from_slice(&ids[b * len..(b + 1) * len]);
        }
        let x0 = self.weights.embed(&padded, bb, tb)?;
        let mut x = lit_from_tensor(&x0)?;
        let mut state = KvState::empty(&self.plan, self.config(), batch, bb);
        state.pos = len;

        let attn_op = format!("attn_prefill_b{bb}_t{tb}");
        let init_op = format!("cache_init_b{bb}_t{tb}");
        let mlp_op = format!("mlp_b{bb}_t{tb}");
        let lin_op = format!("linear_block_b{bb}_t{tb}");

        for (li, (lits, lp)) in self.layers.iter().zip(&self.plan.layers).enumerate() {
            // capture taps X before the attention slot
            let x_in = if capture.is_some() {
                Some(tensor_from_lit(&x)?)
            } else {
                None
            };
            match &lp.attn {
                BlockOp::Attention => {
                    let out = self.runtime.run_mixed(
                        &attn_op,
                        &[
                            ArgRef::Lit(&x),
                            ArgRef::Buf(&lits.attn_norm),
                            ArgRef::Buf(&lits.wq),
                            ArgRef::Buf(&lits.wk),
                            ArgRef::Buf(&lits.wv),
                            ArgRef::Buf(&lits.wo),
                        ],
                    )?;
                    let [y, k, v]: [xla::Literal; 3] = out
                        .try_into()
                        .map_err(|_| Error::Xla("attn_prefill arity".into()))?;
                    if let (Some(cb), Some(x_t)) = (capture.as_deref_mut(), x_in.as_ref()) {
                        let y_t = tensor_from_lit(&y)?;
                        let (xr, yr) = rows_delta(x_t, &y_t, batch, len, d)?;
                        cb(li, &xr, &yr)?;
                    }
                    let caches = self.runtime.run(&init_op, &[&k, &v])?;
                    let [kc, vc]: [xla::Literal; 2] = caches
                        .try_into()
                        .map_err(|_| Error::Xla("cache_init arity".into()))?;
                    state.caches[li] = Some((kc, vc));
                    x = y;
                }
                BlockOp::Linear(_) => {
                    let (w, b) = lits.linear.as_ref().ok_or_else(|| {
                        Error::Config("Linear plan block without folded weights".into())
                    })?;
                    let out = self.runtime.run_mixed(
                        &lin_op,
                        &[ArgRef::Lit(&x), ArgRef::Buf(w), ArgRef::Buf(b)],
                    )?;
                    x = into_single(out, "linear_block")?;
                }
                BlockOp::Identity => {}
            }
            if lp.mlp == MlpOp::Mlp {
                let out = self.runtime.run_mixed(
                    &mlp_op,
                    &[
                        ArgRef::Lit(&x),
                        ArgRef::Buf(&lits.mlp_norm),
                        ArgRef::Buf(&lits.w1),
                        ArgRef::Buf(&lits.w3),
                        ArgRef::Buf(&lits.w2),
                    ],
                )?;
                x = into_single(out, "mlp")?;
            }
        }
        Ok(PrefillResult {
            state,
            hidden: tensor_from_lit(&x)?,
            t_bucket: tb,
        })
    }

    // ------------------------------------------------------ chunked prefill

    /// Snap a requested prefill chunk size onto the AOT prefill grid:
    /// the smallest bucket >= `want`, or the largest bucket when `want`
    /// exceeds the grid. Full chunks then run unpadded at exactly this
    /// width, so the one-chunk-per-iteration stall bound is a real grid
    /// width, not an aspiration.
    pub fn snap_chunk_len(&self, want: usize) -> usize {
        Grid::bucket(&self.grid.prefill_lens, want)
            .or_else(|| self.grid.prefill_lens.iter().copied().max())
            .unwrap_or(0)
    }

    /// True if the AOT grid carries EVERY op the cache-appending chunked
    /// prefill needs at batch bucket `bb` for chunk size `chunk`: the
    /// chunk attention op plus the pointwise mlp/linear/head ops at
    /// every prefill width <= chunk (full chunks run at `chunk`, the
    /// ragged tail at its own bucket). Artifacts that predate the chunk
    /// family make admissions fall back to whole-prompt prefill with
    /// identical semantics — ci/check_artifacts.py fails the build
    /// before that silent slow path can ship.
    pub fn supports_chunked_prefill(&self, bb: usize, chunk: usize) -> bool {
        let art = self.runtime.artifacts();
        let widths: Vec<usize> = self
            .grid
            .prefill_lens
            .iter()
            .copied()
            .filter(|&t| t <= chunk)
            .collect();
        !widths.is_empty()
            && widths.iter().all(|&t| {
                art.has_op(&format!("attn_prefill_chunk_b{bb}_t{t}"))
                    && art.has_op(&format!("mlp_b{bb}_t{t}"))
                    && art.has_op(&format!("linear_block_b{bb}_t{t}"))
                    && art.has_op(&format!("head_b{bb}_t{t}"))
            })
    }

    /// Append `len` prompt tokens to an in-flight prefill — one chunk of
    /// the chunked-admission state machine (DESIGN.md §Chunked prefill).
    ///
    /// The first chunk (`state.pos == 0`) delegates to
    /// [`Engine::prefill`] (the fresh `attn_prefill` + `cache_init`
    /// pair — one layer walk to maintain, not two); later chunks run
    /// the cache-appending
    /// `attn_prefill_chunk` op, which consumes the prior KV at
    /// `state.pos` instead of starting cold. Returns the chunk's final
    /// hidden states [Bb, Tb, D] so the caller can sample the first
    /// token from row `len - 1` of the last chunk.
    ///
    /// Padding invariant: `ids` are padded to the chunk bucket, so
    /// cache rows [pos + len, pos + Tb) hold garbage after the call —
    /// exactly the stale-row protocol of speculative rollback: every
    /// later REAL write (next chunk, decode steps) lands at the row's
    /// own position just before the only queries that could see it, so
    /// garbage is either overwritten first or masked by the causal
    /// bound forever.
    pub fn prefill_chunk(&self, state: &mut KvState, ids: &[u32], len: usize) -> Result<Tensor> {
        let batch = state.batch;
        if len == 0 || batch == 0 || ids.len() != batch * len {
            return Err(Error::Shape(format!(
                "prefill_chunk: {} ids for {batch}x{len}",
                ids.len()
            )));
        }
        let bb = state.bucket_batch;
        if batch > bb {
            return Err(Error::Shape(format!(
                "prefill_chunk: batch {batch} exceeds bucket {bb}"
            )));
        }
        if state.pos == 0 {
            let pre = self.prefill(ids, batch, len, None)?;
            if pre.state.bucket_batch != bb {
                return Err(Error::Shape(format!(
                    "prefill_chunk: first chunk bucketed {} vs state bucket {bb}",
                    pre.state.bucket_batch
                )));
            }
            *state = pre.state;
            return Ok(pre.hidden);
        }
        let tb = self.prefill_bucket(len)?;
        if state.pos + tb > state.max_ctx {
            // dynamic_update_slice clamps its start index: a padded
            // chunk straddling Tmax would silently shift writes onto
            // committed cache entries (same rule as `decode`)
            return Err(Error::Serving(format!(
                "context overflow: chunk at {} + {tb} > {}",
                state.pos, state.max_ctx
            )));
        }
        let chunk_op = format!("attn_prefill_chunk_b{bb}_t{tb}");
        if !self.runtime.artifacts().has_op(&chunk_op) {
            return Err(Error::Artifact(format!(
                "{chunk_op} not in the AOT grid — rebuild artifacts \
                 (`python -m compile.aot`) or serve with whole-prompt prefill"
            )));
        }

        let mut padded = vec![0u32; bb * tb];
        for b in 0..batch {
            padded[b * tb..b * tb + len].copy_from_slice(&ids[b * len..(b + 1) * len]);
        }
        let x0 = self.weights.embed(&padded, bb, tb)?;
        let mut x = lit_from_tensor(&x0)?;
        let pos = lit_scalar_i32(state.pos as i32);

        let mlp_op = format!("mlp_b{bb}_t{tb}");
        let lin_op = format!("linear_block_b{bb}_t{tb}");

        for (li, (lits, lp)) in self.layers.iter().zip(&self.plan.layers).enumerate() {
            match &lp.attn {
                BlockOp::Attention => {
                    let (kc, vc) = state.caches[li]
                        .take()
                        .ok_or_else(|| Error::Serving(format!("layer {li}: no KV cache")))?;
                    let out = self.runtime.run_mixed(
                        &chunk_op,
                        &[
                            ArgRef::Lit(&x),
                            ArgRef::Buf(&lits.attn_norm),
                            ArgRef::Buf(&lits.wq),
                            ArgRef::Buf(&lits.wk),
                            ArgRef::Buf(&lits.wv),
                            ArgRef::Buf(&lits.wo),
                            ArgRef::Lit(&kc),
                            ArgRef::Lit(&vc),
                            ArgRef::Lit(&pos),
                        ],
                    )?;
                    let [y, kc2, vc2]: [xla::Literal; 3] = out
                        .try_into()
                        .map_err(|_| Error::Xla("attn_prefill_chunk arity".into()))?;
                    state.caches[li] = Some((kc2, vc2));
                    x = y;
                }
                BlockOp::Linear(_) => {
                    let (w, b) = lits.linear.as_ref().ok_or_else(|| {
                        Error::Config("Linear plan block without folded weights".into())
                    })?;
                    let out = self.runtime.run_mixed(
                        &lin_op,
                        &[ArgRef::Lit(&x), ArgRef::Buf(w), ArgRef::Buf(b)],
                    )?;
                    x = into_single(out, "linear_block")?;
                }
                BlockOp::Identity => {}
            }
            if lp.mlp == MlpOp::Mlp {
                let out = self.runtime.run_mixed(
                    &mlp_op,
                    &[
                        ArgRef::Lit(&x),
                        ArgRef::Buf(&lits.mlp_norm),
                        ArgRef::Buf(&lits.w1),
                        ArgRef::Buf(&lits.w3),
                        ArgRef::Buf(&lits.w2),
                    ],
                )?;
                x = into_single(out, "mlp")?;
            }
        }
        state.pos += len;
        tensor_from_lit(&x)
    }

    // ----------------------------------------------------- prefix adoption

    /// True if the AOT grid can extend an adopted prompt prefix by ANY
    /// suffix width: prefix reuse needs the cache-appending chunk family
    /// at every prefill bucket, because the uncovered suffix snaps onto
    /// its own bucket (unlike chunked admission, which only ever runs
    /// widths up to the configured chunk). Stale artifacts degrade the
    /// prefix cache to cold prefill, never to an error.
    pub fn supports_prefix_reuse(&self) -> bool {
        self.supports_chunked_prefill(1, self.config().max_ctx)
    }

    /// Prefill ONLY the uncovered suffix of a prompt whose prefix was
    /// adopted from the prefix cache (DESIGN.md §Prefix cache): `state`
    /// starts at the snapshot position and the cache-appending chunk op
    /// extends it by `ids`. Returns the suffix's final hidden states
    /// [1, Tb, D]; the caller samples the first token at row
    /// `ids.len() - 1`.
    pub fn prefill_suffix(&self, state: &mut KvState, ids: &[u32]) -> Result<Tensor> {
        if state.pos == 0 {
            return Err(Error::Serving(
                "prefill_suffix: state holds no adopted prefix (use prefill)".into(),
            ));
        }
        if state.batch != 1 {
            return Err(Error::Serving(format!(
                "prefill_suffix: batch {} (prefix adoption is per-request)",
                state.batch
            )));
        }
        self.prefill_chunk(state, ids, ids.len())
    }

    // -------------------------------------------------------------- decode

    /// Run `s_real` new tokens (per request) through the cached path.
    ///
    /// `ids` is [batch, s_real]; all requests in the group share `state.pos`.
    /// Returns logits [batch, s_real, V].
    pub fn decode(&self, state: &mut KvState, ids: &[u32], s_real: usize) -> Result<Tensor> {
        let batch = state.batch;
        if ids.len() != batch * s_real {
            return Err(Error::Shape(format!(
                "decode: {} ids for {batch}x{s_real}",
                ids.len()
            )));
        }
        if state.pos + s_real > state.max_ctx {
            return Err(Error::Serving(format!(
                "context overflow: {} + {s_real} > {}",
                state.pos, state.max_ctx
            )));
        }
        let bb = state.bucket_batch;
        if batch > bb {
            // an oversized group must fail loudly here, not mis-slice (or
            // panic) downstream — see slice_logits
            return Err(Error::Shape(format!(
                "decode: batch {batch} exceeds bucket {bb}"
            )));
        }
        let sb = self.cached_bucket(s_real)?;
        if state.pos + sb > state.max_ctx {
            // the attn_cached kernel writes the PADDED bucket width via
            // dynamic_update_slice, which clamps its start index: letting
            // a padded call straddle the boundary would silently shift
            // the writes onto committed cache entries. Reject instead
            // (callers decode at bucket widths, where this equals the
            // s_real check above).
            return Err(Error::Serving(format!(
                "context overflow: padded step {} + {sb} > {} (use a bucket width)",
                state.pos, state.max_ctx
            )));
        }

        let mut padded = vec![0u32; bb * sb];
        for b in 0..batch {
            padded[b * sb..b * sb + s_real]
                .copy_from_slice(&ids[b * s_real..(b + 1) * s_real]);
        }
        let x0 = self.weights.embed(&padded, bb, sb)?;
        let mut x = lit_from_tensor(&x0)?;
        let pos = lit_scalar_i32(state.pos as i32);

        let cached_op = format!("attn_cached_b{bb}_s{sb}");
        let mlp_op = format!("mlp_b{bb}_t{sb}");
        let lin_op = format!("linear_block_b{bb}_t{sb}");

        for (li, (lits, lp)) in self.layers.iter().zip(&self.plan.layers).enumerate() {
            match &lp.attn {
                BlockOp::Attention => {
                    let (kc, vc) = state.caches[li]
                        .take()
                        .ok_or_else(|| Error::Serving(format!("layer {li}: no KV cache")))?;
                    let out = self.runtime.run_mixed(
                        &cached_op,
                        &[
                            ArgRef::Lit(&x),
                            ArgRef::Buf(&lits.attn_norm),
                            ArgRef::Buf(&lits.wq),
                            ArgRef::Buf(&lits.wk),
                            ArgRef::Buf(&lits.wv),
                            ArgRef::Buf(&lits.wo),
                            ArgRef::Lit(&kc),
                            ArgRef::Lit(&vc),
                            ArgRef::Lit(&pos),
                        ],
                    )?;
                    let [y, kc2, vc2]: [xla::Literal; 3] = out
                        .try_into()
                        .map_err(|_| Error::Xla("attn_cached arity".into()))?;
                    state.caches[li] = Some((kc2, vc2));
                    x = y;
                }
                BlockOp::Linear(_) => {
                    let (w, b) = lits.linear.as_ref().ok_or_else(|| {
                        Error::Config("Linear plan block without folded weights".into())
                    })?;
                    let out = self.runtime.run_mixed(
                        &lin_op,
                        &[ArgRef::Lit(&x), ArgRef::Buf(w), ArgRef::Buf(b)],
                    )?;
                    x = into_single(out, "linear_block")?;
                }
                BlockOp::Identity => {}
            }
            if lp.mlp == MlpOp::Mlp {
                let out = self.runtime.run_mixed(
                    &mlp_op,
                    &[
                        ArgRef::Lit(&x),
                        ArgRef::Buf(&lits.mlp_norm),
                        ArgRef::Buf(&lits.w1),
                        ArgRef::Buf(&lits.w3),
                        ArgRef::Buf(&lits.w2),
                    ],
                )?;
                x = into_single(out, "mlp")?;
            }
        }
        // note: if a speculative step is later partially rejected, the
        // caller rolls `state.pos` back; stale cache rows beyond pos are
        // masked out and overwritten on the next write.
        state.pos += s_real;

        let logits = self.head_lit(&x, bb, sb)?;
        slice_logits(&logits, batch, s_real, self.config().vocab)
    }

    // --------------------------------------------------- continuous decode

    /// Largest executable batch bucket not exceeding `want` — the decode
    /// group (slot arena) size for a serving config's `max_batch`.
    pub fn decode_group_bucket(&self, want: usize) -> usize {
        let want = want.max(1);
        self.grid
            .batches
            .iter()
            .copied()
            .filter(|&b| b <= want)
            .max()
            .or_else(|| self.grid.batches.iter().copied().min())
            .unwrap_or(1)
    }

    /// Allocate a per-request slot arena sized for `max_batch` under this
    /// engine's plan (substituted layers allocate no rows — §H.2).
    pub fn new_arena(&self, max_batch: usize) -> Result<SlotArena> {
        SlotArena::new(&self.plan, self.config(), self.decode_group_bucket(max_batch))
    }

    /// True if the AOT grid carries the per-row-position decode op for
    /// bucket `bb`; otherwise `decode_rows` serves through the per-row
    /// scalar-pos fallback.
    pub fn supports_row_decode(&self, bb: usize) -> bool {
        self.supports_row_decode_wide(bb, 1)
    }

    /// Snap a requested speculative verify width onto the AOT
    /// `cached_lens` grid: the smallest bucket >= `want`, or the largest
    /// bucket when `want` exceeds the grid. The result equals its own
    /// bucket, so the batched and fallback verify paths agree on the
    /// context-boundary rule and a misconfigured width can never turn
    /// every iteration into an error.
    pub fn snap_verify_width(&self, want: usize) -> usize {
        Grid::bucket(&self.grid.cached_lens, want)
            .or_else(|| self.grid.cached_lens.iter().copied().max())
            .unwrap_or(1)
    }

    /// True if the AOT grid carries EVERY op the batched per-row-position
    /// decode needs at verify width `width` for bucket `bb` (the
    /// speculative iteration's fast path): the rows attention op plus the
    /// pointwise mlp/linear/head ops at the same padded width — the two
    /// grid axes (`cached_lens`, `pointwise_lens`) are independently
    /// editable, so a width present in one but not the other must fall
    /// back instead of erroring every iteration. Artifacts that predate
    /// the widened family fall back to per-row scalar decodes with
    /// identical semantics.
    pub fn supports_row_decode_wide(&self, bb: usize, width: usize) -> bool {
        match Grid::bucket(&self.grid.cached_lens, width) {
            Some(sw) => {
                let art = self.runtime.artifacts();
                art.has_op(&format!("attn_cached_rows_b{bb}_s{sw}"))
                    && art.has_op(&format!("mlp_b{bb}_t{sw}"))
                    && art.has_op(&format!("linear_block_b{bb}_t{sw}"))
                    && art.has_op(&format!("head_b{bb}_t{sw}"))
            }
            None => false,
        }
    }

    /// Decode ONE token for a dynamic set of occupied arena slots — the
    /// continuous-batching iteration. Rows carry their own positions
    /// (gathered from the arena), so one call mixes requests with
    /// different prompt lengths and ages. Returns logits
    /// [rows.len(), 1, V] in `rows` order and advances each row's
    /// position by one.
    pub fn decode_rows(&self, arena: &mut SlotArena, rows: &[RowDecode]) -> Result<Tensor> {
        let wide: Vec<RowSpecDecode> = rows
            .iter()
            .map(|r| RowSpecDecode { slot: r.slot, tokens: vec![r.token] })
            .collect();
        self.decode_rows_spec(arena, &wide)
    }

    /// Speculative verify iteration: run W tokens per occupied row (the
    /// last committed token + the draft proposals) through each row's
    /// cache segment in one call. Returns logits [rows.len(), W, V] in
    /// `rows` order — row i, column j is the target's prediction after
    /// `rows[i].tokens[..=j]` — and advances every row's position by W;
    /// the caller rolls rejected suffixes back with `SlotArena::set_pos`
    /// (stale cache entries beyond the accepted position are masked by
    /// pos and overwritten by later writes, exactly as in spec/mod.rs).
    pub fn decode_rows_spec(
        &self,
        arena: &mut SlotArena,
        rows: &[RowSpecDecode],
    ) -> Result<Tensor> {
        if rows.is_empty() {
            return Err(Error::Serving("decode_rows: empty row set".into()));
        }
        let width = rows[0].tokens.len();
        if width == 0 || rows.iter().any(|r| r.tokens.len() != width) {
            return Err(Error::Serving(
                "decode_rows: rows must share a nonzero verify width".into(),
            ));
        }
        let bb = arena.bucket_batch;
        if rows.len() != arena.occupancy() {
            // every occupied slot must advance each iteration: the batched
            // path feeds pad tokens at pos 0 to rows outside the set, which
            // would clobber a live slot's first cache entry
            return Err(Error::Serving(format!(
                "decode_rows: {} rows for {} occupied slots",
                rows.len(),
                arena.occupancy()
            )));
        }
        // bound by the PADDED bucket width, not the raw width: the
        // fallback's attn_cached bucket writes sw entries, so a raw-width
        // check would make the batched and fallback paths disagree at the
        // context boundary for non-bucket widths
        let sw = self.cached_bucket(width)?;
        let mut seen = vec![false; bb];
        for r in rows {
            if r.slot >= bb || std::mem::replace(&mut seen[r.slot], true) {
                return Err(Error::Serving(format!(
                    "decode_rows: bad or duplicate slot {}",
                    r.slot
                )));
            }
            let pos = arena
                .pos(r.slot)
                .ok_or_else(|| Error::Serving(format!("decode_rows: slot {} is free", r.slot)))?;
            if pos + sw > arena.max_ctx {
                return Err(Error::Serving(format!(
                    "context overflow: slot {} at {} + {sw} (bucket of {width}) > {}",
                    r.slot, pos, arena.max_ctx
                )));
            }
        }
        let logits = if self.supports_row_decode_wide(bb, width) {
            self.decode_rows_batched(arena, rows, width)?
        } else {
            self.decode_rows_fallback(arena, rows, width)?
        };
        for r in rows {
            let p = arena
                .pos(r.slot)
                .ok_or_else(|| Error::Serving(format!("slot {} is not occupied", r.slot)))?;
            arena.set_pos(r.slot, p + width);
        }
        Ok(logits)
    }

    /// Fast path: one `attn_cached_rows` call per layer with the per-row
    /// position vector. Free rows feed pad tokens at pos 0: their
    /// (garbage) segment rows are overwritten and their output ignored.
    fn decode_rows_batched(
        &self,
        arena: &mut SlotArena,
        rows: &[RowSpecDecode],
        width: usize,
    ) -> Result<Tensor> {
        let bb = arena.bucket_batch;
        let sw = self.cached_bucket(width)?;
        let mut tokens = vec![0u32; bb * sw];
        let mut pos = vec![0i32; bb];
        for r in rows {
            tokens[r.slot * sw..r.slot * sw + width].copy_from_slice(&r.tokens);
            pos[r.slot] = arena
                .pos(r.slot)
                .ok_or_else(|| Error::Serving(format!("slot {} is not occupied", r.slot)))?
                as i32;
        }
        let x0 = self.weights.embed(&tokens, bb, sw)?;
        let mut x = lit_from_tensor(&x0)?;
        let pos_lit = lit_i32_vec(&pos);

        let rows_op = format!("attn_cached_rows_b{bb}_s{sw}");
        let mlp_op = format!("mlp_b{bb}_t{sw}");
        let lin_op = format!("linear_block_b{bb}_t{sw}");

        for (li, (lits, lp)) in self.layers.iter().zip(&self.plan.layers).enumerate() {
            match &lp.attn {
                BlockOp::Attention => {
                    // borrow (don't take) the caches: the arena outlives a
                    // failed iteration, and a `?` exit must not leave a
                    // structural hole that bricks later admissions
                    let out = {
                        let (kc, vc) = arena.caches[li]
                            .as_ref()
                            .ok_or_else(|| Error::Serving(format!("layer {li}: no KV cache")))?;
                        self.runtime.run_mixed(
                            &rows_op,
                            &[
                                ArgRef::Lit(&x),
                                ArgRef::Buf(&lits.attn_norm),
                                ArgRef::Buf(&lits.wq),
                                ArgRef::Buf(&lits.wk),
                                ArgRef::Buf(&lits.wv),
                                ArgRef::Buf(&lits.wo),
                                ArgRef::Lit(kc),
                                ArgRef::Lit(vc),
                                ArgRef::Lit(&pos_lit),
                            ],
                        )?
                    };
                    let [y, kc2, vc2]: [xla::Literal; 3] = out
                        .try_into()
                        .map_err(|_| Error::Xla("attn_cached_rows arity".into()))?;
                    arena.caches[li] = Some((kc2, vc2));
                    x = y;
                }
                BlockOp::Linear(_) => {
                    let (w, b) = lits.linear.as_ref().ok_or_else(|| {
                        Error::Config("Linear plan block without folded weights".into())
                    })?;
                    let out = self.runtime.run_mixed(
                        &lin_op,
                        &[ArgRef::Lit(&x), ArgRef::Buf(w), ArgRef::Buf(b)],
                    )?;
                    x = into_single(out, "linear_block")?;
                }
                BlockOp::Identity => {}
            }
            if lp.mlp == MlpOp::Mlp {
                let out = self.runtime.run_mixed(
                    &mlp_op,
                    &[
                        ArgRef::Lit(&x),
                        ArgRef::Buf(&lits.mlp_norm),
                        ArgRef::Buf(&lits.w1),
                        ArgRef::Buf(&lits.w3),
                        ArgRef::Buf(&lits.w2),
                    ],
                )?;
                x = into_single(out, "mlp")?;
            }
        }
        let logits = self.head_lit(&x, bb, sw)?;
        let full = tensor_from_lit(&logits)?;
        let vocab = self.config().vocab;
        let mut out = Vec::with_capacity(rows.len() * width * vocab);
        for r in rows {
            for j in 0..width {
                out.extend_from_slice(full.at2(r.slot, j));
            }
        }
        Tensor::new(vec![rows.len(), width, vocab], out)
    }

    /// Fallback when the rows op is missing from the AOT grid: slice each
    /// row out of the arena, run the batch-1 scalar-pos decode (width W),
    /// and write the updated row back. Slower (host row copies + B
    /// executable calls) but bit-identical semantics, so stale artifact
    /// sets still serve correctly.
    fn decode_rows_fallback(
        &self,
        arena: &mut SlotArena,
        rows: &[RowSpecDecode],
        width: usize,
    ) -> Result<Tensor> {
        let vocab = self.config().vocab;
        let mut out = Vec::with_capacity(rows.len() * width * vocab);
        for r in rows {
            // shared row-transfer protocol (kvcache): slice the slot out
            // as a batch-1 state, decode it solo, write it back
            let pos = arena
                .pos(r.slot)
                .ok_or_else(|| Error::Serving(format!("slot {} is not occupied", r.slot)))?;
            let mut state = take_row_state(&self.plan, self.config(), &arena.caches, r.slot, pos)?;
            let logits = self.decode(&mut state, &r.tokens, width)?;
            for j in 0..width {
                out.extend_from_slice(logits.at2(0, j));
            }
            put_row_state(&mut arena.caches, &state, r.slot)?;
        }
        Tensor::new(vec![rows.len(), width, vocab], out)
    }

    // ---------------------------------------------------------------- head

    /// LM head over a hidden tensor [Bb, Tb, D] -> logits [Bb, Tb, V].
    pub fn head(&self, hidden: &Tensor) -> Result<Tensor> {
        let (bb, tb) = (hidden.shape()[0], hidden.shape()[1]);
        let x = lit_from_tensor(hidden)?;
        let lit = self.head_lit(&x, bb, tb)?;
        tensor_from_lit(&lit)
    }

    fn head_lit(&self, x: &xla::Literal, bb: usize, tb: usize) -> Result<xla::Literal> {
        let op = format!("head_b{bb}_t{tb}");
        let out = self.runtime.run_mixed(
            &op,
            &[ArgRef::Lit(x), ArgRef::Buf(&self.final_norm), ArgRef::Buf(&self.w_head)],
        )?;
        into_single(out, "head")
    }

    /// Ops needed for a (batch, prompt_len, decode) workload — used to
    /// warm the compile cache before latency measurements.
    pub fn warmup_ops(&self, batch: usize, len: usize) -> Result<Vec<String>> {
        let bb = self.batch_bucket(batch)?;
        let tb = self.prefill_bucket(len)?;
        let mut ops = vec![
            format!("attn_prefill_b{bb}_t{tb}"),
            format!("cache_init_b{bb}_t{tb}"),
            format!("mlp_b{bb}_t{tb}"),
            format!("linear_block_b{bb}_t{tb}"),
            format!("head_b{bb}_t{tb}"),
            format!("attn_cached_b{bb}_s1"),
            format!("mlp_b{bb}_t1"),
            format!("linear_block_b{bb}_t1"),
            format!("head_b{bb}_t1"),
        ];
        if self.supports_row_decode(bb) {
            ops.push(format!("attn_cached_rows_b{bb}_s1"));
        }
        Ok(ops)
    }
}

fn into_single(out: Vec<xla::Literal>, what: &str) -> Result<xla::Literal> {
    let mut it = out.into_iter();
    match (it.next(), it.next()) {
        (Some(x), None) => Ok(x),
        _ => Err(Error::Xla(format!("{what}: expected single output"))),
    }
}

/// Extract real-token rows and the attention delta (Y = out - in).
fn rows_delta(
    x_in: &Tensor,
    y_out: &Tensor,
    batch: usize,
    len: usize,
    d: usize,
) -> Result<(Tensor, Tensor)> {
    let mut xr = Vec::with_capacity(batch * len * d);
    let mut yr = Vec::with_capacity(batch * len * d);
    for b in 0..batch {
        for t in 0..len {
            let xi = x_in.at2(b, t);
            let yo = y_out.at2(b, t);
            xr.extend_from_slice(xi);
            yr.extend(yo.iter().zip(xi).map(|(o, i)| o - i));
        }
    }
    Ok((
        Tensor::new(vec![batch * len, d], xr)?,
        Tensor::new(vec![batch * len, d], yr)?,
    ))
}

/// Slice bucket logits [Bb, Sb, V] down to [batch, s_real, V].
fn slice_logits(lit: &xla::Literal, batch: usize, s_real: usize, vocab: usize) -> Result<Tensor> {
    let full = tensor_from_lit(lit)?;
    let (bb, sb) = (full.shape()[0], full.shape()[1]);
    if batch > bb || s_real > sb {
        // a debug_assert here let release builds mis-slice (or panic deep
        // in Tensor::at2) on an oversized request; fail with Shape instead
        return Err(Error::Shape(format!(
            "slice_logits: {batch}x{s_real} exceeds bucket {bb}x{sb}"
        )));
    }
    let mut out = Vec::with_capacity(batch * s_real * vocab);
    for b in 0..batch {
        for s in 0..s_real {
            out.extend_from_slice(full.at2(b, s));
        }
    }
    Tensor::new(vec![batch, s_real, vocab], out)
}
