//! Layerwise execution engine.
//!
//! The engine composes the AOT op grid (attn_prefill / cache_init /
//! attn_cached / linear_block / mlp / head) into full forward passes,
//! dispatching each layer according to its substitution plan:
//!
//!   Attention  -> attn_prefill + cache_init   (prefill)
//!                 attn_cached                  (decode / verify)
//!                 attn_cached_rows             (continuous-batching decode
//!                                               + speculative verify)
//!   Linear     -> linear_block (the NBL path; no KV, no pos)
//!   Identity   -> nothing (DROP)
//!
//! plus `mlp` unless the block was folded. Embedding lookup, sampling and
//! all control flow are host-side Rust; Python never runs here.

pub mod capture;
pub mod engine;

pub use capture::CaptureSource;
pub use engine::{Engine, PrefillResult, RowDecode, RowSpecDecode};
