//! Calibration capture: adapt the engine's prefill tap to the
//! `nbl::calibrate::ActivationSource` interface.
//!
//! Streams `n_seqs` sequences of `seq_len` tokens from a calibration
//! token stream through the engine, emitting per-layer (X, Y_delta) token
//! rows — the paper's §3.1 calibration dataset D with s sequences of
//! context length t.

use crate::error::Result;
use crate::executor::engine::Engine;
use crate::nbl::calibrate::ActivationSource;

pub struct CaptureSource<'a> {
    engine: &'a Engine,
    /// Calibration token stream (windows are cut deterministically).
    tokens: &'a [u32],
    pub n_seqs: usize,
    pub seq_len: usize,
}

impl<'a> CaptureSource<'a> {
    pub fn new(engine: &'a Engine, tokens: &'a [u32], n_seqs: usize, seq_len: usize) -> Self {
        CaptureSource { engine, tokens, n_seqs, seq_len }
    }

    /// Deterministic window starts covering the stream. A token stream
    /// shorter than `seq_len` yields a short window; the shape checks
    /// downstream turn that into a calibration error instead of a panic.
    fn window(&self, i: usize) -> &'a [u32] {
        let span = self.tokens.len().saturating_sub(self.seq_len + 1).max(1);
        let start = ((i * 2654435761usize) % span).min(self.tokens.len());
        let end = (start + self.seq_len).min(self.tokens.len());
        // nbl-lint: allow(panic): start <= end <= tokens.len() by the clamps above
        &self.tokens[start..end]
    }
}

impl ActivationSource for CaptureSource<'_> {
    fn n_layers(&self) -> usize {
        self.engine.config().n_layers
    }

    fn d_model(&self) -> usize {
        self.engine.config().d_model
    }

    fn stream(
        &mut self,
        sink: &mut dyn FnMut(usize, &[f32], &[f32]) -> Result<()>,
    ) -> Result<()> {
        for i in 0..self.n_seqs {
            let ids = self.window(i);
            let mut cb = |layer: usize,
                          x: &crate::tensor::Tensor,
                          y: &crate::tensor::Tensor|
             -> Result<()> { sink(layer, x.data(), y.data()) };
            self.engine.prefill(ids, 1, self.seq_len, Some(&mut cb))?;
        }
        Ok(())
    }
}
