//! Streaming covariance accumulation — the O(s·t·d²) calibration pass.
//!
//! The executor feeds token-row chunks (X = attention-block input,
//! Y = attention delta); this module accumulates raw Gram sums and
//! finalizes unbiased covariance estimates (paper Alg. 2, lines 5-16).
//! Gram products can be computed on the CPU here or offloaded to the
//! `gram` XLA executable — both paths are tested to agree.
//!
//! Y+ = Y + X (residual output, used for the CCA bound) is derived
//! *algebraically* rather than accumulated:
//!   C_{Y+X}  = C_YX + C_XX
//!   C_{Y+Y+} = C_YY + C_YX + C_XY + C_XX
//! so one pass over the data serves both the bound and the LMMSE fit.

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Raw accumulated sums for a pair of d-dimensional streams.
#[derive(Clone)]
pub struct GramAccumulator {
    d: usize,
    pub n: usize,
    pub sum_x: Vec<f64>,
    pub sum_y: Vec<f64>,
    pub gxx: Mat,
    pub gxy: Mat,
    pub gyy: Mat,
}

impl GramAccumulator {
    pub fn new(d: usize) -> Self {
        GramAccumulator {
            d,
            n: 0,
            sum_x: vec![0.0; d],
            sum_y: vec![0.0; d],
            gxx: Mat::zeros(d, d),
            gxy: Mat::zeros(d, d),
            gyy: Mat::zeros(d, d),
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Accumulate a chunk of rows: x, y are [n, d] row-major f32.
    pub fn update(&mut self, x: &[f32], y: &[f32]) -> Result<()> {
        if x.len() != y.len() || x.len() % self.d != 0 {
            return Err(Error::Shape(format!(
                "gram update: x {} y {} d {}",
                x.len(),
                y.len(),
                self.d
            )));
        }
        let n = x.len() / self.d;
        let xm = Mat::from_f32(n, self.d, x);
        let ym = Mat::from_f32(n, self.d, y);
        self.update_mats(&xm, &ym);
        Ok(())
    }

    fn update_mats(&mut self, xm: &Mat, ym: &Mat) {
        let n = xm.rows();
        self.n += n;
        for r in 0..n {
            for (s, v) in self.sum_x.iter_mut().zip(xm.row(r)) {
                *s += v;
            }
            for (s, v) in self.sum_y.iter_mut().zip(ym.row(r)) {
                *s += v;
            }
        }
        self.gxx = self.gxx.add(&xm.gram());
        self.gxy = self.gxy.add(&xm.transpose().matmul(ym));
        self.gyy = self.gyy.add(&ym.gram());
    }

    /// Accumulate pre-computed Gram products (the XLA `gram` executable
    /// path: it returns X^T X, X^T Y and the column sums for a chunk).
    pub fn update_precomputed(
        &mut self,
        n: usize,
        gxx: &Mat,
        gxy: &Mat,
        gyy: &Mat,
        sum_x: &[f64],
        sum_y: &[f64],
    ) {
        self.n += n;
        self.gxx = self.gxx.add(gxx);
        self.gxy = self.gxy.add(gxy);
        self.gyy = self.gyy.add(gyy);
        for (s, v) in self.sum_x.iter_mut().zip(sum_x) {
            *s += v;
        }
        for (s, v) in self.sum_y.iter_mut().zip(sum_y) {
            *s += v;
        }
    }

    /// Merge another accumulator (parallel shards).
    pub fn merge(&mut self, other: &GramAccumulator) {
        assert_eq!(self.d, other.d);
        self.n += other.n;
        self.gxx = self.gxx.add(&other.gxx);
        self.gxy = self.gxy.add(&other.gxy);
        self.gyy = self.gyy.add(&other.gyy);
        for (s, v) in self.sum_x.iter_mut().zip(&other.sum_x) {
            *s += v;
        }
        for (s, v) in self.sum_y.iter_mut().zip(&other.sum_y) {
            *s += v;
        }
    }

    /// Finalize into unbiased covariance estimates.
    pub fn finalize(&self) -> Result<SampleStats> {
        if self.n < 2 {
            return Err(Error::Calibration(format!(
                "need >= 2 samples, have {}",
                self.n
            )));
        }
        let n = self.n as f64;
        let denom = n - 1.0;
        let mean_x: Vec<f64> = self.sum_x.iter().map(|s| s / n).collect();
        let mean_y: Vec<f64> = self.sum_y.iter().map(|s| s / n).collect();
        let d = self.d;
        // C = (G - n μ μ^T) / (n - 1)
        let cov = |g: &Mat, mu_a: &[f64], mu_b: &[f64]| {
            Mat::from_fn(d, d, |i, j| (g[(i, j)] - n * mu_a[i] * mu_b[j]) / denom)
        };
        Ok(SampleStats {
            n: self.n,
            cxx: cov(&self.gxx, &mean_x, &mean_x),
            cxy: cov(&self.gxy, &mean_x, &mean_y),
            cyy: cov(&self.gyy, &mean_y, &mean_y),
            mean_x,
            mean_y,
        })
    }
}

/// Finalized second-order statistics for one layer's (X, Y) pair.
#[derive(Clone)]
pub struct SampleStats {
    pub n: usize,
    pub mean_x: Vec<f64>,
    pub mean_y: Vec<f64>,
    pub cxx: Mat,
    /// Cross-covariance C_XY = E[(X-μx)(Y-μy)^T] (note: cyx = cxy^T).
    pub cxy: Mat,
    pub cyy: Mat,
}

impl SampleStats {
    /// Statistics of the residual output Y+ = Y + X, derived
    /// algebraically (module docs).
    pub fn residual_output(&self) -> (Vec<f64>, Mat, Mat) {
        let mean_yp: Vec<f64> = self
            .mean_x
            .iter()
            .zip(&self.mean_y)
            .map(|(a, b)| a + b)
            .collect();
        // C_{X,Y+} = C_XY + C_XX
        let cx_yp = self.cxy.add(&self.cxx);
        // C_{Y+Y+} = C_YY + C_XY^T + C_XY + C_XX
        let cyp_yp = self
            .cyy
            .add(&self.cxy.transpose())
            .add(&self.cxy)
            .add(&self.cxx);
        (mean_yp, cx_yp, cyp_yp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_cov(x: &[Vec<f64>], y: &[Vec<f64>]) -> Mat {
        let n = x.len();
        let d = x[0].len();
        let mx: Vec<f64> = (0..d).map(|j| x.iter().map(|r| r[j]).sum::<f64>() / n as f64).collect();
        let my: Vec<f64> = (0..d).map(|j| y.iter().map(|r| r[j]).sum::<f64>() / n as f64).collect();
        Mat::from_fn(d, d, |i, j| {
            x.iter()
                .zip(y)
                .map(|(xr, yr)| (xr[i] - mx[i]) * (yr[j] - my[j]))
                .sum::<f64>()
                / (n - 1) as f64
        })
    }

    fn random_rows(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<Vec<f64>>) {
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
        let rows = (0..n)
            .map(|i| flat[i * d..(i + 1) * d].iter().map(|&v| v as f64).collect())
            .collect();
        (flat, rows)
    }

    #[test]
    fn streaming_equals_batch() {
        let mut rng = Rng::new(42);
        let d = 6;
        let (xf, xr) = random_rows(&mut rng, 100, d);
        let (yf, yr) = random_rows(&mut rng, 100, d);

        // stream in uneven chunks
        let mut acc = GramAccumulator::new(d);
        for (lo, hi) in [(0, 13), (13, 50), (50, 99), (99, 100)] {
            acc.update(&xf[lo * d..hi * d], &yf[lo * d..hi * d]).unwrap();
        }
        let st = acc.finalize().unwrap();
        assert!(st.cxx.sub(&naive_cov(&xr, &xr)).max_abs() < 1e-4);
        assert!(st.cxy.sub(&naive_cov(&xr, &yr)).max_abs() < 1e-4);
        assert!(st.cyy.sub(&naive_cov(&yr, &yr)).max_abs() < 1e-4);
    }

    #[test]
    fn merge_equals_single() {
        let mut rng = Rng::new(3);
        let d = 4;
        let (xf, _) = random_rows(&mut rng, 64, d);
        let (yf, _) = random_rows(&mut rng, 64, d);
        let mut whole = GramAccumulator::new(d);
        whole.update(&xf, &yf).unwrap();
        let mut a = GramAccumulator::new(d);
        let mut b = GramAccumulator::new(d);
        a.update(&xf[..32 * d], &yf[..32 * d]).unwrap();
        b.update(&xf[32 * d..], &yf[32 * d..]).unwrap();
        a.merge(&b);
        let s1 = whole.finalize().unwrap();
        let s2 = a.finalize().unwrap();
        assert!(s1.cxx.sub(&s2.cxx).max_abs() < 1e-9);
        assert!(s1.cxy.sub(&s2.cxy).max_abs() < 1e-9);
    }

    #[test]
    fn residual_output_algebra() {
        // directly accumulate Y+ vs derive algebraically: must agree
        let mut rng = Rng::new(9);
        let d = 5;
        let (xf, _) = random_rows(&mut rng, 200, d);
        let (yf, _) = random_rows(&mut rng, 200, d);
        let ypf: Vec<f32> = xf.iter().zip(&yf).map(|(a, b)| a + b).collect();

        let mut acc = GramAccumulator::new(d);
        acc.update(&xf, &yf).unwrap();
        let st = acc.finalize().unwrap();
        let (mean_yp, cx_yp, cyp_yp) = st.residual_output();

        let mut direct = GramAccumulator::new(d);
        direct.update(&xf, &ypf).unwrap();
        let dst = direct.finalize().unwrap();
        for (a, b) in mean_yp.iter().zip(&dst.mean_y) {
            assert!((a - b).abs() < 1e-4);
        }
        assert!(cx_yp.sub(&dst.cxy).max_abs() < 1e-3);
        let mut direct_yy = GramAccumulator::new(d);
        direct_yy.update(&ypf, &ypf).unwrap();
        assert!(cyp_yp.sub(&direct_yy.finalize().unwrap().cxx).max_abs() < 1e-3);
    }

    #[test]
    fn too_few_samples_errors() {
        let acc = GramAccumulator::new(3);
        assert!(acc.finalize().is_err());
    }

    #[test]
    fn shape_mismatch_errors() {
        let mut acc = GramAccumulator::new(4);
        assert!(acc.update(&[0.0; 8], &[0.0; 12]).is_err());
        assert!(acc.update(&[0.0; 7], &[0.0; 7]).is_err());
    }
}
