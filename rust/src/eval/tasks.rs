//! Synthetic multiple-choice task generators.
//!
//! Every task draws items from the *same grammar the models were trained
//! on* (python/compile/corpora.py — word lists mirrored here), with the
//! correct answer being the true-grammar continuation and distractors
//! being corruptions. A trained model scores far above chance; random or
//! heavily-compressed models regress toward chance — which is exactly the
//! measurement the paper's benchmark tables make.
//!
//! Task menu mirrors the paper's eight benchmarks in format:
//!   arc_e      4-way continuation, category-violating distractors (easy)
//!   arc_c      4-way continuation, same-category distractors (hard)
//!   boolq      2-way yes/no fact check, raw loglik (paper: non-norm)
//!   hellaswag  4-way next-sentence, length-normalized
//!   mmlu       4-way infobox completion with 5-shot context
//!   obqa       4-way definition completion, length-normalized
//!   piqa       2-way grammatical-vs-scrambled, length-normalized
//!   winogrande 2-way referent resolution

use crate::util::rng::Rng;

// word lists mirrored from python/compile/corpora.py
const NOUNS: &[&str] = &[
    "robot", "garden", "river", "engine", "signal", "cache", "kernel",
    "matrix", "tensor", "packet", "planet", "crystal", "circuit", "library",
    "model", "window", "market", "forest", "valley", "beacon",
];
const ADJS: &[&str] = &[
    "small", "bright", "hidden", "rapid", "quiet", "linear", "sparse",
    "dense", "ancient", "modern", "stable", "fragile", "deep", "shallow",
];
const VERBS_T: &[&str] = &[
    "moves", "computes", "stores", "routes", "compresses", "observes",
    "updates", "encodes", "decodes", "balances", "measures", "predicts",
];
const ADVS: &[&str] = &["quickly", "slowly", "carefully", "rarely", "often", "silently"];
const PLACES: &[&str] = &[
    "the north field", "the old town", "the data hall", "the lab",
    "the harbor", "the archive",
];
const NAMES: &[&str] = &["arin", "bela", "cato", "dara", "evin", "fara", "goran", "hale"];
const WIKI_TOPICS: &[&str] = &[
    "linear estimator", "canonical analysis", "block cipher", "query cache",
    "token router", "systolic array", "prefix tree", "ring buffer",
    "hash table", "state machine", "packet filter", "page allocator",
];
const WIKI_FIELDS: &[&str] = &["type", "origin", "status", "class", "order", "family"];
const WIKI_VALUES: &[&str] = &[
    "primary", "secondary", "derived", "classical", "modern",
    "composite", "atomic", "stable", "deprecated",
];

/// One multiple-choice item (strings; the harness tokenizes).
#[derive(Debug, Clone)]
pub struct Item {
    pub context: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskSpec {
    pub name: &'static str,
    /// Length-normalize choice log-likelihood (lm-eval "acc_norm").
    pub length_norm: bool,
    pub n_choices: usize,
}

pub const TASKS: &[TaskSpec] = &[
    TaskSpec { name: "arc_e", length_norm: true, n_choices: 4 },
    TaskSpec { name: "arc_c", length_norm: true, n_choices: 4 },
    TaskSpec { name: "boolq", length_norm: false, n_choices: 2 },
    TaskSpec { name: "hellaswag", length_norm: true, n_choices: 4 },
    TaskSpec { name: "mmlu", length_norm: false, n_choices: 4 },
    TaskSpec { name: "obqa", length_norm: true, n_choices: 4 },
    TaskSpec { name: "piqa", length_norm: true, n_choices: 2 },
    TaskSpec { name: "winogrande", length_norm: false, n_choices: 2 },
];

pub fn all_tasks() -> &'static [TaskSpec] {
    TASKS
}

fn pick<'a>(rng: &mut Rng, xs: &[&'a str]) -> &'a str {
    xs[rng.below(xs.len())]
}

fn pick_other<'a>(rng: &mut Rng, xs: &[&'a str], not: &str) -> &'a str {
    loop {
        let c = pick(rng, xs);
        if c != not {
            return c;
        }
    }
}

/// Shuffle the correct answer into a random slot.
fn assemble(rng: &mut Rng, context: String, correct: String, distractors: Vec<String>) -> Item {
    let mut choices = distractors;
    let slot = rng.below(choices.len() + 1);
    choices.insert(slot, correct);
    Item { context, choices, correct: slot }
}

pub fn generate(task: &TaskSpec, n_items: usize, seed: u64) -> Vec<Item> {
    let mut rng = Rng::new(seed ^ fxhash(task.name));
    (0..n_items).map(|_| generate_one(task.name, &mut rng)).collect()
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

fn generate_one(name: &str, rng: &mut Rng) -> Item {
    match name {
        "arc_e" => {
            // "the {adj} {noun} {verb} the {noun2} ___" — correct: adverb;
            // distractors: nouns (category violation, easy)
            let ctx = format!(
                "the {} {} {} the {} ",
                pick(rng, ADJS), pick(rng, NOUNS), pick(rng, VERBS_T), pick(rng, NOUNS)
            );
            let correct = format!("{}.", pick(rng, ADVS));
            let distractors = (0..3).map(|_| format!("{}.", pick(rng, NOUNS))).collect();
            assemble(rng, ctx, correct, distractors)
        }
        "arc_c" => {
            // harder: the count-sentence template; distractors swap the
            // plural suffix / preposition structure (same category)
            let k = 2 + rng.below(98);
            let adj = pick(rng, ADJS);
            let noun = pick(rng, NOUNS);
            let place = pick(rng, PLACES);
            let ctx = format!("there are {k} {adj} ");
            let correct = format!("{noun}s in {place}.");
            let distractors = vec![
                format!("{noun}s on {place}."),                        // wrong preposition
                format!("{}s in {place}.", pick_other(rng, ADVS, "")), // adverb as noun
                format!("{noun}s in the {}.", pick(rng, NOUNS)),       // noun as place
            ];
            assemble(rng, ctx, correct, distractors)
        }
        "boolq" => {
            // 2-way category agreement: after "near the" the grammar only
            // ever produces places — never bare nouns.
            let name = pick(rng, NAMES);
            let noun = pick(rng, NOUNS);
            let place = pick(rng, PLACES).trim_start_matches("the ").to_string();
            let wrong = pick(rng, NOUNS);
            let ctx = format!("{name} said that the {noun} near the ");
            assemble(rng, ctx, format!("{place} "), vec![format!("{wrong} ")])
        }
        "hellaswag" => {
            // two true sentences, pick the true third vs sentences built
            // from scrambled grammar
            let s = |rng: &mut Rng| {
                format!(
                    "the {} {} {} the {} {}.",
                    pick(rng, ADJS), pick(rng, NOUNS), pick(rng, VERBS_T),
                    pick(rng, NOUNS), pick(rng, ADVS)
                )
            };
            let ctx = format!("{} {} ", s(rng), s(rng));
            let correct = s(rng);
            let scrambled = |rng: &mut Rng| {
                format!(
                    "the {} {} {} the {} {}.",
                    pick(rng, NOUNS), pick(rng, ADVS), pick(rng, ADJS),
                    pick(rng, VERBS_T), pick(rng, NOUNS)
                )
            };
            let distractors = (0..3).map(|_| scrambled(rng)).collect();
            assemble(rng, ctx, correct, distractors)
        }
        "mmlu" => {
            // 5-shot infobox completion: "field: value" lines from the
            // tiny-wiki grammar, answer with a valid VALUE (distractors:
            // topics — invalid fillers)
            let mut ctx = String::new();
            for _ in 0..5 {
                ctx.push_str(&format!(
                    "{}: {}\n",
                    pick(rng, WIKI_FIELDS),
                    pick(rng, WIKI_VALUES)
                ));
            }
            ctx.push_str(&format!("{}:", pick(rng, WIKI_FIELDS)));
            // distractors: adjectives — length-matched to the values but
            // never seen after "field:" in the wiki grammar ("stable"
            // lives in both lists, so re-draw on collision)
            let value = pick(rng, WIKI_VALUES);
            let correct = format!(" {value}");
            let distractors = (0..3)
                .map(|_| format!(" {}", pick_other(rng, ADJS, value)))
                .collect();
            assemble(rng, ctx, correct, distractors)
        }
        "obqa" => {
            // definition completion from the wiki grammar
            let topic = pick(rng, WIKI_TOPICS);
            let ctx = format!("== {topic} ==\na {topic} is a ");
            let correct = format!("{} {} that {} data.",
                                  pick(rng, ADJS), pick(rng, NOUNS), pick(rng, VERBS_T));
            let distractors = (0..3)
                .map(|_| format!("{} {} that {} data.",
                                 pick(rng, ADVS), pick(rng, VERBS_T), pick(rng, ADJS)))
                .collect();
            assemble(rng, ctx, correct, distractors)
        }
        "piqa" => {
            // grammatical vs word-order-scrambled completion (2-way)
            let name = pick(rng, NAMES);
            let noun = pick(rng, NOUNS);
            let place = pick(rng, PLACES);
            let verb = pick(rng, VERBS_T);
            let adj = pick(rng, ADJS);
            let obj = pick(rng, NOUNS);
            let ctx = format!("{name} said that ");
            let correct = format!("the {noun} near {place} {verb} every {adj} {obj}.");
            let wrong = format!("near the {verb} {place} every {noun} {obj} {adj}.");
            assemble(rng, ctx, correct, vec![wrong])
        }
        "winogrande" => {
            // 2-way plural agreement across a long dependency: the "there
            // are {k}" opener forces the plural form much later.
            let k = 2 + rng.below(98);
            let adj = pick(rng, ADJS);
            let noun = pick(rng, NOUNS);
            let place = pick(rng, PLACES);
            let ctx = format!("there are {k} {adj} {noun}");
            let correct = format!("s in {place}.");
            let wrong = format!(" in {place}.");
            assemble(rng, ctx, correct, vec![wrong])
        }
        other => panic!("unknown task {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_items() {
        for task in TASKS {
            let items = generate(task, 20, 7);
            assert_eq!(items.len(), 20);
            for it in &items {
                assert_eq!(it.choices.len(), task.n_choices, "{}", task.name);
                assert!(it.correct < it.choices.len());
                assert!(!it.context.is_empty());
                assert!(it.choices.iter().all(|c| !c.is_empty()));
                assert!(it.context.is_ascii() && it.choices.iter().all(|c| c.is_ascii()));
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for task in TASKS {
            let a = generate(task, 5, 11);
            let b = generate(task, 5, 11);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.choices, y.choices);
                assert_eq!(x.correct, y.correct);
            }
        }
    }

    #[test]
    fn correct_slot_is_uniformish() {
        let spec = &TASKS[0];
        let items = generate(spec, 200, 3);
        let mut counts = [0usize; 4];
        for it in items {
            counts[it.correct] += 1;
        }
        for c in counts {
            assert!(c > 20, "slot distribution skewed: {counts:?}");
        }
    }

    #[test]
    fn correct_choice_differs_from_distractors() {
        for task in TASKS {
            for it in generate(task, 30, 5) {
                let correct = &it.choices[it.correct];
                for (i, c) in it.choices.iter().enumerate() {
                    if i != it.correct {
                        assert_ne!(c, correct, "{}", task.name);
                    }
                }
            }
        }
    }
}
