//! lm-eval-harness-style scoring: choose the answer with the highest
//! (optionally length-normalized) log-likelihood under the model.
//!
//! All choices of an item are scored in ONE batched prefill (the choices
//! become batch rows padded to a common bucket) — on this single-core
//! testbed dispatch overhead dominates, so batching choices is the
//! difference between minutes and tens of minutes per table.

use crate::data::tokenizer::ByteTokenizer;
use crate::error::Result;
use crate::executor::engine::Engine;
use crate::eval::tasks::{generate, Item, TaskSpec};
use crate::sampling::log_softmax;
use crate::util::{mean, percentile};

/// A tokenized multiple-choice item.
pub struct McItem {
    pub context: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub correct: usize,
}

impl McItem {
    pub fn tokenize(item: &Item) -> McItem {
        let tok = ByteTokenizer::new();
        McItem {
            context: tok.encode(&item.context),
            choices: item.choices.iter().map(|c| tok.encode(c)).collect(),
            correct: item.correct,
        }
    }
}

/// Score one item; returns the chosen index.
pub fn score_item(engine: &Engine, item: &McItem, length_norm: bool) -> Result<usize> {
    let n = item.choices.len();
    // rows: context + choice, right-padded to the longest row
    let rows: Vec<Vec<u32>> = item
        .choices
        .iter()
        .map(|c| {
            let mut r = item.context.clone();
            r.extend_from_slice(c);
            r
        })
        .collect();
    let max_len = rows.iter().map(|r| r.len()).max().unwrap();
    let mut ids = vec![0u32; n * max_len];
    for (i, r) in rows.iter().enumerate() {
        ids[i * max_len..i * max_len + r.len()].copy_from_slice(r);
    }
    let out = engine.prefill(&ids, n, max_len, None)?;
    let logits = engine.head(&out.hidden)?;

    let mut best = (f64::NEG_INFINITY, 0usize);
    for (i, choice) in item.choices.iter().enumerate() {
        let ctx_len = item.context.len();
        let mut ll = 0.0f64;
        // token at absolute position p is predicted by logits at p-1
        for (j, &tok) in choice.iter().enumerate() {
            let p = ctx_len + j;
            let ls = log_softmax(logits.at2(i, p - 1));
            ll += ls[tok as usize];
        }
        let score = if length_norm { ll / choice.len() as f64 } else { ll };
        if score > best.0 {
            best = (score, i);
        }
    }
    Ok(best.1)
}

/// Result for one task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub name: &'static str,
    pub accuracy: f64,
    pub n: usize,
}

impl TaskResult {
    /// Binomial standard error.
    pub fn se(&self) -> f64 {
        (self.accuracy * (1.0 - self.accuracy) / self.n as f64).sqrt()
    }

    pub fn chance(&self, n_choices: usize) -> f64 {
        1.0 / n_choices as f64
    }
}

/// Summary across all tasks (paper App. E.3 pooled SE).
#[derive(Debug, Clone)]
pub struct EvalSummary {
    pub tasks: Vec<TaskResult>,
    pub avg_accuracy: f64,
    pub pooled_se: f64,
}

/// Run every task in the menu on the engine.
pub fn evaluate_all(
    engine: &Engine,
    tasks: &[TaskSpec],
    n_items: usize,
    seed: u64,
) -> Result<EvalSummary> {
    let mut results = Vec::new();
    for spec in tasks {
        let items = generate(spec, n_items, seed);
        let mut correct = 0usize;
        for item in &items {
            let mc = McItem::tokenize(item);
            if score_item(engine, &mc, spec.length_norm)? == mc.correct {
                correct += 1;
            }
        }
        results.push(TaskResult {
            name: spec.name,
            accuracy: correct as f64 / items.len() as f64,
            n: items.len(),
        });
    }
    Ok(summarize(results))
}

pub fn summarize(tasks: Vec<TaskResult>) -> EvalSummary {
    let accs: Vec<f64> = tasks.iter().map(|t| t.accuracy).collect();
    let n = tasks.len().max(1) as f64;
    let pooled_se = (tasks.iter().map(|t| t.se() * t.se()).sum::<f64>()).sqrt() / n;
    EvalSummary { avg_accuracy: mean(&accs), pooled_se, tasks }
}

/// Latency percentiles helper for serve-side summaries (re-exported here
/// because the bench tables pair accuracy with speed columns).
pub fn p50_p90(xs: &[f64]) -> (f64, f64) {
    (percentile(xs, 50.0), percentile(xs, 90.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pooled_se_formula() {
        let tasks = vec![
            TaskResult { name: "a", accuracy: 0.5, n: 100 },
            TaskResult { name: "b", accuracy: 0.5, n: 100 },
        ];
        let se_each = (0.25f64 / 100.0).sqrt();
        let want = (2.0 * se_each * se_each).sqrt() / 2.0;
        let s = summarize(tasks);
        assert!((s.pooled_se - want).abs() < 1e-12);
        assert!((s.avg_accuracy - 0.5).abs() < 1e-12);
    }

    #[test]
    fn task_se_extremes() {
        let t = TaskResult { name: "x", accuracy: 1.0, n: 50 };
        assert_eq!(t.se(), 0.0);
        let t2 = TaskResult { name: "x", accuracy: 0.5, n: 50 };
        assert!(t2.se() > 0.0);
    }

    #[test]
    fn tokenize_round_trips_lengths() {
        let item = Item {
            context: "ab ".into(),
            choices: vec!["cd.".into(), "efgh.".into()],
            correct: 1,
        };
        let mc = McItem::tokenize(&item);
        assert_eq!(mc.context.len(), 3);
        assert_eq!(mc.choices[1].len(), 5);
        assert_eq!(mc.correct, 1);
    }

    use crate::eval::tasks::Item;
}
