//! Perplexity evaluation (paper App. F.1, Tables 14/15).

use crate::data::corpus::Corpus;
use crate::error::Result;
use crate::executor::engine::Engine;
use crate::sampling::log_softmax;

/// Perplexity over `n_windows` sequential windows of `win` tokens.
///
/// Each window is prefetched once; token t is scored from logits at t-1
/// (the first token of a window is unscored, standard sliding protocol).
pub fn perplexity(engine: &Engine, corpus: &Corpus, n_windows: usize, win: usize) -> Result<f64> {
    let windows = corpus.sequential_windows(win, n_windows);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in windows {
        let out = engine.prefill(w, 1, w.len(), None)?;
        let logits = engine.head(&out.hidden)?;
        for t in 1..w.len() {
            let ls = log_softmax(logits.at2(0, t - 1));
            nll -= ls[w[t] as usize];
            count += 1;
        }
    }
    if count == 0 {
        return Ok(f64::INFINITY);
    }
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    // engine-backed perplexity is covered by rust/tests/test_nbl_end_to_end.rs;
    // the unit here checks degenerate inputs only.
    use crate::data::corpus::{Corpus, CorpusId};

    #[test]
    fn empty_windows_is_infinite() {
        let c = Corpus {
            id: CorpusId::TinyC4,
            split: "val".into(),
            tokens: vec![1, 2, 3],
        };
        // window longer than the corpus -> no windows -> inf
        assert_eq!(c.sequential_windows(100, 5).len(), 0);
    }
}
