//! Evaluation harness: 8 synthetic multiple-choice "reasoning" tasks
//! (stand-ins for ARC-e/c, BoolQ, HellaSwag, MMLU, OBQA, PIQA,
//! WinoGrande — DESIGN.md §2) scored exactly like lm-eval-harness
//! (choice log-likelihood, optionally length-normalized), plus
//! perplexity on the tiny-c4 / tiny-wiki validation splits.

pub mod harness;
pub mod perplexity;
pub mod tasks;

pub use harness::{evaluate_all, EvalSummary, McItem, TaskResult};
pub use perplexity::perplexity;
pub use tasks::{all_tasks, TaskSpec};
