//! Paper-shaped table formatting + CSV/JSON persistence under reports/.

use std::path::PathBuf;

use crate::error::Result;
use crate::util::json::Json;

/// A simple column-aligned table (the shape of the paper's Tables 2-5).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write reports/<id>.txt and reports/<id>.csv; returns the txt path.
    pub fn save(&self, id: &str) -> Result<PathBuf> {
        let dir = reports_dir();
        std::fs::create_dir_all(&dir)?;
        let txt = dir.join(format!("{id}.txt"));
        std::fs::write(&txt, self.render())?;
        std::fs::write(dir.join(format!("{id}.csv")), self.to_csv())?;
        Ok(txt)
    }
}

pub fn reports_dir() -> PathBuf {
    if let Ok(d) = std::env::var("NBL_REPORTS") {
        return PathBuf::from(d);
    }
    PathBuf::from("reports")
}

pub fn save_json(id: &str, j: &Json) -> Result<PathBuf> {
    let dir = reports_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{id}.json"));
    std::fs::write(&path, j.to_string())?;
    Ok(path)
}

/// Provenance block every bench artifact embeds: which commit produced
/// the numbers, when, and whether the fast (CI-scale) profile was on.
/// Best-effort by design — a detached tarball build reports "unknown"
/// rather than failing the bench.
pub fn provenance() -> Json {
    let git_sha = std::env::var("GITHUB_SHA")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(|| {
            std::process::Command::new("git")
                .args(["rev-parse", "--short", "HEAD"])
                .output()
                .ok()
                .filter(|o| o.status.success())
                .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
                .filter(|s| !s.is_empty())
        })
        .unwrap_or_else(|| "unknown".to_string());
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let nbl_fast = std::env::var("NBL_FAST").is_ok_and(|v| v == "1");
    Json::obj(vec![
        ("git_sha", Json::Str(git_sha)),
        ("unix_time", Json::Num(unix_time as f64)),
        ("nbl_fast", Json::Bool(nbl_fast)),
    ])
}

/// Format a ratio like the paper ("1.27"), with 1 = baseline.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}")
}

/// Format an accuracy in percent with one decimal ("70.2").
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("T", &["Method", "Avg"]);
        t.row(vec!["Baseline".into(), "70.2".into()]);
        t.row(vec!["Attn NBL-8".into(), "70.0".into()]);
        let r = t.render();
        assert!(r.contains("Baseline"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ratio(1.266), "1.27");
        assert_eq!(pct(0.702), "70.2");
    }

    #[test]
    fn provenance_is_serializable() {
        let p = provenance();
        assert!(!p.get("git_sha").unwrap().as_str().unwrap().is_empty());
        assert!(p.get("unix_time").unwrap().as_f64().unwrap() >= 0.0);
        let back = Json::parse(&p.to_string()).unwrap();
        assert!(back.get("nbl_fast").unwrap().as_bool().is_ok());
    }
}
