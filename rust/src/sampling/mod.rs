//! Token sampling (host-side; logits come back from the head executable).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SamplingParams {
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0 }
    }

    pub fn top_k(k: usize, temperature: f64, seed: u64) -> SamplingParams {
        SamplingParams { temperature, top_k: k, seed }
    }
}

pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        let rng = Rng::new(params.seed);
        Sampler { params, rng }
    }

    /// Sample a token id from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.params.temperature <= 0.0 {
            return argmax(logits);
        }
        // top-k filter then softmax at temperature
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let k = if self.params.top_k == 0 {
            logits.len()
        } else {
            self.params.top_k.min(logits.len())
        };
        let kept = &idx[..k];
        let t = self.params.temperature;
        let max = logits[kept[0]] as f64;
        let weights: Vec<f64> = kept
            .iter()
            .map(|&i| ((logits[i] as f64 - max) / t).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.uniform() * total;
        for (w, &i) in weights.iter().zip(kept) {
            u -= w;
            if u <= 0.0 {
                return i as u32;
            }
        }
        kept[k - 1] as u32
    }
}

pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Log-softmax of a logits row (eval scoring).
pub fn log_softmax(logits: &[f32]) -> Vec<f64> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64) - max).exp()).collect();
    let lse = exps.iter().sum::<f64>().ln() + max;
    logits.iter().map(|&x| x as f64 - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplingParams::greedy());
        assert_eq!(s.sample(&[0.1, 3.0, 2.0]), 1);
    }

    #[test]
    fn top1_equals_greedy() {
        let mut s = Sampler::new(SamplingParams::top_k(1, 1.0, 9));
        for _ in 0..20 {
            assert_eq!(s.sample(&[0.1, 3.0, 2.0]), 1);
        }
    }

    #[test]
    fn topk_stays_in_top_set() {
        let mut s = Sampler::new(SamplingParams::top_k(2, 1.0, 4));
        for _ in 0..200 {
            let t = s.sample(&[0.0, 5.0, 4.5, -2.0]);
            assert!(t == 1 || t == 2);
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        let mut hot = Sampler::new(SamplingParams::top_k(0, 5.0, 1));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(hot.sample(&[1.0, 1.1, 0.9, 1.05]));
        }
        assert!(seen.len() >= 3, "high temperature should visit most tokens");
    }

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = ls.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }
}
