//! `repro` — the NBL coordinator CLI.
//!
//! Subcommands:
//!   serve      start the TCP serving engine (optionally NBL-compressed)
//!   calibrate  run Algorithm 1/2 and print bounds + rankings
//!   rank       per-layer CCA bound + criteria rankings (Fig 2 / T20)
//!   eval       8-task accuracy + perplexity for a plan
//!   generate   greedy/sampled generation from a prompt (T13 --sweep)
//!   info       artifacts / model / grid summary

use std::sync::Arc;

use nbl::bench::experiments::{ExpConfig, Workbench};
use nbl::data::corpus::CorpusId;
use nbl::data::ByteTokenizer;
use nbl::eval::perplexity;
use nbl::nbl::criteria::Criterion;
use nbl::report::Table;
use nbl::sampling::SamplingParams;
use nbl::server::api::GenRequest;
use nbl::server::service::{Server, ServerConfig};
use nbl::server::tcp::TcpFrontend;
use nbl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["sweep", "drop", "help"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "calibrate" | "rank" => rank(&args),
        "eval" => eval(&args),
        "generate" => generate(&args),
        "info" => info(),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
    .map_err(|e| anyhow::anyhow!("{e}"))
}

const HELP: &str = "\
repro — Neural Block Linearization coordinator

USAGE: repro <command> [options]

COMMANDS
  serve     --model main --m 2 --addr 127.0.0.1:7878   NBL-compressed TCP server
  rank      --model main --corpus tinyc4               per-layer CCA bounds + rankings
  eval      --model main --m 2 [--drop]                8-task accuracy + perplexity
  generate  --model main --prompt 'the small robot ' --tokens 48 [--m 2] [--sweep]
  info                                                 artifacts summary

Set NBL_FAST=1 for quick calibration/eval budgets.
";

fn corpus_of(args: &Args) -> CorpusId {
    match args.get_or("corpus", "tinyc4") {
        "tinywiki" => CorpusId::TinyWiki,
        _ => CorpusId::TinyC4,
    }
}

fn workbench(args: &Args) -> nbl::error::Result<Workbench> {
    Workbench::with_corpus(
        args.get_or("model", "main"),
        ExpConfig::from_env(),
        corpus_of(args),
    )
}

fn serve(args: &Args) -> nbl::error::Result<()> {
    let wb = workbench(args)?;
    let m = args.get_usize("m", 0)?;
    let plan = if m == 0 {
        nbl::nbl::plan::ModelPlan::baseline(wb.engine.config().n_layers)
    } else {
        wb.report.plan_attn_nbl(m, Criterion::CcaBound)?
    };
    println!("plan: {} [{}]", plan.kind.label(), plan.describe());
    let engine = Arc::new(wb.engine.with_plan(plan)?);
    let server = Arc::new(Server::new(engine, ServerConfig::default()));
    let metrics = server.metrics.clone();
    let front = TcpFrontend::start(server, args.get_or("addr", "127.0.0.1:7878"))?;
    println!("listening on {} (line-JSON; ctrl-c to stop)", front.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let s = metrics.summary();
        if s.requests > 0 {
            println!(
                "served {} requests, {} tokens, mean TTFT {:.1} ms",
                s.requests,
                s.generated_tokens,
                s.mean_ttft_s * 1e3
            );
        }
    }
}

fn rank(args: &Args) -> nbl::error::Result<()> {
    let wb = workbench(args)?;
    let mut table = Table::new(
        &format!(
            "per-layer calibration ({}, corpus {})",
            wb.engine.config().name,
            wb.calib.id.name()
        ),
        &["layer", "cca_nmse_bound", "bound/dim", "cosine_dist", "top_rho"],
    );
    for lc in &wb.report.layers {
        table.row(vec![
            lc.layer.to_string(),
            format!("{:.4}", lc.cca.nmse_bound),
            format!("{:.6}", lc.cca.nmse_bound_per_dim),
            format!("{:.4}", lc.cosine_distance),
            format!("{:.5}", lc.cca.rho.first().copied().unwrap_or(0.0)),
        ]);
    }
    println!("{}", table.render());
    for crit in [Criterion::CcaBound, Criterion::CosineDistance] {
        println!(
            "{} ranking (most->least important): {:?}",
            crit.name(),
            wb.report.importance_ranking(crit)
        );
    }
    Ok(())
}

fn eval(args: &Args) -> nbl::error::Result<()> {
    let wb = workbench(args)?;
    let m = args.get_usize("m", 0)?;
    let plan = if m == 0 {
        nbl::nbl::plan::ModelPlan::baseline(wb.engine.config().n_layers)
    } else if args.flag("drop") {
        wb.report.plan_attn_drop(m, Criterion::CosineDistance)
    } else {
        wb.report.plan_attn_nbl(m, Criterion::CcaBound)?
    };
    println!("plan: {}", plan.kind.label());
    let engine = wb.engine.with_plan(plan)?;
    let acc = wb.accuracy(&engine)?;
    for t in &acc.tasks {
        println!("  {:<12} {:.3}", t.name, t.accuracy);
    }
    println!("  avg {:.3} ± {:.3}", acc.avg_accuracy, acc.pooled_se);
    let ppl = perplexity(&engine, &wb.val, wb.cfg.ppl_windows, 128)?;
    println!("  perplexity ({}) {:.3}", wb.val.id.name(), ppl);
    let speed = wb.speed(&engine)?;
    println!(
        "  prefill {:.0} tok/s, decode {:.0} tok/s",
        speed.prefill_tok_s, speed.decode_tok_s
    );
    Ok(())
}

fn generate(args: &Args) -> nbl::error::Result<()> {
    let wb = workbench(args)?;
    let tok = ByteTokenizer::new();
    let prompt = args.get_or("prompt", "the small robot ");
    let tokens = args.get_usize("tokens", 48)?;
    let temperature = args.get_f64("temperature", 0.0)?;
    let ms: Vec<usize> = if args.flag("sweep") {
        let k = wb.engine.config().n_layers;
        (0..k).collect()
    } else {
        vec![args.get_usize("m", 0)?]
    };
    for m in ms {
        for (name, drop) in [("NBL", false), ("DROP", true)] {
            if m == 0 && drop {
                continue;
            }
            let plan = if m == 0 {
                nbl::nbl::plan::ModelPlan::baseline(wb.engine.config().n_layers)
            } else if drop {
                wb.report.plan_attn_drop(m, Criterion::CosineDistance)
            } else {
                wb.report.plan_attn_nbl(m, Criterion::CcaBound)?
            };
            let engine = wb.engine.with_plan(plan)?;
            let server = Server::new(Arc::new(engine), ServerConfig::default());
            let r = server.generate_one(&GenRequest {
                id: 0,
                prompt: tok.encode(prompt),
                max_new_tokens: tokens,
                params: if temperature > 0.0 {
                    SamplingParams::top_k(20, temperature, 7)
                } else {
                    SamplingParams::greedy()
                },
                tenant: String::new(),
                weight: 1,
                deadline_ms: None,
                stream: false,
            });
            let label = if m == 0 { "baseline".into() } else { format!("{name}-{m}") };
            println!("[{label:>9}] {:?}", r.text);
        }
    }
    Ok(())
}

fn info() -> nbl::error::Result<()> {
    let artifacts = nbl::model::Artifacts::discover()?;
    println!("artifacts: {}", artifacts.root.display());
    let grid = artifacts.grid()?;
    println!(
        "grid: batches {:?}, prefill {:?}, cached {:?}, pointwise {:?}",
        grid.batches, grid.prefill_lens, grid.cached_lens, grid.pointwise_lens
    );
    let runtime = nbl::runtime::Runtime::new(artifacts.clone())?;
    for name in artifacts.model_names()? {
        let engine = nbl::executor::Engine::load(runtime.clone(), &name)?;
        let c = engine.config();
        println!(
            "model {:<8} layers {:>2}  d {}  heads {}/{}  params {}",
            name,
            c.n_layers,
            c.d_model,
            c.n_heads,
            c.n_kv_heads,
            engine.weights.param_count()
        );
    }
    Ok(())
}
