//! Model hyper-parameters, parsed from the weight manifest JSON written
//! by `python/compile/model.py::save_weights` (single source of truth).

use crate::error::Result;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_ctx: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

impl ModelConfig {
    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: name.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            max_ctx: j.get("max_ctx")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
        })
    }

    pub fn d_q(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Bytes of one layer's KV cache for a batch (f32; paper §H.2 uses
    /// half precision — the formula-level comparisons scale accordingly).
    pub fn kv_bytes_per_layer(&self, batch: usize, ctx: usize, bytes_per_elem: usize) -> usize {
        2 * batch * ctx * self.d_kv() * bytes_per_elem
    }

    /// Paper §H.2: KV bytes with m of the K layers linearized.
    pub fn kv_bytes_with_nbl(
        &self,
        batch: usize,
        ctx: usize,
        m: usize,
        bytes_per_elem: usize,
    ) -> usize {
        self.kv_bytes_per_layer(batch, ctx, bytes_per_elem) * (self.n_layers - m)
    }

    /// Approximate forward FLOPs for a prefill of length n (paper §4.2
    /// complexity model) under a plan with `m` linearized attentions and
    /// `blocks_dropped` whole blocks removed.
    pub fn prefill_flops(&self, n: usize, m_linear: usize, blocks_dropped: usize) -> f64 {
        let d = self.d_model as f64;
        let dq = self.d_q() as f64;
        let dkv = self.d_kv() as f64;
        let f = self.d_ff as f64;
        let nn = n as f64;
        let attn_proj = 2.0 * nn * d * (dq + 2.0 * dkv + dq);
        let attn_quad = 2.0 * nn * nn * (dq + dq); // scores + values
        let linear = 2.0 * nn * d * d;
        let mlp = 2.0 * nn * d * f * 3.0;
        let k = self.n_layers as f64;
        let m = m_linear as f64;
        let dropped = blocks_dropped as f64;
        let full_layers = k - m - dropped;
        full_layers * (attn_proj + attn_quad + mlp) + m * (linear + mlp)
            + 2.0 * nn * d * self.vocab as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 256,
            d_model: 128,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 256,
            max_ctx: 512,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn derived_dims() {
        let c = cfg();
        assert_eq!(c.d_q(), 128);
        assert_eq!(c.d_kv(), 64);
    }

    #[test]
    fn kv_formula_matches_paper() {
        let c = cfg();
        // 2 * bs * n * d * g/h == 2 * bs * n * d_kv
        let full = c.kv_bytes_per_layer(64, 512, 2) * c.n_layers;
        let nbl12 = c.kv_bytes_with_nbl(64, 512, 2, 2) + c.kv_bytes_per_layer(64, 512, 2) * 0;
        assert_eq!(nbl12, full / 6 * 4);
        assert!((c.kv_bytes_with_nbl(64, 512, 3, 2) as f64 / full as f64 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prefill_flops_decrease_with_m() {
        let c = cfg();
        let f0 = c.prefill_flops(512, 0, 0);
        let f2 = c.prefill_flops(512, 2, 0);
        let f4 = c.prefill_flops(512, 4, 0);
        assert!(f0 > f2 && f2 > f4);
        // quadratic term dominates more at longer n: relative gain grows
        let gain_short = c.prefill_flops(32, 2, 0) / c.prefill_flops(32, 0, 0);
        let gain_long = f2 / f0;
        assert!(gain_long < gain_short);
    }

    #[test]
    fn from_json_round_trip() {
        let j = Json::parse(
            r#"{"vocab":256,"d_model":128,"n_layers":6,"n_heads":4,
                "n_kv_heads":2,"head_dim":32,"d_ff":256,"max_ctx":512,
                "rope_theta":10000.0,"norm_eps":1e-5}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json("t", &j).unwrap();
        assert_eq!(c, cfg());
    }
}
