//! Weight loading: the flat f32 .bin + JSON manifest emitted by
//! `python/compile/model.py::save_weights`. Layout (row-major, LE):
//! emb, per-layer [attn_norm, wq, wk, wv, wo, mlp_norm, w1, w3, w2],
//! final_norm, w_head.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub attn_norm: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub mlp_norm: Tensor,
    pub w1: Tensor,
    pub w3: Tensor,
    pub w2: Tensor,
}

#[derive(Debug, Clone)]
pub struct Weights {
    pub config: ModelConfig,
    pub emb: Tensor,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Tensor,
    pub w_head: Tensor,
}

impl Weights {
    pub fn load(config_name: &str, bin_path: &Path, json_path: &Path) -> Result<Weights> {
        let manifest = Json::parse_file(json_path)?;
        let config = ModelConfig::from_json(config_name, manifest.get("config")?)?;
        let raw = std::fs::read(bin_path)?;
        let total = manifest.get("total_bytes")?.as_usize()?;
        if raw.len() != total {
            return Err(Error::Artifact(format!(
                "weights {}: {} bytes on disk, manifest says {}",
                bin_path.display(),
                raw.len(),
                total
            )));
        }

        // index tensors by name
        let mut by_name: BTreeMap<String, Tensor> = BTreeMap::new();
        for t in manifest.get("tensors")?.as_arr()? {
            let name = t.get("name")?.as_str()?.to_string();
            let shape = t.get("shape")?.as_usize_vec()?;
            let off = t.get("offset_bytes")?.as_usize()?;
            let size = t.get("size_bytes")?.as_usize()?;
            if off + size > raw.len() {
                return Err(Error::Artifact(format!("tensor {name} out of bounds")));
            }
            let floats: Vec<f32> = raw[off..off + size]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            by_name.insert(name, Tensor::new(shape, floats)?);
        }

        let mut take = |name: &str| -> Result<Tensor> {
            by_name
                .remove(name)
                .ok_or_else(|| Error::Artifact(format!("missing tensor '{name}'")))
        };

        let emb = take("emb")?;
        let mut layers = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            layers.push(LayerWeights {
                attn_norm: take(&format!("layers.{i}.attn_norm"))?,
                wq: take(&format!("layers.{i}.wq"))?,
                wk: take(&format!("layers.{i}.wk"))?,
                wv: take(&format!("layers.{i}.wv"))?,
                wo: take(&format!("layers.{i}.wo"))?,
                mlp_norm: take(&format!("layers.{i}.mlp_norm"))?,
                w1: take(&format!("layers.{i}.w1"))?,
                w3: take(&format!("layers.{i}.w3"))?,
                w2: take(&format!("layers.{i}.w2"))?,
            });
        }
        let w = Weights {
            emb,
            layers,
            final_norm: take("final_norm")?,
            w_head: take("w_head")?,
            config,
        };
        w.validate()?;
        Ok(w)
    }

    /// Shape-check every tensor against the config.
    pub fn validate(&self) -> Result<()> {
        let c = &self.config;
        let want = |t: &Tensor, shape: &[usize], name: &str| -> Result<()> {
            if t.shape() != shape {
                return Err(Error::Shape(format!(
                    "{name}: shape {:?}, want {shape:?}",
                    t.shape()
                )));
            }
            Ok(())
        };
        want(&self.emb, &[c.vocab, c.d_model], "emb")?;
        want(&self.final_norm, &[c.d_model], "final_norm")?;
        want(&self.w_head, &[c.d_model, c.vocab], "w_head")?;
        for (i, l) in self.layers.iter().enumerate() {
            want(&l.attn_norm, &[c.d_model], &format!("l{i}.attn_norm"))?;
            want(&l.wq, &[c.d_model, c.d_q()], &format!("l{i}.wq"))?;
            want(&l.wk, &[c.d_model, c.d_kv()], &format!("l{i}.wk"))?;
            want(&l.wv, &[c.d_model, c.d_kv()], &format!("l{i}.wv"))?;
            want(&l.wo, &[c.d_q(), c.d_model], &format!("l{i}.wo"))?;
            want(&l.mlp_norm, &[c.d_model], &format!("l{i}.mlp_norm"))?;
            want(&l.w1, &[c.d_model, c.d_ff], &format!("l{i}.w1"))?;
            want(&l.w3, &[c.d_model, c.d_ff], &format!("l{i}.w3"))?;
            want(&l.w2, &[c.d_ff, c.d_model], &format!("l{i}.w2"))?;
        }
        Ok(())
    }

    /// Embedding lookup on the host (ids -> [B, T, D]); embedding is pure
    /// gather so it never goes through an executable.
    pub fn embed(&self, ids: &[u32], batch: usize, t: usize) -> Result<Tensor> {
        let d = self.config.d_model;
        if ids.len() != batch * t {
            return Err(Error::Shape(format!(
                "embed: {} ids for batch {batch} x t {t}",
                ids.len()
            )));
        }
        let mut out = vec![0.0f32; batch * t * d];
        for (i, &id) in ids.iter().enumerate() {
            if id as usize >= self.config.vocab {
                return Err(Error::Shape(format!("token id {id} >= vocab")));
            }
            out[i * d..(i + 1) * d].copy_from_slice(self.emb.row(id as usize));
        }
        Tensor::new(vec![batch, t, d], out)
    }

    pub fn param_count(&self) -> usize {
        let mut n = self.emb.len() + self.final_norm.len() + self.w_head.len();
        for l in &self.layers {
            n += l.attn_norm.len()
                + l.wq.len()
                + l.wk.len()
                + l.wv.len()
                + l.wo.len()
                + l.mlp_norm.len()
                + l.w1.len()
                + l.w3.len()
                + l.w2.len();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // integration tests against real artifacts live in rust/tests/;
    // here we unit-test validate() failure modes with hand-built weights.

    fn tiny_config() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 2,
            d_ff: 8,
            max_ctx: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_weights() -> Weights {
        let c = tiny_config();
        Weights {
            emb: Tensor::zeros(vec![c.vocab, c.d_model]),
            layers: vec![LayerWeights {
                attn_norm: Tensor::zeros(vec![c.d_model]),
                wq: Tensor::zeros(vec![c.d_model, c.d_q()]),
                wk: Tensor::zeros(vec![c.d_model, c.d_kv()]),
                wv: Tensor::zeros(vec![c.d_model, c.d_kv()]),
                wo: Tensor::zeros(vec![c.d_q(), c.d_model]),
                mlp_norm: Tensor::zeros(vec![c.d_model]),
                w1: Tensor::zeros(vec![c.d_model, c.d_ff]),
                w3: Tensor::zeros(vec![c.d_model, c.d_ff]),
                w2: Tensor::zeros(vec![c.d_ff, c.d_model]),
            }],
            final_norm: Tensor::zeros(vec![c.d_model]),
            w_head: Tensor::zeros(vec![c.d_model, c.vocab]),
            config: c,
        }
    }

    #[test]
    fn validate_accepts_consistent() {
        assert!(tiny_weights().validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_shape() {
        let mut w = tiny_weights();
        w.layers[0].wq = Tensor::zeros(vec![4, 3]);
        assert!(w.validate().is_err());
    }

    #[test]
    fn embed_gathers_rows() {
        let mut w = tiny_weights();
        w.emb = Tensor::from_fn(vec![8, 4], |i| i as f32);
        let e = w.embed(&[1, 0, 7], 1, 3).unwrap();
        assert_eq!(e.at2(0, 0), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(e.at2(0, 2), &[28.0, 29.0, 30.0, 31.0]);
        assert!(w.embed(&[9], 1, 1).is_err()); // out of vocab
        assert!(w.embed(&[1, 2], 1, 3).is_err()); // wrong count
    }
}
