//! Model configuration, weight loading and artifact discovery.

pub mod artifacts;
pub mod config;
pub mod weights;

pub use artifacts::Artifacts;
pub use config::ModelConfig;
pub use weights::{LayerWeights, Weights};
