//! Artifact discovery: locate artifacts/ (built by `make artifacts`) and
//! resolve HLO files, weights, corpora and goldens through the manifest.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Artifacts {
    pub root: PathBuf,
    pub manifest: Json,
}

impl Artifacts {
    /// Locate artifacts/: $NBL_ARTIFACTS, ./artifacts, or walking up from
    /// the executable (cargo target dirs).
    pub fn discover() -> Result<Artifacts> {
        let mut candidates: Vec<PathBuf> = Vec::new();
        if let Ok(env) = std::env::var("NBL_ARTIFACTS") {
            candidates.push(PathBuf::from(env));
        }
        if let Ok(cwd) = std::env::current_dir() {
            let mut dir = cwd.as_path();
            loop {
                candidates.push(dir.join("artifacts"));
                match dir.parent() {
                    Some(p) => dir = p,
                    None => break,
                }
            }
        }
        for c in candidates {
            if c.join("manifest.json").exists() {
                return Artifacts::open(&c);
            }
        }
        Err(Error::Artifact(
            "artifacts/manifest.json not found — run `make artifacts` first \
             (or set NBL_ARTIFACTS)"
                .into(),
        ))
    }

    pub fn open(root: impl AsRef<Path>) -> Result<Artifacts> {
        let root = root.as_ref().to_path_buf();
        let manifest = Json::parse_file(root.join("manifest.json"))?;
        Ok(Artifacts { root, manifest })
    }

    /// Absolute path of an HLO op artifact by stem (e.g. "mlp_b1_t32").
    pub fn hlo_path(&self, op: &str) -> Result<PathBuf> {
        let rel = self.manifest.get("hlo")?.get(op).map_err(|_| {
            Error::Artifact(format!("op '{op}' not in the AOT grid (manifest.json)"))
        })?;
        let p = self.root.join(rel.as_str()?);
        if !p.exists() {
            return Err(Error::Artifact(format!("missing HLO file {}", p.display())));
        }
        Ok(p)
    }

    pub fn has_op(&self, op: &str) -> bool {
        self.manifest
            .get("hlo")
            .ok()
            .and_then(|h| h.opt(op))
            .is_some()
    }

    pub fn weights_paths(&self, model: &str) -> Result<(PathBuf, PathBuf)> {
        let w = self.manifest.get("weights")?.get(model).map_err(|_| {
            Error::Artifact(format!("unknown model '{model}'"))
        })?;
        Ok((
            self.root.join(w.get("bin")?.as_str()?),
            self.root.join(w.get("manifest")?.as_str()?),
        ))
    }

    pub fn corpus_path(&self, key: &str) -> Result<PathBuf> {
        let rel = self.manifest.get("corpora")?.get(key)?;
        Ok(self.root.join(rel.as_str()?))
    }

    pub fn goldens(&self) -> Result<Json> {
        Json::parse_file(self.root.join("goldens.json"))
    }

    pub fn model_names(&self) -> Result<Vec<String>> {
        Ok(self.manifest.get("weights")?.as_obj()?.keys().cloned().collect())
    }

    /// The AOT shape grid (for bucket selection in the executor).
    pub fn grid(&self) -> Result<Grid> {
        let g = self.manifest.get("grid")?;
        Ok(Grid {
            batches: g.get("batches")?.as_usize_vec()?,
            prefill_lens: g.get("prefill_lens")?.as_usize_vec()?,
            cached_lens: g.get("cached_lens")?.as_usize_vec()?,
            pointwise_lens: g.get("pointwise_lens")?.as_usize_vec()?,
            gram_n: g.get("gram_n")?.as_usize()?,
            gram_d: g.get("gram_d")?.as_usize()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct Grid {
    pub batches: Vec<usize>,
    pub prefill_lens: Vec<usize>,
    pub cached_lens: Vec<usize>,
    pub pointwise_lens: Vec<usize>,
    pub gram_n: usize,
    pub gram_d: usize,
}

impl Grid {
    /// Smallest bucket >= n, or None if n exceeds the grid.
    pub fn bucket(sorted: &[usize], n: usize) -> Option<usize> {
        sorted.iter().copied().filter(|&b| b >= n).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let lens = vec![32, 128, 512];
        assert_eq!(Grid::bucket(&lens, 1), Some(32));
        assert_eq!(Grid::bucket(&lens, 32), Some(32));
        assert_eq!(Grid::bucket(&lens, 33), Some(128));
        assert_eq!(Grid::bucket(&lens, 512), Some(512));
        assert_eq!(Grid::bucket(&lens, 513), None);
    }

    #[test]
    fn missing_artifacts_is_clear_error() {
        let e = Artifacts::open("/nonexistent/path").unwrap_err();
        assert!(e.to_string().contains("manifest.json") || e.to_string().contains("json"));
    }
}
