//! Shared experiment driver: builds every method's plan (NBL + all
//! baselines), measures accuracy and §4.1 speed, and emits paper-shaped
//! table rows. Used by every bench target and the `repro` CLI
//! (DESIGN.md §4 experiment index).

use std::sync::Arc;

use crate::baselines::slicegpt::{slicegpt_analytic_speedup, slicegpt_apply};
use crate::baselines::sleb::sleb_select;
use crate::data::corpus::{Corpus, CorpusId};
use crate::error::Result;
use crate::eval::harness::{evaluate_all, EvalSummary};
use crate::eval::perplexity;
use crate::eval::tasks::all_tasks;
use crate::executor::capture::CaptureSource;
use crate::executor::engine::Engine;
use crate::linalg::Mat;
use crate::model::artifacts::Artifacts;
use crate::nbl::calibrate::{CalibrationReport, Calibrator};
use crate::nbl::criteria::Criterion;
use crate::nbl::plan::{ModelPlan, PlanKind};
use crate::runtime::Runtime;
use crate::sampling::argmax;
use crate::util::timer::Timer;

/// Workload knobs; `fast()` keeps every bench under a couple of minutes.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub calib_seqs: usize,
    pub calib_len: usize,
    pub eval_items: usize,
    pub ppl_windows: usize,
    pub speed_prompt: usize,
    pub speed_gen: usize,
    pub speed_reps: usize,
    pub seed: u64,
}

impl ExpConfig {
    pub fn full() -> ExpConfig {
        ExpConfig {
            calib_seqs: 48,
            calib_len: 128,
            eval_items: 24,
            ppl_windows: 12,
            speed_prompt: 512,
            speed_gen: 128,
            speed_reps: 3,
            seed: 1234,
        }
    }

    pub fn fast() -> ExpConfig {
        ExpConfig {
            calib_seqs: 12,
            calib_len: 128,
            eval_items: 8,
            ppl_windows: 4,
            speed_prompt: 128,
            speed_gen: 32,
            speed_reps: 2,
            seed: 1234,
        }
    }

    pub fn from_env() -> ExpConfig {
        if std::env::var("NBL_FAST").is_ok() {
            ExpConfig::fast()
        } else {
            ExpConfig::full()
        }
    }
}

/// Everything a bench needs for one model.
pub struct Workbench {
    pub artifacts: Artifacts,
    pub runtime: Arc<Runtime>,
    pub engine: Engine,
    pub report: CalibrationReport,
    pub calib: Corpus,
    pub val: Corpus,
    pub cfg: ExpConfig,
}

impl Workbench {
    pub fn new(model: &str, cfg: ExpConfig) -> Result<Workbench> {
        // calibrate on the models' pretraining mix by default; the
        // single-corpus choice is the F.1 ablation (bench_ablations)
        Workbench::with_corpus(model, cfg, CorpusId::Mix)
    }

    pub fn with_corpus(model: &str, cfg: ExpConfig, calib_id: CorpusId) -> Result<Workbench> {
        let artifacts = Artifacts::discover()?;
        let runtime = Runtime::new(artifacts.clone())?;
        let engine = Engine::load(runtime.clone(), model)?;
        let calib = Corpus::load(&artifacts, calib_id, "train")?;
        let val = Corpus::load(&artifacts, calib_id, "val")?;
        let mut src = CaptureSource::new(&engine, &calib.tokens, cfg.calib_seqs, cfg.calib_len);
        let report = Calibrator::run(&mut src)?;
        Ok(Workbench { artifacts, runtime, engine, report, calib, val, cfg })
    }

    /// Mean residual-stream covariance across layers (SliceGPT input).
    pub fn stream_cov(&self) -> Mat {
        let d = self.engine.config().d_model;
        let mut acc = Mat::zeros(d, d);
        let mut n = 0usize;
        for lc in &self.report.layers {
            if lc.stats.n > 0 {
                acc = acc.add(&lc.stats.cxx);
                n += 1;
            }
        }
        acc.scale(1.0 / n.max(1) as f64)
    }

    /// Perplexity of a plan on the validation split.
    pub fn ppl(&self, plan: &ModelPlan) -> Result<f64> {
        let e = self.engine.with_plan(plan.clone())?;
        perplexity(&e, &self.val, self.cfg.ppl_windows, 128)
    }

    /// Full 8-task eval of an engine.
    pub fn accuracy(&self, engine: &Engine) -> Result<EvalSummary> {
        evaluate_all(engine, all_tasks(), self.cfg.eval_items, self.cfg.seed)
    }

    /// §4.1 protocol: prefill tok/s and median decode tok/s at batch 1.
    pub fn speed(&self, engine: &Engine) -> Result<SpeedResult> {
        measure_speed(
            engine,
            &self.calib.tokens,
            self.cfg.speed_prompt,
            self.cfg.speed_gen,
            self.cfg.speed_reps,
        )
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SpeedResult {
    pub prefill_tok_s: f64,
    pub decode_tok_s: f64,
}

/// Measure prefill/decode speed (batch 1, greedy), warm caches first.
pub fn measure_speed(
    engine: &Engine,
    token_stream: &[u32],
    prompt_len: usize,
    gen_len: usize,
    reps: usize,
) -> Result<SpeedResult> {
    let prompt = &token_stream[..prompt_len];
    // decode is timed from a shorter prompt so the cache has room for
    // gen_len tokens (paper protocol: prefill and generation are
    // measured as separate phases)
    let max_ctx = engine.config().max_ctx;
    let decode_prompt_len = prompt_len.min(max_ctx.saturating_sub(gen_len + 1)).max(1);
    let decode_prompt = &token_stream[..decode_prompt_len];

    // warm both compile cache and data paths
    let pre = engine.prefill(prompt, 1, prompt_len, None)?;
    drop(pre);
    let warm = engine.prefill(decode_prompt, 1, decode_prompt_len, None)?;
    let mut st = warm.state;
    let _ = engine.decode(&mut st, &[1], 1)?;

    // best-of-N timing: the testbed is a single shared vCPU with bursty
    // host-side contention, so the *minimum* time is the faithful cost of
    // the code path (documented in EXPERIMENTS.md §Methodology)
    let mut prefill_speeds = Vec::with_capacity(reps);
    let mut decode_speeds = Vec::with_capacity(reps);
    for _ in 0..reps.max(3) {
        let t = Timer::start();
        let pre = engine.prefill(prompt, 1, prompt_len, None)?;
        let logits = engine.head(&pre.hidden)?;
        let next = argmax(logits.at2(0, prompt_len - 1));
        let ttft = t.elapsed_s();
        prefill_speeds.push(prompt_len as f64 / ttft);
        drop(pre);
        let _ = next;

        let dpre = engine.prefill(decode_prompt, 1, decode_prompt_len, None)?;
        let dlogits = engine.head(&dpre.hidden)?;
        let mut next = argmax(dlogits.at2(0, decode_prompt_len - 1));
        let mut state = dpre.state;
        let mut intervals = Vec::with_capacity(gen_len);
        let gen = gen_len.min(state.remaining());
        for _ in 0..gen {
            let t2 = Timer::start();
            let l = engine.decode(&mut state, &[next], 1)?;
            next = argmax(l.at2(0, 0));
            intervals.push(t2.elapsed_s());
        }
        let per: Vec<f64> = intervals.iter().map(|&dt| 1.0 / dt.max(1e-12)).collect();
        decode_speeds.push(crate::util::median(&per));
    }
    let best = |xs: &[f64]| xs.iter().cloned().fold(0.0f64, f64::max);
    Ok(SpeedResult {
        prefill_tok_s: best(&prefill_speeds),
        decode_tok_s: best(&decode_speeds),
    })
}

/// A method row in the main tables.
pub struct MethodRow {
    pub plan: ModelPlan,
    /// Engine override (SliceGPT swaps weights, not just the plan).
    pub engine: Option<Engine>,
    /// Analytic speed-up override (SliceGPT: width-slicing not executable
    /// on the static-shape grid — DESIGN.md §2).
    pub analytic_speedup: Option<f64>,
}

/// Build the full method grid of Tables 2/3 for a workbench.
pub fn build_method_grid(wb: &Workbench, ms: &[usize]) -> Result<Vec<MethodRow>> {
    let n_layers = wb.engine.config().n_layers;
    let mut rows = Vec::new();
    rows.push(MethodRow {
        plan: ModelPlan::baseline(n_layers),
        engine: None,
        analytic_speedup: None,
    });

    // SliceGPT-{15,25,35}%
    let cov = wb.stream_cov();
    for pct in [15u32, 25, 35] {
        let sliced = slicegpt_apply(&wb.engine.weights, &cov, pct)?;
        let mut plan = ModelPlan::baseline(n_layers);
        plan.kind = PlanKind::SliceGpt(pct);
        let engine = Engine::new(wb.runtime.clone(), Arc::new(sliced), plan.clone())?;
        rows.push(MethodRow {
            plan,
            engine: Some(engine),
            analytic_speedup: Some(slicegpt_analytic_speedup(pct)),
        });
    }

    for &m in ms {
        if m >= n_layers {
            continue;
        }
        // SLEB-m (greedy ppl-based block removal)
        let sleb = sleb_select(n_layers, m, |p| wb.ppl(p))?;
        rows.push(MethodRow { plan: sleb, engine: None, analytic_speedup: None });

        // Block DROP-m (cosine criterion, per He et al.)
        let mut bd = ModelPlan::baseline(n_layers);
        bd.kind = PlanKind::BlockDrop(m);
        for idx in crate::nbl::criteria::select_lowest(
            &wb.report.scores(Criterion::CosineDistance),
            m,
        ) {
            bd.drop_block(idx);
        }
        rows.push(MethodRow { plan: bd, engine: None, analytic_speedup: None });

        // Block NBL-m (residual LMMSE over the whole block)
        let mut bn = ModelPlan::baseline(n_layers);
        bn.kind = PlanKind::BlockNbl(m);
        for idx in crate::nbl::criteria::select_lowest(
            &wb.report.scores(Criterion::CcaBound),
            m,
        ) {
            let lin = wb.report.layers[idx].fit_linear_residual()?;
            bn.linearize_block(idx, Arc::new(lin));
        }
        rows.push(MethodRow { plan: bn, engine: None, analytic_speedup: None });

        // Attn DROP-m (cosine criterion)
        let mut ad = wb.report.plan_attn_drop(m, Criterion::CosineDistance);
        ad.kind = PlanKind::AttnDrop(m);
        rows.push(MethodRow { plan: ad, engine: None, analytic_speedup: None });

        // Attn NBL-m (the paper's method, CCA criterion)
        let an = wb.report.plan_attn_nbl(m, Criterion::CcaBound)?;
        rows.push(MethodRow { plan: an, engine: None, analytic_speedup: None });
    }
    Ok(rows)
}

/// One fully-evaluated row of Table 2/3/4.
pub struct EvaluatedRow {
    pub label: String,
    pub summary: EvalSummary,
    pub prefill_ratio: f64,
    pub decode_ratio: f64,
    pub kv_fraction: f64,
}

/// Evaluate the full grid; the first row must be the baseline (ratios are
/// normalized to it, matching the paper's presentation).
pub fn evaluate_grid(wb: &Workbench, rows: &[MethodRow]) -> Result<Vec<EvaluatedRow>> {
    let mut out = Vec::with_capacity(rows.len());
    let mut base_speed: Option<SpeedResult> = None;
    for row in rows {
        let engine_storage;
        let engine: &Engine = match &row.engine {
            Some(e) => e,
            None => {
                engine_storage = wb.engine.with_plan(row.plan.clone())?;
                &engine_storage
            }
        };
        let summary = wb.accuracy(engine)?;
        let speed = wb.speed(engine)?;
        let base = *base_speed.get_or_insert(speed);
        let (prefill_ratio, decode_ratio) = match row.analytic_speedup {
            Some(s) => (s, s * 0.5 + 0.5), // SliceGPT: decode gains are smaller (paper T2/T3)
            None => (
                speed.prefill_tok_s / base.prefill_tok_s,
                speed.decode_tok_s / base.decode_tok_s,
            ),
        };
        log::info!(
            "{}: acc {:.3} prefill x{:.2} decode x{:.2}",
            row.plan.kind.label(),
            summary.avg_accuracy,
            prefill_ratio,
            decode_ratio
        );
        out.push(EvaluatedRow {
            label: row.plan.kind.label(),
            summary,
            prefill_ratio,
            decode_ratio,
            kv_fraction: row.plan.kv_fraction(),
        });
    }
    Ok(out)
}

/// Render evaluated rows as the paper's main-table layout.
pub fn main_table(title: &str, rows: &[EvaluatedRow]) -> crate::report::Table {
    let mut headers = vec!["Method"];
    for t in all_tasks() {
        headers.push(t.name);
    }
    headers.extend(["Avg", "PooledSE", "Prefill", "Throughput", "KV"]);
    let mut table = crate::report::Table::new(title, &headers);
    for r in rows {
        let mut cells = vec![r.label.clone()];
        for t in &r.summary.tasks {
            cells.push(crate::report::pct(t.accuracy));
        }
        cells.push(crate::report::pct(r.summary.avg_accuracy));
        cells.push(format!("{:.2}", r.summary.pooled_se * 100.0));
        cells.push(crate::report::ratio(r.prefill_ratio));
        cells.push(crate::report::ratio(r.decode_ratio));
        cells.push(format!("{:.2}", r.kv_fraction));
        table.row(cells);
    }
    table
}
