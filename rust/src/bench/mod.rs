//! Micro-benchmark harness (criterion is not available offline) plus the
//! shared experiment driver behind every paper table.
//!
//! Warmup + timed iterations with median/p10/p90 reporting, plus a
//! comparison helper for speed-up tables (every speed number in the
//! paper's tables is a ratio vs the repo's own baseline, matching the
//! paper's normalization).

pub mod experiments;

use crate::util::timer::Timer;
use crate::util::{mean, percentile};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchStats {
    pub fn speedup_vs(&self, baseline: &BenchStats) -> f64 {
        baseline.median_s / self.median_s.max(1e-12)
    }

    pub fn line(&self) -> String {
        format!(
            "{:<32} median {:>9.3} ms  (p10 {:>8.3}, p90 {:>8.3}, n={})",
            self.name,
            self.median_s * 1e3,
            self.p10_s * 1e3,
            self.p90_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` warmups.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
    }
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        median_s: percentile(&samples, 50.0),
        mean_s: mean(&samples),
        p10_s: percentile(&samples, 10.0),
        p90_s: percentile(&samples, 90.0),
    }
}

/// Adaptive: run for at least `min_time_s`, at least 3 iterations.
pub fn bench_for(name: &str, warmup: usize, min_time_s: f64, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < 3 || total.elapsed_s() < min_time_s {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
        if samples.len() > 10_000 {
            break;
        }
    }
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        median_s: percentile(&samples, 50.0),
        mean_s: mean(&samples),
        p10_s: percentile(&samples, 10.0),
        p90_s: percentile(&samples, 90.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 10);
        assert!(s.median_s >= 0.0);
        assert!(s.p10_s <= s.p90_s);
    }

    #[test]
    fn speedup_ratio() {
        let slow = BenchStats {
            name: "slow".into(),
            iters: 1,
            median_s: 0.2,
            mean_s: 0.2,
            p10_s: 0.2,
            p90_s: 0.2,
        };
        let fast = BenchStats { name: "fast".into(), median_s: 0.1, ..slow.clone() };
        assert!((fast.speedup_vs(&slow) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bench_for_respects_min_time() {
        let s = bench_for("sleepy", 0, 0.02, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(s.iters >= 3);
    }
}
