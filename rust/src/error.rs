//! Crate-wide error type.

/// Unified error for the whole coordinator.
#[derive(thiserror::Error, Debug)]
pub enum Error {
    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("shape error: {0}")]
    Shape(String),

    #[error("linalg error: {0}")]
    Linalg(String),

    #[error("calibration error: {0}")]
    Calibration(String),

    #[error("serving error: {0}")]
    Serving(String),

    /// The client walked away (explicit cancel frame or disconnect);
    /// typed so front ends and tests can match it without string
    /// comparison.
    #[error("request cancelled")]
    Cancelled,

    /// The request's submission-relative deadline passed before it
    /// finished — shed from the queue or preempted mid-decode.
    #[error("deadline exceeded")]
    DeadlineExceeded,

    #[error("config error: {0}")]
    Config(String),

    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
