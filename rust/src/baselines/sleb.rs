//! SLEB (Song et al. 2024): streamline LLMs by greedily removing the
//! transformer *block* whose removal hurts calibration perplexity least,
//! re-evaluating after every removal.

use crate::error::{Error, Result};
use crate::nbl::plan::{ModelPlan, PlanKind};

/// Greedily drop `m` whole blocks. `eval_ppl(plan)` must return the
/// calibration-set perplexity of the model under `plan`.
pub fn sleb_select(
    n_layers: usize,
    m: usize,
    mut eval_ppl: impl FnMut(&ModelPlan) -> Result<f64>,
) -> Result<ModelPlan> {
    if m > n_layers {
        return Err(Error::Calibration(format!(
            "SLEB: cannot drop {m} of {n_layers} blocks"
        )));
    }
    let mut plan = ModelPlan::baseline(n_layers);
    plan.kind = PlanKind::Sleb(m);
    let mut dropped: Vec<usize> = Vec::new();
    for _round in 0..m {
        let mut best: Option<(usize, f64)> = None;
        for cand in 0..n_layers {
            if dropped.contains(&cand) {
                continue;
            }
            let mut trial = plan.clone();
            trial.drop_block(cand);
            let ppl = eval_ppl(&trial)?;
            if best.map_or(true, |(_, b)| ppl < b) {
                best = Some((cand, ppl));
            }
        }
        let (idx, _) = best.ok_or_else(|| Error::Calibration("SLEB: nothing left".into()))?;
        plan.drop_block(idx);
        dropped.push(idx);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nbl::plan::MlpOp;

    #[test]
    fn drops_cheapest_blocks_first() {
        // synthetic: dropping layer i costs ppl penalty = i (layer 0 cheapest)
        let plan = sleb_select(5, 2, |p| {
            let mut ppl = 10.0;
            for (i, l) in p.layers.iter().enumerate() {
                if l.mlp == MlpOp::Identity {
                    ppl += i as f64;
                }
            }
            Ok(ppl)
        })
        .unwrap();
        assert_eq!(plan.kv_layers(), 3);
        assert_eq!(plan.layers[0].mlp, MlpOp::Identity);
        assert_eq!(plan.layers[1].mlp, MlpOp::Identity);
        assert_eq!(plan.kind.label(), "SLEB-2");
    }

    #[test]
    fn greedy_is_adaptive() {
        // interaction: dropping 2 is cheap only if 0 already dropped
        let plan = sleb_select(3, 2, |p| {
            let d: Vec<bool> = p.layers.iter().map(|l| l.mlp == MlpOp::Identity).collect();
            let mut ppl = 10.0;
            if d[0] {
                ppl += 0.1;
            }
            if d[1] {
                ppl += 5.0;
            }
            if d[2] {
                ppl += if d[0] { 0.2 } else { 3.0 };
            }
            Ok(ppl)
        })
        .unwrap();
        let d: Vec<bool> = plan.layers.iter().map(|l| l.mlp == MlpOp::Identity).collect();
        assert_eq!(d, vec![true, false, true]);
    }

    #[test]
    fn rejects_m_too_large() {
        assert!(sleb_select(2, 3, |_| Ok(1.0)).is_err());
    }

    #[test]
    fn propagates_eval_errors() {
        let r = sleb_select(2, 1, |_| Err(crate::error::Error::msg("boom")));
        assert!(r.is_err());
    }
}
