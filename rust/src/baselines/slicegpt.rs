//! SliceGPT-like baseline (Ashkboos et al. 2024).
//!
//! SliceGPT rotates the residual stream into its PCA basis and slices the
//! lowest-variance directions, shrinking every weight matrix. Our AOT
//! executables are shape-static, so we apply the *same* information
//! bottleneck re-embedded at full width: every inter-block weight is
//! replaced by its projection onto the top-k principal subspace
//! (W' = P Pᵀ W for inputs, W' = W P Pᵀ for outputs, with P ∈ R^{d×k}
//! from the residual-stream covariance). The accuracy effect of slicing
//! is fully exercised; the wall-clock speed-up is reported analytically
//! from the FLOP ratio (DESIGN.md §2, EXPERIMENTS.md notes this).

use crate::error::Result;
use crate::linalg::{eigh, Mat};
use crate::model::weights::Weights;
use crate::tensor::Tensor;

/// Build the rank-k residual-stream projector P Pᵀ from a stream
/// covariance estimate (d x d).
pub fn principal_projector(cov: &Mat, keep: usize) -> Result<Mat> {
    let r = eigh(cov)?;
    let d = cov.rows();
    let k = keep.min(d);
    // P = top-k eigenvector columns; projector = P Pᵀ
    let p = Mat::from_fn(d, k, |i, j| r.vectors[(i, j)]);
    Ok(p.matmul_nt(&p))
}

fn project_rows(proj: &Mat, w: &Tensor) -> Tensor {
    // rows of w live in residual space: w' = proj @ w
    let (d, cols) = (w.shape()[0], w.shape()[1]);
    let wm = Mat::from_f32(d, cols, w.data());
    let out = proj.matmul(&wm);
    Tensor::new(vec![d, cols], out.to_f32()).unwrap()
}

fn project_cols(proj: &Mat, w: &Tensor) -> Tensor {
    // columns of w produce residual-space vectors: w' = w @ proj
    let (rows, d) = (w.shape()[0], w.shape()[1]);
    let wm = Mat::from_f32(rows, d, w.data());
    let out = wm.matmul(proj);
    Tensor::new(vec![rows, d], out.to_f32()).unwrap()
}

/// Apply SliceGPT-style slicing at `percent`% sparsity to a copy of the
/// weights. `stream_cov` is the residual-stream covariance from
/// calibration (averaged over layers).
pub fn slicegpt_apply(weights: &Weights, stream_cov: &Mat, percent: u32) -> Result<Weights> {
    let d = weights.config.d_model;
    let keep = ((d as f64) * (1.0 - percent as f64 / 100.0)).round() as usize;
    let proj = principal_projector(stream_cov, keep.max(1))?;
    let mut out = weights.clone();
    for l in out.layers.iter_mut() {
        // inputs read from the residual stream
        l.wq = project_rows(&proj, &l.wq);
        l.wk = project_rows(&proj, &l.wk);
        l.wv = project_rows(&proj, &l.wv);
        l.w1 = project_rows(&proj, &l.w1);
        l.w3 = project_rows(&proj, &l.w3);
        // outputs write into the residual stream
        l.wo = project_cols(&proj, &l.wo);
        l.w2 = project_cols(&proj, &l.w2);
    }
    out.w_head = project_rows(&proj, &out.w_head);
    Ok(out)
}

/// Analytic speed-up of true width-slicing at `percent`% (FLOP ratio of
/// the dominant d-dependent matmuls; the paper's Table 2/3 prefill column
/// analogue for this baseline).
pub fn slicegpt_analytic_speedup(percent: u32) -> f64 {
    let keep = 1.0 - percent as f64 / 100.0;
    // linear layers scale ~ d_kept (one side of each GEMM is sliced)
    1.0 / keep.max(0.01)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_cov(d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::from_fn(d, d, |_, _| rng.normal());
        let mut c = a.matmul_nt(&a);
        for i in 0..d {
            c[(i, i)] += 0.1;
        }
        c
    }

    #[test]
    fn projector_is_idempotent_and_rank_k() {
        let cov = random_cov(8, 1);
        let p = principal_projector(&cov, 3).unwrap();
        // idempotent
        assert!(p.matmul(&p).sub(&p).max_abs() < 1e-8);
        // trace == rank
        assert!((p.trace() - 3.0).abs() < 1e-8);
    }

    #[test]
    fn full_rank_projector_is_identity() {
        let cov = random_cov(6, 2);
        let p = principal_projector(&cov, 6).unwrap();
        assert!(p.sub(&Mat::identity(6)).max_abs() < 1e-8);
    }

    #[test]
    fn projector_keeps_top_directions() {
        // diagonal covariance: projector must keep the largest-variance axes
        let mut cov = Mat::zeros(4, 4);
        for (i, v) in [0.1, 5.0, 0.2, 3.0].iter().enumerate() {
            cov[(i, i)] = *v;
        }
        let p = principal_projector(&cov, 2).unwrap();
        assert!((p[(1, 1)] - 1.0).abs() < 1e-9);
        assert!((p[(3, 3)] - 1.0).abs() < 1e-9);
        assert!(p[(0, 0)].abs() < 1e-9);
        assert!(p[(2, 2)].abs() < 1e-9);
    }

    #[test]
    fn analytic_speedup_monotone() {
        assert!(slicegpt_analytic_speedup(35) > slicegpt_analytic_speedup(25));
        assert!(slicegpt_analytic_speedup(25) > slicegpt_analytic_speedup(15));
        assert!(slicegpt_analytic_speedup(15) > 1.0);
    }
}
