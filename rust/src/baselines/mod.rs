//! Baseline compression methods the paper compares against.
//!
//! - Attn/Block DROP (He et al. 2024) — plans built directly from
//!   `CalibrationReport` with the cosine criterion (see `nbl::calibrate`).
//! - SLEB (Song et al. 2024) — greedy perplexity-driven block removal.
//! - SliceGPT (Ashkboos et al. 2024) — PCA rotation + width slicing,
//!   re-embedded at full width (DESIGN.md §2 documents the substitution).

pub mod slicegpt;
pub mod sleb;

pub use slicegpt::slicegpt_apply;
pub use sleb::sleb_select;
