//! # nbl — Neural Block Linearization
//!
//! Production-shaped reproduction of *Efficient Large Language Model
//! Inference with Neural Block Linearization* (Erdogan, Tonin, Cevher,
//! 2025). NBL replaces self-attention blocks of a pre-trained transformer
//! with closed-form linear layers fitted by the LMMSE estimator on
//! calibration activations, selecting layers via a CCA-derived bound on
//! the linearization NMSE (paper Thm. 3.2). No fine-tuning involved.
//!
//! The crate is the L3 coordinator of a three-layer stack (see DESIGN.md):
//! JAX/Pallas author the compute graph at build time, this crate loads the
//! AOT-lowered HLO artifacts through the PJRT C API and owns everything at
//! run time: calibration, substitution planning, KV-cache management,
//! batching, serving, evaluation and the benchmark harness.
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! ```ignore
//! let engine = Engine::load(&Artifacts::discover()?, "main")?;
//! let plan = nbl::calibrate(&engine, &calib_set)?.plan_attn_nbl(2);
//! let engine = engine.with_plan(plan);
//! let out = engine.generate(&prompt_ids, 64, &SamplingParams::greedy())?;
//! ```

// Unsafe is denied crate-wide; the only sanctioned sites are the
// Send/Sync impls over PJRT handles (each carries #[allow(unsafe_code)]
// plus a SAFETY note, and nbl-lint's `unsafe` pass audits the set).
#![deny(unsafe_code)]

pub mod baselines;
pub mod bench;
pub mod data;
pub mod error;
pub mod eval;
pub mod executor;
pub mod kvcache;
pub mod linalg;
pub mod model;
pub mod nbl;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod spec;
pub mod stats;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
