//! Request/response types + line-JSON wire codec.

use crate::error::{Error, Result};
use crate::sampling::SamplingParams;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
}

impl GenRequest {
    /// Parse the wire form: {"id":1,"prompt":"text","max_tokens":32,
    /// "temperature":0.0,"top_k":0}  (prompt_ids may replace prompt).
    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let id = j.get("id")?.as_usize()? as u64;
        let prompt = if let Some(text) = j.opt("prompt") {
            crate::data::tokenizer::ByteTokenizer::new().encode(text.as_str()?)
        } else if let Some(ids) = j.opt("prompt_ids") {
            ids.as_usize_vec()?.iter().map(|&x| x as u32).collect()
        } else {
            return Err(Error::Serving("need prompt or prompt_ids".into()));
        };
        if prompt.is_empty() {
            return Err(Error::Serving("empty prompt".into()));
        }
        let max_new_tokens = match j.opt("max_tokens") {
            Some(v) => v.as_usize()?,
            None => 32,
        };
        let temperature = match j.opt("temperature") {
            Some(v) => v.as_f64()?,
            None => 0.0,
        };
        let top_k = match j.opt("top_k") {
            Some(v) => v.as_usize()?,
            None => 0,
        };
        let seed = match j.opt("seed") {
            Some(v) => v.as_usize()? as u64,
            None => id,
        };
        Ok(GenRequest {
            id,
            prompt,
            max_new_tokens,
            params: SamplingParams { temperature, top_k, seed },
        })
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub error: Option<String>,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            (
                "tokens",
                Json::arr_f64(self.tokens.iter().map(|&t| t as f64)),
            ),
            ("ttft_ms", Json::Num(self.ttft_ms)),
            ("total_ms", Json::Num(self.total_ms)),
        ]);
        if let Some(e) = &self.error {
            j.set("error", Json::Str(e.clone()));
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trip() {
        let j = Json::parse(
            r#"{"id": 7, "prompt": "abc", "max_tokens": 5, "temperature": 0.8, "top_k": 3}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![97, 98, 99]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.params.top_k, 3);
    }

    #[test]
    fn prompt_ids_accepted() {
        let j = Json::parse(r#"{"id": 1, "prompt_ids": [10, 20]}"#).unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.prompt, vec![10, 20]);
        assert_eq!(r.max_new_tokens, 32);
    }

    #[test]
    fn rejects_empty() {
        assert!(GenRequest::from_json(&Json::parse(r#"{"id":1,"prompt":""}"#).unwrap()).is_err());
        assert!(GenRequest::from_json(&Json::parse(r#"{"id":1}"#).unwrap()).is_err());
    }

    #[test]
    fn response_serializes() {
        let r = GenResponse {
            id: 3,
            tokens: vec![1, 2],
            text: "ab".into(),
            ttft_ms: 1.5,
            total_ms: 10.0,
            error: None,
        };
        let s = r.to_json().to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 3);
        assert!(back.opt("error").is_none());
    }
}
