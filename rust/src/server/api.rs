//! Request/response types + line-JSON wire codec, including the stats
//! endpoint ({"stats": true} on the TCP line protocol).

use crate::error::{Error, Result};
use crate::sampling::SamplingParams;
use crate::server::metrics::{MetricsSummary, SchedulerGauges};
use crate::server::trace::TraceStats;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// fairness tag: requests sharing a tenant share one DRR queue
    /// ("" = default tenant)
    pub tenant: String,
    /// deficit-round-robin weight (quantum multiplier), clamped >= 1
    pub weight: u64,
    /// wall-clock budget measured from submit; None = no deadline
    pub deadline_ms: Option<u64>,
    /// opt-in per-token JSONL frames instead of a one-shot reply
    pub stream: bool,
}

impl GenRequest {
    /// Parse the wire form: {"id":1,"prompt":"text","max_tokens":32,
    /// "temperature":0.0,"top_k":0}  (prompt_ids may replace prompt).
    /// Optional serving fields: "tenant" (fair-queue tag), "weight"
    /// (DRR quantum multiplier, >= 1), "deadline_ms", "stream".
    pub fn from_json(j: &Json) -> Result<GenRequest> {
        let id = j.get("id")?.as_usize()? as u64;
        let prompt = if let Some(text) = j.opt("prompt") {
            crate::data::tokenizer::ByteTokenizer::new().encode(text.as_str()?)
        } else if let Some(ids) = j.opt("prompt_ids") {
            ids.as_usize_vec()?.iter().map(|&x| x as u32).collect()
        } else {
            return Err(Error::Serving("need prompt or prompt_ids".into()));
        };
        if prompt.is_empty() {
            return Err(Error::Serving("empty prompt".into()));
        }
        let max_new_tokens = match j.opt("max_tokens") {
            Some(v) => v.as_usize()?,
            None => 32,
        };
        let temperature = match j.opt("temperature") {
            Some(v) => v.as_f64()?,
            None => 0.0,
        };
        let top_k = match j.opt("top_k") {
            Some(v) => v.as_usize()?,
            None => 0,
        };
        let seed = match j.opt("seed") {
            Some(v) => v.as_usize()? as u64,
            None => id,
        };
        let tenant = match j.opt("tenant") {
            Some(v) => v.as_str()?.to_string(),
            None => String::new(),
        };
        let weight = match j.opt("weight") {
            Some(v) => (v.as_usize()? as u64).max(1),
            None => 1,
        };
        let deadline_ms = match j.opt("deadline_ms") {
            Some(v) => Some(v.as_usize()? as u64),
            None => None,
        };
        let stream = j.opt("stream").and_then(|v| v.as_bool().ok()).unwrap_or(false);
        Ok(GenRequest {
            id,
            prompt,
            max_new_tokens,
            params: SamplingParams { temperature, top_k, seed },
            tenant,
            weight,
            deadline_ms,
            stream,
        })
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub error: Option<String>,
}

impl GenResponse {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            (
                "tokens",
                Json::arr_f64(self.tokens.iter().map(|&t| t as f64)),
            ),
            ("ttft_ms", Json::Num(self.ttft_ms)),
            ("total_ms", Json::Num(self.total_ms)),
        ]);
        if let Some(e) = &self.error {
            j.set("error", Json::Str(e.clone()));
        }
        j
    }
}

/// True if a wire line is a stats query ({"stats": true}) rather than a
/// generation request.
pub fn is_stats_request(j: &Json) -> bool {
    j.opt("stats")
        .and_then(|v| v.as_bool().ok())
        .unwrap_or(false)
}

/// True if a wire line is a flight-recorder export query
/// ({"trace": true}): the reply is a Chrome-trace JSON object built
/// from the ring's current contents.
pub fn is_trace_request(j: &Json) -> bool {
    j.opt("trace")
        .and_then(|v| v.as_bool().ok())
        .unwrap_or(false)
}

/// Parse a cancellation frame ({"cancel": <id>}); returns the id of the
/// request the client wants aborted, or None for any other line.
pub fn cancel_request_id(j: &Json) -> Option<u64> {
    j.opt("cancel").and_then(|v| v.as_usize().ok()).map(|id| id as u64)
}

/// One streamed token, emitted as its own JSONL line when the request
/// opted in with {"stream":true}. `index` is 0-based and strictly
/// increasing per request — ci/check_stream.py enforces monotonicity.
pub fn token_frame(id: u64, index: usize, token: u32, text: &str) -> Json {
    Json::obj(vec![
        ("frame", Json::Str("token".into())),
        ("id", Json::Num(id as f64)),
        ("index", Json::Num(index as f64)),
        ("token", Json::Num(token as f64)),
        ("text", Json::Str(text.into())),
    ])
}

/// One committed token forwarded on a streaming request's sink channel
/// (service -> front end). The front end renders it as a
/// [`token_frame`] line; `index` is the position in the request's
/// output sequence, so concatenating sink tokens in order reproduces
/// the one-shot reply exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamToken {
    pub id: u64,
    pub index: usize,
    pub token: u32,
}

/// Terminal frame of a streamed request: the full one-shot response body
/// tagged "done" (success) or "error" (typed failure, including
/// "cancelled" and "deadline exceeded"). Exactly one terminal frame is
/// emitted per streamed request.
pub fn terminal_frame(resp: &GenResponse) -> Json {
    let mut j = resp.to_json();
    let tag = if resp.error.is_some() { "error" } else { "done" };
    j.set("frame", Json::Str(tag.into()));
    j
}

/// Wire form of the stats endpoint: request/latency summary plus the
/// scheduler's continuous-batching gauges (queue depth, per-iteration
/// batch occupancy, KV-pool utilization). `kv_in_use`/`kv_capacity` are
/// sampled live from the pool so idle servers still report truthfully.
pub fn stats_to_json(
    s: &MetricsSummary,
    g: &SchedulerGauges,
    kv_in_use: usize,
    kv_capacity: usize,
    t: &TraceStats,
) -> Json {
    let kv_util = if kv_capacity == 0 {
        0.0
    } else {
        kv_in_use as f64 / kv_capacity as f64
    };
    Json::obj(vec![
        ("requests", Json::Num(s.requests as f64)),
        ("generated_tokens", Json::Num(s.generated_tokens as f64)),
        ("mean_ttft_ms", Json::Num(s.mean_ttft_s * 1e3)),
        ("p90_ttft_ms", Json::Num(s.p90_ttft_s * 1e3)),
        ("p50_ttft_ms", Json::Num(s.p50_ttft_s * 1e3)),
        ("p95_ttft_ms", Json::Num(s.p95_ttft_s * 1e3)),
        ("p99_ttft_ms", Json::Num(s.p99_ttft_s * 1e3)),
        ("p50_itl_ms", Json::Num(s.p50_itl_s * 1e3)),
        ("p95_itl_ms", Json::Num(s.p95_itl_s * 1e3)),
        ("p99_itl_ms", Json::Num(s.p99_itl_s * 1e3)),
        // TTFT attribution (queue + prefill + stall == ttft per request;
        // park is lifetime parking, outside the identity)
        ("mean_queue_ms", Json::Num(s.mean_queue_s * 1e3)),
        ("p50_queue_ms", Json::Num(s.p50_queue_s * 1e3)),
        ("p95_queue_ms", Json::Num(s.p95_queue_s * 1e3)),
        ("p99_queue_ms", Json::Num(s.p99_queue_s * 1e3)),
        ("mean_prefill_ms", Json::Num(s.mean_prefill_s * 1e3)),
        ("p50_prefill_ms", Json::Num(s.p50_prefill_s * 1e3)),
        ("p95_prefill_ms", Json::Num(s.p95_prefill_s * 1e3)),
        ("p99_prefill_ms", Json::Num(s.p99_prefill_s * 1e3)),
        ("mean_stall_ms", Json::Num(s.mean_stall_s * 1e3)),
        ("p50_stall_ms", Json::Num(s.p50_stall_s * 1e3)),
        ("p95_stall_ms", Json::Num(s.p95_stall_s * 1e3)),
        ("p99_stall_ms", Json::Num(s.p99_stall_s * 1e3)),
        ("mean_park_ms", Json::Num(s.mean_park_s * 1e3)),
        ("p50_park_ms", Json::Num(s.p50_park_s * 1e3)),
        ("p95_park_ms", Json::Num(s.p95_park_s * 1e3)),
        ("p99_park_ms", Json::Num(s.p99_park_s * 1e3)),
        ("timings_retained", Json::Num(s.timings_retained as f64)),
        ("timings_dropped", Json::Num(s.timings_dropped as f64)),
        ("timings_capacity", Json::Num(s.timings_capacity as f64)),
        ("trace_events", Json::Num(t.recorded as f64)),
        ("trace_dropped", Json::Num(t.dropped as f64)),
        ("trace_capacity", Json::Num(t.capacity as f64)),
        ("phase_intake_ms", Json::Num(g.phase_intake_s * 1e3)),
        ("phase_admission_ms", Json::Num(g.phase_admission_s * 1e3)),
        ("phase_chunked_ms", Json::Num(g.phase_chunked_s * 1e3)),
        ("phase_observe_ms", Json::Num(g.phase_observe_s * 1e3)),
        ("phase_decode_ms", Json::Num(g.phase_decode_s * 1e3)),
        ("mean_prefill_tok_s", Json::Num(s.mean_prefill_tok_s)),
        ("median_decode_tok_s", Json::Num(s.median_decode_tok_s)),
        ("aggregate_tok_s", Json::Num(s.aggregate_tok_s)),
        // SLO summary: goodput counts only tokens whose request met its
        // deadline; attainment is met / (met + missed + expired + shed)
        // over requests that carried a deadline (1.0 when none did)
        ("goodput_tok_s", Json::Num(s.goodput_tok_s)),
        ("slo_attainment", Json::Num(s.slo_attainment)),
        // gauge lanes contributing to this rollup (1 = single worker,
        // N = data-parallel replicas; DESIGN.md §Data parallelism)
        ("replicas", Json::Num(g.replicas as f64)),
        ("queue_depth", Json::Num(g.queue_depth as f64)),
        ("iterations", Json::Num(g.iterations as f64)),
        ("mean_batch_occupancy", Json::Num(g.mean_occupancy())),
        ("mean_rows_per_iteration", Json::Num(g.mean_rows_per_iteration())),
        ("admissions", Json::Num(g.admissions as f64)),
        ("slot_reuses", Json::Num(g.slot_reuses as f64)),
        // front-end lifecycle counters: client-aborted, deadline-expired
        // mid-flight, and shed-from-queue requests; tenants_active is
        // the number of tenants with queued or running work
        ("cancelled", Json::Num(g.cancelled as f64)),
        ("expired", Json::Num(g.expired as f64)),
        ("shed", Json::Num(g.shed as f64)),
        ("tenants_active", Json::Num(g.tenants_active as f64)),
        ("committed_tokens", Json::Num(g.committed_tokens as f64)),
        ("prefill_chunks", Json::Num(g.prefill_chunks as f64)),
        ("chunked_admissions", Json::Num(g.chunked_admissions as f64)),
        ("chunk_stalls", Json::Num(g.chunk_stalls as f64)),
        ("chunk_stall_ms_total", Json::Num(g.chunk_stall_s * 1e3)),
        ("chunk_stall_ms_mean", Json::Num(g.mean_chunk_stall_ms())),
        ("spec_rounds", Json::Num(g.spec_rounds as f64)),
        ("spec_proposed", Json::Num(g.spec_proposed as f64)),
        ("spec_accepted", Json::Num(g.spec_accepted as f64)),
        ("spec_acceptance_rate", Json::Num(g.acceptance_rate())),
        ("tokens_per_row_iteration", Json::Num(g.tokens_per_row_iteration())),
        ("prefix_hits", Json::Num(g.prefix_hits as f64)),
        ("prefix_misses", Json::Num(g.prefix_misses as f64)),
        ("prefix_hit_rate", Json::Num(g.prefix_hit_rate())),
        ("prefix_hit_tokens", Json::Num(g.prefix_hit_tokens as f64)),
        ("prefix_inserts", Json::Num(g.prefix_inserts as f64)),
        ("prefix_evictions", Json::Num(g.prefix_evictions as f64)),
        ("prefix_entries", Json::Num(g.prefix_entries as f64)),
        ("prefix_bytes", Json::Num(g.prefix_bytes as f64)),
        ("prefix_capacity_bytes", Json::Num(g.prefix_capacity_bytes as f64)),
        ("prefix_publish_skips", Json::Num(g.prefix_publish_skips as f64)),
        ("prefix_expand_copies", Json::Num(g.prefix_expand_copies as f64)),
        ("peak_rows", Json::Num(g.peak_rows as f64)),
        ("paged_block_tokens", Json::Num(g.paged_block_tokens as f64)),
        ("blocks_capacity", Json::Num(g.blocks_capacity as f64)),
        ("blocks_free", Json::Num(g.blocks_free as f64)),
        ("blocks_used", Json::Num(g.blocks_used as f64)),
        ("blocks_shared", Json::Num(g.blocks_shared as f64)),
        ("blocks_live_tokens", Json::Num(g.blocks_live_tokens as f64)),
        ("cow_copies", Json::Num(g.cow_copies as f64)),
        ("preemptions", Json::Num(g.preemptions as f64)),
        ("paged_splices", Json::Num(g.paged_splices as f64)),
        ("paged_splice_tokens", Json::Num(g.paged_splice_tokens as f64)),
        ("paged_fragmentation", Json::Num(g.paged_fragmentation())),
        ("kv_in_use_bytes", Json::Num(kv_in_use as f64)),
        ("kv_capacity_bytes", Json::Num(kv_capacity as f64)),
        ("kv_utilization", Json::Num(kv_util)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trip() {
        let j = Json::parse(
            r#"{"id": 7, "prompt": "abc", "max_tokens": 5, "temperature": 0.8, "top_k": 3}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![97, 98, 99]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.params.top_k, 3);
        // serving fields default to: anonymous tenant, weight 1, no
        // deadline, one-shot reply
        assert_eq!(r.tenant, "");
        assert_eq!(r.weight, 1);
        assert_eq!(r.deadline_ms, None);
        assert!(!r.stream);
    }

    #[test]
    fn serving_fields_parsed_and_weight_clamped() {
        let j = Json::parse(
            r#"{"id": 2, "prompt": "x", "tenant": "bulk", "weight": 4,
                "deadline_ms": 250, "stream": true}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.tenant, "bulk");
        assert_eq!(r.weight, 4);
        assert_eq!(r.deadline_ms, Some(250));
        assert!(r.stream);
        // weight 0 would stall its DRR queue forever — clamp to 1
        let j = Json::parse(r#"{"id": 3, "prompt": "x", "weight": 0}"#).unwrap();
        assert_eq!(GenRequest::from_json(&j).unwrap().weight, 1);
    }

    #[test]
    fn cancel_frame_parsed() {
        assert_eq!(
            cancel_request_id(&Json::parse(r#"{"cancel": 42}"#).unwrap()),
            Some(42)
        );
        assert_eq!(
            cancel_request_id(&Json::parse(r#"{"id": 1, "prompt": "x"}"#).unwrap()),
            None
        );
        assert_eq!(cancel_request_id(&Json::parse(r#"{"stats": true}"#).unwrap()), None);
    }

    #[test]
    fn stream_frames_serialize() {
        let f = token_frame(5, 2, 97, "a");
        let back = Json::parse(&f.to_string()).unwrap();
        assert_eq!(back.get("frame").unwrap().as_str().unwrap(), "token");
        assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 5);
        assert_eq!(back.get("index").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("token").unwrap().as_usize().unwrap(), 97);
        assert_eq!(back.get("text").unwrap().as_str().unwrap(), "a");

        let ok = GenResponse {
            id: 5,
            tokens: vec![97],
            text: "a".into(),
            ttft_ms: 1.0,
            total_ms: 2.0,
            error: None,
        };
        let t = Json::parse(&terminal_frame(&ok).to_string()).unwrap();
        assert_eq!(t.get("frame").unwrap().as_str().unwrap(), "done");
        assert!(t.opt("error").is_none());

        let err = GenResponse { error: Some("cancelled".into()), ..ok };
        let t = Json::parse(&terminal_frame(&err).to_string()).unwrap();
        assert_eq!(t.get("frame").unwrap().as_str().unwrap(), "error");
        assert_eq!(t.get("error").unwrap().as_str().unwrap(), "cancelled");
    }

    #[test]
    fn prompt_ids_accepted() {
        let j = Json::parse(r#"{"id": 1, "prompt_ids": [10, 20]}"#).unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.prompt, vec![10, 20]);
        assert_eq!(r.max_new_tokens, 32);
    }

    #[test]
    fn rejects_empty() {
        assert!(GenRequest::from_json(&Json::parse(r#"{"id":1,"prompt":""}"#).unwrap()).is_err());
        assert!(GenRequest::from_json(&Json::parse(r#"{"id":1}"#).unwrap()).is_err());
    }

    #[test]
    fn stats_request_detected() {
        assert!(is_stats_request(&Json::parse(r#"{"stats": true}"#).unwrap()));
        assert!(!is_stats_request(
            &Json::parse(r#"{"stats": false}"#).unwrap()
        ));
        assert!(!is_stats_request(
            &Json::parse(r#"{"id": 1, "prompt": "x"}"#).unwrap()
        ));
    }

    #[test]
    fn stats_serialize_gauges() {
        let s = MetricsSummary {
            requests: 4,
            generated_tokens: 40,
            mean_ttft_s: 0.01,
            p90_ttft_s: 0.02,
            mean_prefill_tok_s: 1000.0,
            median_decode_tok_s: 100.0,
            aggregate_tok_s: 50.0,
            p50_ttft_s: 0.009,
            p95_ttft_s: 0.021,
            p99_ttft_s: 0.022,
            p50_itl_s: 0.004,
            p95_itl_s: 0.006,
            p99_itl_s: 0.007,
            mean_queue_s: 0.002,
            p95_queue_s: 0.003,
            mean_prefill_s: 0.006,
            mean_stall_s: 0.002,
            mean_park_s: 0.001,
            timings_retained: 4,
            timings_dropped: 0,
            timings_capacity: 4096,
            goodput_tok_s: 45.0,
            slo_attainment: 0.9,
            ..Default::default()
        };
        let g = SchedulerGauges {
            iterations: 10,
            occupied_rows: 30,
            bucket_rows: 80,
            admissions: 6,
            slot_reuses: 2,
            queue_depth: 1,
            kv_in_use: 0,
            kv_capacity: 0,
            committed_tokens: 60,
            spec_rounds: 10,
            spec_proposed: 40,
            spec_accepted: 30,
            prefill_chunks: 9,
            chunked_admissions: 2,
            chunk_stalls: 5,
            chunk_stall_s: 0.05,
            prefix_hits: 3,
            prefix_misses: 1,
            prefix_hit_tokens: 192,
            prefix_inserts: 4,
            prefix_evictions: 1,
            prefix_entries: 3,
            prefix_bytes: 2048,
            prefix_capacity_bytes: 4096,
            paged_block_tokens: 64,
            blocks_capacity: 16,
            blocks_free: 10,
            blocks_used: 6,
            blocks_shared: 2,
            blocks_live_tokens: 320,
            cow_copies: 1,
            preemptions: 2,
            paged_splices: 3,
            paged_splice_tokens: 256,
            phase_intake_s: 0.5,
            phase_decode_s: 1.5,
            cancelled: 3,
            expired: 1,
            shed: 2,
            tenants_active: 2,
            ..Default::default()
        };
        let t = TraceStats { capacity: 1024, recorded: 200, dropped: 8 };
        let j = stats_to_json(&s, &g, 512, 1024, &t);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("requests").unwrap().as_usize().unwrap(), 4);
        assert_eq!(back.get("queue_depth").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("slot_reuses").unwrap().as_usize().unwrap(), 2);
        assert!((back.get("mean_batch_occupancy").unwrap().as_f64().unwrap() - 0.375).abs() < 1e-9);
        assert!((back.get("kv_utilization").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
        assert_eq!(back.get("spec_rounds").unwrap().as_usize().unwrap(), 10);
        assert_eq!(back.get("prefill_chunks").unwrap().as_usize().unwrap(), 9);
        assert_eq!(back.get("chunked_admissions").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("chunk_stalls").unwrap().as_usize().unwrap(), 5);
        assert!((back.get("chunk_stall_ms_mean").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
        assert!((back.get("spec_acceptance_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
        let tpi = back.get("tokens_per_row_iteration").unwrap().as_f64().unwrap();
        assert!((tpi - 2.0).abs() < 1e-9);
        assert_eq!(back.get("prefix_hits").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("prefix_hit_tokens").unwrap().as_usize().unwrap(), 192);
        assert_eq!(back.get("prefix_entries").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("prefix_bytes").unwrap().as_usize().unwrap(), 2048);
        assert!((back.get("prefix_hit_rate").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
        assert!((back.get("p95_ttft_ms").unwrap().as_f64().unwrap() - 21.0).abs() < 1e-9);
        assert!((back.get("p50_itl_ms").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
        assert_eq!(back.get("paged_block_tokens").unwrap().as_usize().unwrap(), 64);
        assert_eq!(back.get("blocks_free").unwrap().as_usize().unwrap(), 10);
        assert_eq!(back.get("blocks_shared").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("cow_copies").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("preemptions").unwrap().as_usize().unwrap(), 2);
        // 320 live of 8 frames * 64 tokens -> 0.375 slack
        let frag = back.get("paged_fragmentation").unwrap().as_f64().unwrap();
        assert!((frag - 0.375).abs() < 1e-9);
        // TTFT attribution, retention, phase, and trace keys
        assert!((back.get("mean_queue_ms").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert!((back.get("p95_queue_ms").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
        assert!((back.get("mean_prefill_ms").unwrap().as_f64().unwrap() - 6.0).abs() < 1e-9);
        assert!((back.get("mean_stall_ms").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
        assert!((back.get("mean_park_ms").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert_eq!(back.get("timings_retained").unwrap().as_usize().unwrap(), 4);
        assert_eq!(back.get("timings_capacity").unwrap().as_usize().unwrap(), 4096);
        assert_eq!(back.get("trace_events").unwrap().as_usize().unwrap(), 200);
        assert_eq!(back.get("trace_dropped").unwrap().as_usize().unwrap(), 8);
        assert_eq!(back.get("trace_capacity").unwrap().as_usize().unwrap(), 1024);
        assert!((back.get("phase_intake_ms").unwrap().as_f64().unwrap() - 500.0).abs() < 1e-9);
        assert!((back.get("phase_decode_ms").unwrap().as_f64().unwrap() - 1500.0).abs() < 1e-9);
        // front-end lifecycle + SLO keys
        assert_eq!(back.get("cancelled").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("expired").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("shed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(back.get("tenants_active").unwrap().as_usize().unwrap(), 2);
        assert!((back.get("goodput_tok_s").unwrap().as_f64().unwrap() - 45.0).abs() < 1e-9);
        assert!((back.get("slo_attainment").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn trace_request_detected() {
        assert!(is_trace_request(&Json::parse(r#"{"trace": true}"#).unwrap()));
        assert!(!is_trace_request(&Json::parse(r#"{"trace": false}"#).unwrap()));
        assert!(!is_trace_request(&Json::parse(r#"{"stats": true}"#).unwrap()));
    }

    #[test]
    fn response_serializes() {
        let r = GenResponse {
            id: 3,
            tokens: vec![1, 2],
            text: "ab".into(),
            ttft_ms: 1.5,
            total_ms: 10.0,
            error: None,
        };
        let s = r.to_json().to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 3);
        assert!(back.opt("error").is_none());
    }
}
