//! Data-parallel dispatch (DESIGN.md §Data parallelism): N replicated
//! continuous-batching loops over the SAME `Arc`-shared engine weights,
//! behind one submission channel. Each replica owns its own iteration
//! loop, target+draft slot arenas, paged block accounting, prefix
//! cache slice, gauge lane, and trace tid; all replicas charge the one
//! shared `KvPool` byte ceiling.
//!
//! Routing is prefix-affinity with join-shortest-queue fallback
//! ([`pick`]): a stat-free `PrefixCache::covered` peek per replica
//! finds the longest cached match for the incoming prompt, and
//! shared-prefix traffic lands on the replica that already holds the
//! prefix (ties broken toward the shortest queue). Prompts no replica
//! has seen go join-shortest-queue on the dispatcher-visible inflight
//! counts. A replica with every slot taken is never chosen while an
//! open one exists.
//!
//! Each replica also gets a *host lane* ([`HostLane`]): a thread that
//! drains deferred host-side work — terminal response sends, streaming
//! frame emission, prefix-cache snapshot publication — so the work for
//! iteration k overlaps the device compute of iteration k+1. The
//! handoff is sequence-numbered (submitted vs processed counters); the
//! worker quiesces the lane before probing its prefix cache so it
//! always reads its own writes, and all of one request's frames and
//! its terminal answer ride the same FIFO lane, which keeps the
//! cancellation/deadline ordering of PR 9 intact across the buffer
//! boundary.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::error::Error;
use crate::kvcache::prefix::PrefixCache;
use crate::kvcache::KvState;
use crate::server::api::{GenResponse, StreamToken};
use crate::server::service::{
    error_response, publish_prefix, run_replica, ReplicaCtx, Server, ServerHandle, Submission,
};
use crate::util::lock_unpoisoned;

/// Dispatcher-visible load of one replica: requests routed to it that
/// have not yet received their terminal answer (queued + chunk-
/// prefilling + parked + decoding). Arrive happens on the dispatcher
/// thread at routing time; depart happens on the replica (or its host
/// lane teardown) when the reply sender is consumed — every routed
/// request is answered exactly once, so the pairing is exact.
#[derive(Default)]
pub struct ReplicaStatus {
    inflight: AtomicUsize,
}

impl ReplicaStatus {
    pub fn arrive(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    pub fn depart(&self) {
        // saturating: a spurious extra depart must not wrap to usize::MAX
        // and blackhole the replica forever
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

/// Route one request: prefix affinity first, join-shortest-queue as the
/// fallback. `inflight[i]` is replica i's dispatcher-visible load,
/// `coverage[i]` the longest cached prefix (tokens) its cache holds for
/// this prompt, `max_batch` the per-replica slot count.
///
/// Candidates are the *open* replicas (`inflight < max_batch`, i.e. a
/// free-or-preemptible slot exists); only when every replica is
/// saturated does the whole set become eligible (the request must queue
/// somewhere). Among candidates: the longest coverage wins (ties:
/// lighter load, then lower index); zero coverage everywhere means pure
/// JSQ (ties: lower index). Deterministic, so routing is replayable.
pub fn pick(inflight: &[usize], coverage: &[usize], max_batch: usize) -> usize {
    let n = inflight.len().min(coverage.len());
    if n == 0 {
        return 0;
    }
    let open: Vec<usize> = (0..n).filter(|&i| inflight[i] < max_batch).collect();
    let all: Vec<usize> = (0..n).collect();
    let cand: &[usize] = if open.is_empty() { &all } else { &open };
    let affine = cand.iter().copied().filter(|&i| coverage[i] > 0).max_by(|&a, &b| {
        coverage[a]
            .cmp(&coverage[b])
            .then(inflight[b].cmp(&inflight[a]))
            .then(b.cmp(&a))
    });
    if let Some(i) = affine {
        return i;
    }
    cand.iter().copied().min_by_key(|&i| (inflight[i], i)).unwrap_or(0)
}

/// One deferred unit of host-side work for a replica's host lane.
/// Everything a worker wants off its critical path between device
/// iterations: channel sends and multi-layer snapshot copies.
pub(crate) enum HostWork {
    /// Terminal answer (the reply sender was already removed from the
    /// outbox, so the worker forgets the request immediately).
    Respond(Sender<GenResponse>, GenResponse),
    /// One committed streaming token.
    Emit(Sender<StreamToken>, StreamToken),
    /// Prefix-cache publication of a finished admission prefill: the
    /// states moved here, so the snapshot host copies run off-worker.
    Publish {
        cache: Arc<Mutex<PrefixCache>>,
        snap: usize,
        block_tokens: Option<usize>,
        prompt: Vec<u32>,
        covered: usize,
        target: KvState,
        draft: Option<KvState>,
    },
    /// Lane teardown sentinel.
    Stop,
}

/// Execute one unit of host work. Shared by the host-lane thread and
/// the inline (single-worker / lane-down) path, so deferred and
/// non-deferred execution cannot drift.
pub(crate) fn run_host_work(w: HostWork) {
    match w {
        HostWork::Respond(tx, resp) => {
            let _ = tx.send(resp);
        }
        HostWork::Emit(tx, t) => {
            let _ = tx.send(t);
        }
        HostWork::Publish { cache, snap, block_tokens, prompt, covered, target, draft } => {
            publish_prefix(&cache, snap, block_tokens, &prompt, covered, &target, draft.as_ref());
        }
        HostWork::Stop => {}
    }
}

/// A replica's host-overlap lane: a FIFO queue drained by a dedicated
/// thread. `submitted` (worker-only) and `processed` (thread-published)
/// are the sequence numbers of the double-buffer handoff: the worker's
/// [`Self::quiesce`] waits for `processed` to catch up before reading
/// state the lane may still be writing (its prefix cache).
pub(crate) struct HostLane {
    tx: Sender<HostWork>,
    /// Items handed to the lane (worker thread only — plain u64).
    submitted: u64,
    /// Items the lane thread finished (Release on write, Acquire on
    /// read: quiesce observes the cache inserts that preceded the bump).
    processed: Arc<AtomicU64>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HostLane {
    pub(crate) fn spawn() -> HostLane {
        let (tx, rx) = channel::<HostWork>();
        let processed = Arc::new(AtomicU64::new(0));
        let done = processed.clone();
        let join = std::thread::spawn(move || {
            while let Ok(w) = rx.recv() {
                let stop = matches!(w, HostWork::Stop);
                run_host_work(w);
                done.fetch_add(1, Ordering::Release);
                if stop {
                    break;
                }
            }
        });
        HostLane { tx, submitted: 0, processed, join: Some(join) }
    }

    /// Hand one item to the lane. Returns the item back if the lane
    /// thread is gone (the caller runs it inline — degraded but
    /// correct, never dropped).
    pub(crate) fn defer(&mut self, w: HostWork) -> Option<HostWork> {
        match self.tx.send(w) {
            Ok(()) => {
                self.submitted += 1;
                None
            }
            Err(e) => Some(e.0),
        }
    }

    /// Block (spin-yield) until every deferred item has been processed.
    /// Bounded: a wedged lane degrades to stale prefix reads, not a
    /// hung scheduler.
    pub(crate) fn quiesce(&self) {
        let mut spins: u32 = 0;
        while self.processed.load(Ordering::Acquire) < self.submitted {
            spins += 1;
            if spins > 5_000_000 {
                return;
            }
            std::thread::yield_now();
        }
    }
}

impl Drop for HostLane {
    fn drop(&mut self) {
        // FIFO guarantees everything queued before Stop is delivered
        // before the join returns
        let _ = self.tx.send(HostWork::Stop);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Dispatcher-side view of one spawned replica.
struct ReplicaLink {
    tx: Sender<Submission>,
    join: Option<std::thread::JoinHandle<()>>,
    status: Arc<ReplicaStatus>,
    /// Affinity peek handle (None when prefix reuse is off).
    cache: Option<Arc<Mutex<PrefixCache>>>,
}

/// Stand up `config.replicas` serving loops plus the dispatcher thread
/// fronting them. The returned handle is indistinguishable from a
/// single-worker one: submit/cancel/shutdown route transparently.
pub(crate) fn spawn_replicated(server: Arc<Server>) -> ServerHandle {
    let n = server.config.replicas.max(2);
    // pre-register every gauge lane so the `replicas` rollup gauge
    // reports N from the first stats scrape, not lazily
    server.metrics.ensure_lanes(n);
    let share_prefix =
        server.config.prefix_cache_bytes > 0 && server.engine.supports_prefix_reuse();
    let mut links: Vec<ReplicaLink> = Vec::with_capacity(n);
    for lane in 0..n {
        // each replica owns a slice of the prefix budget: affinity
        // routing keeps a given prefix's traffic on one replica, so
        // slicing (not sharing) the tree avoids cross-replica lock
        // traffic on the hot probe path, and the gauge rollup SUMs the
        // slices back into one capacity number
        let cache = if share_prefix {
            Some(Arc::new(Mutex::new(PrefixCache::new(
                (server.config.prefix_cache_bytes / n).max(1),
            ))))
        } else {
            None
        };
        let status = Arc::new(ReplicaStatus::default());
        let (tx, rx) = channel::<Submission>();
        let ctx = ReplicaCtx {
            lane,
            prefix: cache.clone(),
            status: Some(status.clone()),
            host: Some(HostLane::spawn()),
        };
        let srv = server.clone();
        let join = std::thread::spawn(move || run_replica(&srv, &rx, ctx));
        links.push(ReplicaLink { tx, join: Some(join), status, cache });
    }
    let (tx, rx) = channel::<Submission>();
    let max_batch = server.config.max_batch;
    let join = std::thread::spawn(move || run_dispatch(&rx, links, max_batch));
    ServerHandle::from_parts(tx, join)
}

/// The dispatcher loop: route requests ([`pick`]), broadcast cancels
/// (unknown ids are a no-op on every replica but the owning one), and
/// fan shutdown out to every replica before joining them.
fn run_dispatch(rx: &Receiver<Submission>, mut links: Vec<ReplicaLink>, max_batch: usize) {
    loop {
        match rx.recv() {
            Ok(Submission::Request(req, reply, watch, sink)) => {
                let inflight: Vec<usize> = links.iter().map(|l| l.status.inflight()).collect();
                let coverage: Vec<usize> = links
                    .iter()
                    .map(|l| {
                        l.cache.as_ref().map_or(0, |c| {
                            // stat-free peek: routing must not touch LRU
                            // order or the replica's hit counters
                            lock_unpoisoned(c)
                                .covered(&req.prompt, req.prompt.len().saturating_sub(1))
                        })
                    })
                    .collect();
                let chosen = pick(&inflight, &coverage, max_batch);
                let Some(link) = links.get(chosen) else { continue };
                link.status.arrive();
                if let Err(e) = link.tx.send(Submission::Request(req, reply, watch, sink)) {
                    // replica thread died: answer instead of hanging the
                    // client, and rebalance the count we just took
                    link.status.depart();
                    if let Submission::Request(req, reply, _, _) = e.0 {
                        let _ = reply.send(error_response(
                            req.id,
                            Error::Serving("replica unavailable".into()),
                        ));
                    }
                }
            }
            Ok(Submission::Cancel(id)) => {
                for l in &links {
                    let _ = l.tx.send(Submission::Cancel(id));
                }
            }
            Ok(Submission::Shutdown) | Err(_) => break,
        }
    }
    for l in &links {
        let _ = l.tx.send(Submission::Shutdown);
    }
    for l in links.iter_mut() {
        if let Some(j) = l.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsq_routes_to_least_loaded() {
        assert_eq!(pick(&[3, 1, 2], &[0, 0, 0], 8), 1);
        // tie breaks toward the lower index
        assert_eq!(pick(&[2, 1, 1], &[0, 0, 0], 8), 1);
    }

    #[test]
    fn affinity_beats_jsq_when_open() {
        // replica 2 holds the longest cached prefix: it wins even while
        // busier than the JSQ choice
        assert_eq!(pick(&[0, 1, 3], &[0, 0, 128], 8), 2);
        // coverage ties break toward the lighter replica
        assert_eq!(pick(&[5, 2, 3], &[0, 64, 64], 8), 1);
    }

    #[test]
    fn saturated_replica_never_wins_affinity() {
        // the covered replica is full: affinity must not override the
        // free-slot requirement
        assert_eq!(pick(&[4, 0], &[256, 0], 4), 1);
    }

    #[test]
    fn all_saturated_falls_back_to_jsq_over_everyone() {
        assert_eq!(pick(&[7, 5, 6], &[0, 0, 0], 4), 1);
        // and affinity still orders the saturated set
        assert_eq!(pick(&[7, 5, 6], &[0, 0, 9], 4), 2);
    }

    #[test]
    fn empty_and_mismatched_inputs_are_safe() {
        assert_eq!(pick(&[], &[], 4), 0);
        assert_eq!(pick(&[1, 2, 3], &[0], 4), 0);
    }

    /// Property sweep (deterministic LCG): with at least one open
    /// replica, the dispatcher never routes to a saturated one.
    #[test]
    fn never_routes_to_saturated_while_open_exists() {
        let mut state: u64 = 0x9e37_79b9_7f4a_7c15;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        for _case in 0..2000 {
            let n = 1 + next() % 8;
            let max_batch = 1 + next() % 8;
            let inflight: Vec<usize> = (0..n).map(|_| next() % (max_batch * 2)).collect();
            let coverage: Vec<usize> = (0..n).map(|_| next() % 512).collect();
            let chosen = pick(&inflight, &coverage, max_batch);
            assert!(chosen < n, "pick out of range: {chosen} >= {n}");
            if inflight.iter().any(|&f| f < max_batch) {
                assert!(
                    inflight[chosen] < max_batch,
                    "routed to saturated replica {chosen} (inflight {inflight:?}, \
                     coverage {coverage:?}, max_batch {max_batch})"
                );
            }
        }
    }

    #[test]
    fn status_counts_saturate_at_zero() {
        let st = ReplicaStatus::default();
        st.depart();
        assert_eq!(st.inflight(), 0);
        st.arrive();
        st.arrive();
        st.depart();
        assert_eq!(st.inflight(), 1);
    }

    #[test]
    fn host_lane_quiesce_observes_all_work() {
        let (sink_tx, sink_rx) = channel::<StreamToken>();
        let mut lane = HostLane::spawn();
        for i in 0..64u32 {
            let w = HostWork::Emit(
                sink_tx.clone(),
                StreamToken { id: 1, index: i as usize, token: i },
            );
            assert!(lane.defer(w).is_none());
        }
        lane.quiesce();
        // after quiesce every frame is already in the sink, in order
        let got: Vec<u32> = sink_rx.try_iter().map(|t| t.token).collect();
        assert_eq!(got, (0..64u32).collect::<Vec<u32>>());
    }
}
