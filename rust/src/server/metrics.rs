//! Serving metrics: TTFT, TPOT, prefill speed and throughput in the
//! paper's §4.1 definitions.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::{lock_unpoisoned, mean, median, percentile};

#[derive(Debug, Clone)]
pub struct RequestTiming {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// time to first token, seconds
    pub ttft_s: f64,
    /// total request wall time, seconds
    pub total_s: f64,
    /// per-generated-token intervals, seconds
    pub token_intervals: Vec<f64>,
}

impl RequestTiming {
    /// Paper §4.1: prefill speed = context tokens / time-to-first-token.
    pub fn prefill_speed(&self) -> f64 {
        self.prompt_tokens as f64 / self.ttft_s.max(1e-12)
    }

    /// Paper §4.1: throughput = median tokens/s over intervals.
    pub fn decode_throughput(&self) -> f64 {
        if self.token_intervals.is_empty() {
            return 0.0;
        }
        let per: Vec<f64> = self
            .token_intervals
            .iter()
            .map(|&dt| 1.0 / dt.max(1e-12))
            .collect();
        median(&per)
    }
}

/// Per-request stopwatch used by the generation loop.
pub struct Stopwatch {
    start: Instant,
    first_token: Option<f64>,
    last_mark: f64,
    intervals: Vec<f64>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
            first_token: None,
            last_mark: 0.0,
            intervals: Vec::new(),
        }
    }

    pub fn mark_token(&mut self) {
        self.mark_tokens(1);
    }

    /// Mark `n` tokens emitted at this instant — one speculative verify
    /// pass commits up to W at once. The elapsed interval since the last
    /// mark is amortized over them: pushing n near-zero intervals instead
    /// would poison the median per-token throughput (§4.1) the summary
    /// reports.
    pub fn mark_tokens(&mut self, n: usize) {
        if n == 0 {
            return;
        }
        let now = self.start.elapsed().as_secs_f64();
        let mut n = n;
        if self.first_token.is_none() {
            self.first_token = Some(now);
            n -= 1;
        }
        if n > 0 {
            let dt = (now - self.last_mark) / n as f64;
            for _ in 0..n {
                self.intervals.push(dt);
            }
        }
        self.last_mark = now;
    }

    pub fn finish(self, prompt_tokens: usize, generated_tokens: usize) -> RequestTiming {
        let total = self.start.elapsed().as_secs_f64();
        RequestTiming {
            prompt_tokens,
            generated_tokens,
            ttft_s: self.first_token.unwrap_or(total),
            total_s: total,
            token_intervals: self.intervals,
        }
    }
}

/// Scheduler-level gauges and counters (continuous batching): queue
/// depth, per-iteration batch occupancy, KV-pool utilization, and slot
/// churn. Updated by the worker loop once per decode iteration.
#[derive(Debug, Clone, Default)]
pub struct SchedulerGauges {
    /// Decode iterations run.
    pub iterations: u64,
    /// Sum of occupied rows over iterations (occupancy numerator).
    // nbl-lint: gauge(mean_batch_occupancy, mean_rows_per_iteration)
    pub occupied_rows: u64,
    /// Sum of arena rows over iterations (occupancy denominator).
    // nbl-lint: gauge(mean_batch_occupancy)
    pub bucket_rows: u64,
    /// Max rows occupied simultaneously at any iteration — the
    /// concurrency number `serve_bench --paged-compare` compares
    /// between paged and contiguous admission under one KV budget.
    pub peak_rows: usize,
    /// Requests admitted into a KV slot.
    pub admissions: u64,
    /// Admissions into a row that a finished request freed earlier
    /// (slot reuse without restarting the batch).
    pub slot_reuses: u64,
    /// Waiting requests at the last observation.
    pub queue_depth: usize,
    /// KV-pool bytes reserved at the last observation.
    // nbl-lint: gauge(kv_in_use_bytes)
    pub kv_in_use: usize,
    /// KV-pool capacity in bytes.
    // nbl-lint: gauge(kv_capacity_bytes)
    pub kv_capacity: usize,
    /// Tokens committed by decode iterations (all rows, all widths).
    pub committed_tokens: u64,
    /// Prefill chunks executed by the chunked-admission state machine
    /// (DESIGN.md §Chunked prefill), including each machine's first and
    /// final chunk.
    pub prefill_chunks: u64,
    /// Admissions whose prompt was prefilled to completion through the
    /// multi-chunk state machine rather than one whole-prompt call
    /// (counted even when the request finishes on its prefill token and
    /// never occupies a decode row, e.g. a max-context prompt).
    pub chunked_admissions: u64,
    /// Chunks that ran while decode rows were live — each one stalls
    /// the whole decode group for its duration (the prefill/decode
    /// interference the chunk size bounds).
    pub chunk_stalls: u64,
    /// Seconds decode rows spent stalled behind prefill chunks (sum of
    /// the durations counted by `chunk_stalls`).
    // nbl-lint: gauge(chunk_stall_ms_total, chunk_stall_ms_mean)
    pub chunk_stall_s: f64,
    /// Speculative verify passes (target iterations with width > 1).
    pub spec_rounds: u64,
    /// Draft tokens that entered verification.
    pub spec_proposed: u64,
    /// Draft tokens the target accepted (greedy match).
    pub spec_accepted: u64,
    /// Prefix-cache probes that adopted a cached prompt prefix
    /// (DESIGN.md §Prefix cache).
    pub prefix_hits: u64,
    /// Prefix-cache probes that found nothing (cold prefill).
    pub prefix_misses: u64,
    /// Prompt tokens served from cached prefixes (prefill work skipped).
    pub prefix_hit_tokens: u64,
    /// Snapshots published into the radix tree (insert-on-miss).
    pub prefix_inserts: u64,
    /// Entries LRU-evicted under the prefix byte budget.
    pub prefix_evictions: u64,
    /// Live radix-tree entries at the last observation.
    pub prefix_entries: usize,
    /// Snapshot bytes resident at the last observation.
    pub prefix_bytes: usize,
    /// Prefix-cache byte budget (0 = cache off).
    pub prefix_capacity_bytes: usize,
    /// Publication rounds skipped because the covered prefix was
    /// already resident (no host copy built).
    pub prefix_publish_skips: u64,
    /// Per-layer KvSnapshot expansion copies performed by warm
    /// adoptions (legacy snapshot path; stays ZERO in paged mode — the
    /// counter `--paged-compare` verifies).
    pub prefix_expand_copies: u64,
    /// Paged block size in tokens (0 = paged mode off).
    pub paged_block_tokens: usize,
    /// KV budget in target-block units (paged mode).
    pub blocks_capacity: usize,
    /// Remaining budget in target-block units at the last observation.
    pub blocks_free: usize,
    /// Private (pool-charged) block frames resident.
    pub blocks_used: usize,
    /// Shared (zero-charge, prefix-cache-owned) block frames resident.
    pub blocks_shared: usize,
    /// Tokens actually cached across all block tables.
    pub blocks_live_tokens: usize,
    /// Private tail frames kept at adoption (copy-on-write count).
    pub cow_copies: u64,
    /// Slots evicted under block pressure for later re-admission.
    pub preemptions: u64,
    /// Warm adoptions that spliced a shared block run into a table.
    pub paged_splices: u64,
    /// Prompt tokens covered by spliced runs.
    pub paged_splice_tokens: u64,
}

impl SchedulerGauges {
    /// Mean occupied fraction of the decode batch per iteration.
    pub fn mean_occupancy(&self) -> f64 {
        if self.bucket_rows == 0 {
            return 0.0;
        }
        self.occupied_rows as f64 / self.bucket_rows as f64
    }

    /// Mean occupied ROWS per iteration (how many requests actually
    /// shared a decode call).
    pub fn mean_rows_per_iteration(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.occupied_rows as f64 / self.iterations as f64
    }

    pub fn kv_utilization(&self) -> f64 {
        if self.kv_capacity == 0 {
            return 0.0;
        }
        self.kv_in_use as f64 / self.kv_capacity as f64
    }

    /// Fraction of draft proposals the target accepted (paper §5: the
    /// driver of the speculative speed-up).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// Mean tokens committed per occupied row per target iteration —
    /// exactly 1.0 for plain continuous decoding, > 1.0 when speculative
    /// verification pays off.
    pub fn tokens_per_row_iteration(&self) -> f64 {
        if self.occupied_rows == 0 {
            return 0.0;
        }
        self.committed_tokens as f64 / self.occupied_rows as f64
    }

    /// Mean decode stall per interfering prefill chunk, in milliseconds
    /// — the per-iteration head-of-line cost chunking bounds (one grid
    /// width instead of a whole long prompt).
    pub fn mean_chunk_stall_ms(&self) -> f64 {
        if self.chunk_stalls == 0 {
            return 0.0;
        }
        self.chunk_stall_s * 1e3 / self.chunk_stalls as f64
    }

    /// Fraction of admission probes that adopted a cached prefix — the
    /// warm-traffic share the prefix cache converts from prefill compute
    /// into a host-side row copy.
    pub fn prefix_hit_rate(&self) -> f64 {
        let probes = self.prefix_hits + self.prefix_misses;
        if probes == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / probes as f64
    }

    /// Token slack trapped in allocated block frames: 1 - live/(frames
    /// * block). Contiguous rows waste `max_ctx - live` per request
    /// instead; the gap between the two is the capacity paging buys.
    pub fn paged_fragmentation(&self) -> f64 {
        let frames = self.blocks_used + self.blocks_shared;
        if frames == 0 || self.paged_block_tokens == 0 {
            return 0.0;
        }
        1.0 - self.blocks_live_tokens as f64 / (frames * self.paged_block_tokens) as f64
    }
}

/// Aggregates request timings across the server lifetime.
#[derive(Default)]
pub struct MetricsHub {
    timings: Mutex<Vec<RequestTiming>>,
    gauges: Mutex<SchedulerGauges>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    pub fn record(&self, t: RequestTiming) {
        lock_unpoisoned(&self.timings).push(t);
    }

    /// One decode iteration ran with `occupied` of `bucket` rows live.
    pub fn note_iteration(&self, occupied: usize, bucket: usize) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.iterations += 1;
        g.occupied_rows += occupied as u64;
        g.bucket_rows += bucket as u64;
        g.peak_rows = g.peak_rows.max(occupied);
    }

    /// `layers` per-layer KvSnapshot expansion copies ran for one warm
    /// adoption (the legacy snapshot restore path; paged splices never
    /// call this, which is exactly what the zero-copy bench asserts).
    pub fn note_prefix_expand(&self, layers: usize) {
        lock_unpoisoned(&self.gauges).prefix_expand_copies += layers as u64;
    }

    /// Mirror the worker-local paged block-pool counters into the
    /// gauges (refreshed once per scheduler iteration, like
    /// `observe_prefix`).
    pub fn observe_paged(&self, s: &crate::kvcache::paged::PagedStats) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.paged_block_tokens = s.block_tokens;
        g.blocks_capacity = s.capacity_blocks;
        g.blocks_free = s.free_blocks;
        g.blocks_used = s.used_blocks;
        g.blocks_shared = s.shared_blocks;
        g.blocks_live_tokens = s.live_tokens;
        g.cow_copies = s.cow_copies;
        g.preemptions = s.preemptions;
        g.paged_splices = s.splices;
        g.paged_splice_tokens = s.splice_tokens;
    }

    /// `committed` tokens were emitted by the iteration that just ran;
    /// with speculation a single iteration commits 1..=W per row.
    pub fn note_committed(&self, committed: usize) {
        lock_unpoisoned(&self.gauges).committed_tokens += committed as u64;
    }

    /// One speculative verify pass ran: `proposed` draft tokens entered
    /// verification and `accepted` of them matched the target.
    pub fn note_spec_round(&self, proposed: usize, accepted: usize) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.spec_rounds += 1;
        g.spec_proposed += proposed as u64;
        g.spec_accepted += accepted as u64;
    }

    /// One prefill chunk ran; `stalled` = decode rows were live and
    /// waited `dt_s` seconds for it (the interference gauge).
    pub fn note_prefill_chunk(&self, stalled: bool, dt_s: f64) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.prefill_chunks += 1;
        if stalled {
            g.chunk_stalls += 1;
            g.chunk_stall_s += dt_s;
        }
    }

    /// An admission completed through the multi-chunk prefill machine.
    pub fn note_chunked_admission(&self) {
        lock_unpoisoned(&self.gauges).chunked_admissions += 1;
    }

    /// A request was admitted into a slot (`reused` = the row had served
    /// an earlier, now-finished request).
    pub fn note_admission(&self, reused: bool) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.admissions += 1;
        if reused {
            g.slot_reuses += 1;
        }
    }

    /// Mirror the worker-local prefix-cache counters into the gauges
    /// (refreshed once per scheduler iteration, like `observe` — the
    /// radix tree itself stays single-threaded on the worker).
    pub fn observe_prefix(&self, s: &crate::kvcache::prefix::PrefixStats) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.prefix_hits = s.hits;
        g.prefix_misses = s.misses;
        g.prefix_hit_tokens = s.hit_tokens;
        g.prefix_inserts = s.inserts;
        g.prefix_evictions = s.evictions;
        g.prefix_entries = s.entries;
        g.prefix_bytes = s.bytes_in_use;
        g.prefix_capacity_bytes = s.capacity_bytes;
        g.prefix_publish_skips = s.publish_skips;
    }

    /// Refresh the point-in-time gauges (queue depth + KV pool state).
    pub fn observe(&self, queue_depth: usize, kv_in_use: usize, kv_capacity: usize) {
        let mut g = lock_unpoisoned(&self.gauges);
        g.queue_depth = queue_depth;
        g.kv_in_use = kv_in_use;
        g.kv_capacity = kv_capacity;
    }

    pub fn gauges(&self) -> SchedulerGauges {
        lock_unpoisoned(&self.gauges).clone()
    }

    /// Snapshot of every recorded request timing — benches slice TTFT
    /// by prompt-length class (e.g. p50 TTFT of short requests admitted
    /// behind a long prompt, the number chunked prefill exists to lower).
    pub fn timings(&self) -> Vec<RequestTiming> {
        lock_unpoisoned(&self.timings).clone()
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.timings).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn summary(&self) -> MetricsSummary {
        let ts = lock_unpoisoned(&self.timings);
        let ttfts: Vec<f64> = ts.iter().map(|t| t.ttft_s).collect();
        let prefill: Vec<f64> = ts.iter().map(|t| t.prefill_speed()).collect();
        let tput: Vec<f64> = ts
            .iter()
            .filter(|t| !t.token_intervals.is_empty())
            .map(|t| t.decode_throughput())
            .collect();
        // inter-token latency distribution over ALL generated tokens
        // (flattened, so a busy request weighs by its token count, not
        // once per request — the tail a per-request median hides)
        let itls: Vec<f64> = ts.iter().flat_map(|t| t.token_intervals.iter().copied()).collect();
        let total_tokens: usize = ts.iter().map(|t| t.generated_tokens).sum();
        let wall: f64 = ts.iter().map(|t| t.total_s).sum();
        MetricsSummary {
            requests: ts.len(),
            generated_tokens: total_tokens,
            mean_ttft_s: mean(&ttfts),
            p50_ttft_s: percentile(&ttfts, 50.0),
            p90_ttft_s: percentile(&ttfts, 90.0),
            p95_ttft_s: percentile(&ttfts, 95.0),
            p99_ttft_s: percentile(&ttfts, 99.0),
            p50_itl_s: percentile(&itls, 50.0),
            p95_itl_s: percentile(&itls, 95.0),
            p99_itl_s: percentile(&itls, 99.0),
            mean_prefill_tok_s: mean(&prefill),
            median_decode_tok_s: median(&tput),
            aggregate_tok_s: total_tokens as f64 / wall.max(1e-12),
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub requests: usize,
    pub generated_tokens: usize,
    pub mean_ttft_s: f64,
    pub p50_ttft_s: f64,
    pub p90_ttft_s: f64,
    pub p95_ttft_s: f64,
    pub p99_ttft_s: f64,
    /// Inter-token latency percentiles over every generated token.
    pub p50_itl_s: f64,
    pub p95_itl_s: f64,
    pub p99_itl_s: f64,
    pub mean_prefill_tok_s: f64,
    pub median_decode_tok_s: f64,
    pub aggregate_tok_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_math() {
        let t = RequestTiming {
            prompt_tokens: 100,
            generated_tokens: 3,
            ttft_s: 0.5,
            total_s: 1.0,
            token_intervals: vec![0.1, 0.2, 0.1],
        };
        assert!((t.prefill_speed() - 200.0).abs() < 1e-9);
        assert!((t.decode_throughput() - 10.0).abs() < 1e-9); // median of 10,5,10
    }

    #[test]
    fn stopwatch_tracks_first_token() {
        let mut sw = Stopwatch::new();
        sw.mark_token();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.mark_token();
        let t = sw.finish(10, 2);
        assert!(t.ttft_s >= 0.0);
        assert_eq!(t.token_intervals.len(), 1);
        assert!(t.token_intervals[0] >= 0.002);
    }

    #[test]
    fn gauges_track_iterations_and_churn() {
        let hub = MetricsHub::new();
        hub.note_iteration(2, 8);
        hub.note_iteration(6, 8);
        hub.note_admission(false);
        hub.note_admission(true);
        hub.observe(3, 500, 1000);
        let g = hub.gauges();
        assert_eq!(g.iterations, 2);
        assert!((g.mean_occupancy() - 0.5).abs() < 1e-9);
        assert!((g.mean_rows_per_iteration() - 4.0).abs() < 1e-9);
        assert_eq!(g.admissions, 2);
        assert_eq!(g.slot_reuses, 1);
        assert_eq!(g.queue_depth, 3);
        assert!((g.kv_utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mark_tokens_amortizes_the_interval() {
        let mut sw = Stopwatch::new();
        sw.mark_token(); // prefill token: sets TTFT, no interval
        std::thread::sleep(std::time::Duration::from_millis(4));
        sw.mark_tokens(4); // one verify pass committed 4 tokens
        let t = sw.finish(8, 5);
        assert_eq!(t.token_intervals.len(), 4);
        // equal shares of the elapsed window, not 3 near-zero intervals
        let first = t.token_intervals[0];
        assert!(first >= 0.0009);
        for dt in &t.token_intervals {
            assert!((dt - first).abs() < 1e-12);
        }
    }

    #[test]
    fn spec_gauges_track_acceptance_and_commit_rate() {
        let hub = MetricsHub::new();
        // two iterations over 2 occupied rows each; speculation commits
        // more than one token per row-iteration
        hub.note_iteration(2, 8);
        hub.note_spec_round(6, 4);
        hub.note_committed(6); // 4 accepted + 2 corrections
        hub.note_iteration(2, 8);
        hub.note_spec_round(6, 2);
        hub.note_committed(4);
        let g = hub.gauges();
        assert_eq!(g.spec_rounds, 2);
        assert_eq!(g.spec_proposed, 12);
        assert_eq!(g.spec_accepted, 6);
        assert_eq!(g.committed_tokens, 10);
        assert!((g.acceptance_rate() - 0.5).abs() < 1e-9);
        assert!((g.tokens_per_row_iteration() - 2.5).abs() < 1e-9);
        // plain decoding commits exactly one token per row-iteration
        let plain = MetricsHub::new();
        plain.note_iteration(3, 8);
        plain.note_committed(3);
        let p = plain.gauges();
        assert!((p.tokens_per_row_iteration() - 1.0).abs() < 1e-9);
        assert_eq!(p.acceptance_rate(), 0.0);
    }

    #[test]
    fn chunk_gauges_track_stall_time() {
        let hub = MetricsHub::new();
        hub.note_prefill_chunk(false, 0.050); // admission ramp: no decode live
        hub.note_prefill_chunk(true, 0.010);
        hub.note_prefill_chunk(true, 0.030);
        hub.note_chunked_admission();
        let g = hub.gauges();
        assert_eq!(g.prefill_chunks, 3);
        assert_eq!(g.chunked_admissions, 1);
        assert_eq!(g.chunk_stalls, 2);
        assert!((g.chunk_stall_s - 0.040).abs() < 1e-12);
        assert!((g.mean_chunk_stall_ms() - 20.0).abs() < 1e-9);
        // no interfering chunks -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().mean_chunk_stall_ms(), 0.0);
    }

    #[test]
    fn prefix_gauges_mirror_cache_stats() {
        let hub = MetricsHub::new();
        let s = crate::kvcache::prefix::PrefixStats {
            hits: 6,
            misses: 2,
            hit_tokens: 384,
            inserts: 5,
            evictions: 1,
            publish_skips: 3,
            entries: 4,
            bytes_in_use: 4096,
            capacity_bytes: 8192,
        };
        hub.observe_prefix(&s);
        let g = hub.gauges();
        assert_eq!(g.prefix_hits, 6);
        assert_eq!(g.prefix_misses, 2);
        assert_eq!(g.prefix_hit_tokens, 384);
        assert_eq!(g.prefix_inserts, 5);
        assert_eq!(g.prefix_evictions, 1);
        assert_eq!(g.prefix_publish_skips, 3);
        assert_eq!(g.prefix_entries, 4);
        assert_eq!(g.prefix_bytes, 4096);
        assert_eq!(g.prefix_capacity_bytes, 8192);
        assert!((g.prefix_hit_rate() - 0.75).abs() < 1e-9);
        // no probes -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn paged_gauges_mirror_pool_stats() {
        let hub = MetricsHub::new();
        let s = crate::kvcache::paged::PagedStats {
            block_tokens: 64,
            capacity_blocks: 32,
            free_blocks: 20,
            used_blocks: 8,
            shared_blocks: 4,
            live_tokens: 576,
            cow_copies: 2,
            preemptions: 1,
            splices: 4,
            splice_tokens: 512,
        };
        hub.observe_paged(&s);
        hub.note_prefix_expand(6);
        hub.note_prefix_expand(6);
        let g = hub.gauges();
        assert_eq!(g.paged_block_tokens, 64);
        assert_eq!(g.blocks_capacity, 32);
        assert_eq!(g.blocks_free, 20);
        assert_eq!(g.blocks_used, 8);
        assert_eq!(g.blocks_shared, 4);
        assert_eq!(g.blocks_live_tokens, 576);
        assert_eq!(g.cow_copies, 2);
        assert_eq!(g.preemptions, 1);
        assert_eq!(g.paged_splices, 4);
        assert_eq!(g.paged_splice_tokens, 512);
        assert_eq!(g.prefix_expand_copies, 12);
        // 576 live of 12 frames * 64 tokens -> 25% slack
        assert!((g.paged_fragmentation() - 0.25).abs() < 1e-9);
        // no frames -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().paged_fragmentation(), 0.0);
    }

    #[test]
    fn peak_rows_tracks_the_high_water_mark() {
        let hub = MetricsHub::new();
        hub.note_iteration(2, 8);
        hub.note_iteration(6, 8);
        hub.note_iteration(3, 8);
        assert_eq!(hub.gauges().peak_rows, 6);
    }

    #[test]
    fn summary_percentiles_cover_ttft_and_itl() {
        let hub = MetricsHub::new();
        for i in 0..10 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 3,
                ttft_s: 0.01 * (i + 1) as f64,
                total_s: 0.5,
                token_intervals: vec![0.01, 0.02],
            });
        }
        let s = hub.summary();
        assert!((s.p50_ttft_s - 0.055).abs() < 1e-9);
        assert!(s.p95_ttft_s > s.p50_ttft_s);
        assert!(s.p99_ttft_s >= s.p95_ttft_s);
        assert!(s.p99_ttft_s <= 0.1 + 1e-9);
        // ITL is flattened over tokens: half 0.01, half 0.02
        assert!((s.p50_itl_s - 0.015).abs() < 1e-9);
        assert!((s.p99_itl_s - 0.02).abs() < 1e-6);
    }

    #[test]
    fn hub_aggregates() {
        let hub = MetricsHub::new();
        for _ in 0..3 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 5,
                ttft_s: 0.1,
                total_s: 0.6,
                token_intervals: vec![0.1; 4],
            });
        }
        let s = hub.summary();
        assert_eq!(s.requests, 3);
        assert_eq!(s.generated_tokens, 15);
        assert!((s.mean_prefill_tok_s - 100.0).abs() < 1e-9);
        assert!((s.median_decode_tok_s - 10.0).abs() < 1e-6);
    }
}
