//! Serving metrics: TTFT, TPOT, prefill speed and throughput in the
//! paper's §4.1 definitions.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::{mean, median, percentile};

#[derive(Debug, Clone)]
pub struct RequestTiming {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// time to first token, seconds
    pub ttft_s: f64,
    /// total request wall time, seconds
    pub total_s: f64,
    /// per-generated-token intervals, seconds
    pub token_intervals: Vec<f64>,
}

impl RequestTiming {
    /// Paper §4.1: prefill speed = context tokens / time-to-first-token.
    pub fn prefill_speed(&self) -> f64 {
        self.prompt_tokens as f64 / self.ttft_s.max(1e-12)
    }

    /// Paper §4.1: throughput = median tokens/s over intervals.
    pub fn decode_throughput(&self) -> f64 {
        if self.token_intervals.is_empty() {
            return 0.0;
        }
        let per: Vec<f64> = self
            .token_intervals
            .iter()
            .map(|&dt| 1.0 / dt.max(1e-12))
            .collect();
        median(&per)
    }
}

/// Per-request stopwatch used by the generation loop.
pub struct Stopwatch {
    start: Instant,
    first_token: Option<f64>,
    last_mark: f64,
    intervals: Vec<f64>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
            first_token: None,
            last_mark: 0.0,
            intervals: Vec::new(),
        }
    }

    pub fn mark_token(&mut self) {
        let now = self.start.elapsed().as_secs_f64();
        if self.first_token.is_none() {
            self.first_token = Some(now);
        } else {
            self.intervals.push(now - self.last_mark);
        }
        self.last_mark = now;
    }

    pub fn finish(self, prompt_tokens: usize, generated_tokens: usize) -> RequestTiming {
        let total = self.start.elapsed().as_secs_f64();
        RequestTiming {
            prompt_tokens,
            generated_tokens,
            ttft_s: self.first_token.unwrap_or(total),
            total_s: total,
            token_intervals: self.intervals,
        }
    }
}

/// Aggregates request timings across the server lifetime.
#[derive(Default)]
pub struct MetricsHub {
    timings: Mutex<Vec<RequestTiming>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    pub fn record(&self, t: RequestTiming) {
        self.timings.lock().unwrap().push(t);
    }

    pub fn len(&self) -> usize {
        self.timings.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn summary(&self) -> MetricsSummary {
        let ts = self.timings.lock().unwrap();
        let ttfts: Vec<f64> = ts.iter().map(|t| t.ttft_s).collect();
        let prefill: Vec<f64> = ts.iter().map(|t| t.prefill_speed()).collect();
        let tput: Vec<f64> = ts
            .iter()
            .filter(|t| !t.token_intervals.is_empty())
            .map(|t| t.decode_throughput())
            .collect();
        let total_tokens: usize = ts.iter().map(|t| t.generated_tokens).sum();
        let wall: f64 = ts.iter().map(|t| t.total_s).sum();
        MetricsSummary {
            requests: ts.len(),
            generated_tokens: total_tokens,
            mean_ttft_s: mean(&ttfts),
            p90_ttft_s: percentile(&ttfts, 90.0),
            mean_prefill_tok_s: mean(&prefill),
            median_decode_tok_s: median(&tput),
            aggregate_tok_s: total_tokens as f64 / wall.max(1e-12),
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub requests: usize,
    pub generated_tokens: usize,
    pub mean_ttft_s: f64,
    pub p90_ttft_s: f64,
    pub mean_prefill_tok_s: f64,
    pub median_decode_tok_s: f64,
    pub aggregate_tok_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_math() {
        let t = RequestTiming {
            prompt_tokens: 100,
            generated_tokens: 3,
            ttft_s: 0.5,
            total_s: 1.0,
            token_intervals: vec![0.1, 0.2, 0.1],
        };
        assert!((t.prefill_speed() - 200.0).abs() < 1e-9);
        assert!((t.decode_throughput() - 10.0).abs() < 1e-9); // median of 10,5,10
    }

    #[test]
    fn stopwatch_tracks_first_token() {
        let mut sw = Stopwatch::new();
        sw.mark_token();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.mark_token();
        let t = sw.finish(10, 2);
        assert!(t.ttft_s >= 0.0);
        assert_eq!(t.token_intervals.len(), 1);
        assert!(t.token_intervals[0] >= 0.002);
    }

    #[test]
    fn hub_aggregates() {
        let hub = MetricsHub::new();
        for _ in 0..3 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 5,
                ttft_s: 0.1,
                total_s: 0.6,
                token_intervals: vec![0.1; 4],
            });
        }
        let s = hub.summary();
        assert_eq!(s.requests, 3);
        assert_eq!(s.generated_tokens, 15);
        assert!((s.mean_prefill_tok_s - 100.0).abs() < 1e-9);
        assert!((s.median_decode_tok_s - 10.0).abs() < 1e-6);
    }
}
