//! Serving metrics: TTFT, TPOT, prefill speed and throughput in the
//! paper's §4.1 definitions.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::hist::StreamingHistogram;
use crate::util::{lock_unpoisoned, median};

#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// time to first token, seconds
    pub ttft_s: f64,
    /// total request wall time, seconds
    pub total_s: f64,
    /// TTFT attribution (DESIGN.md §Observability): time waiting in the
    /// FIFO before admission started...
    pub queue_s: f64,
    /// ...prefill compute (whole-prompt, warm-suffix, or the sum of the
    /// chunks)...
    pub prefill_s: f64,
    /// ...and everything else before the first token: iterations spent
    /// behind other requests' chunks/decodes between our own chunks.
    /// `queue_s + prefill_s + stall_s == ttft_s` by construction.
    pub stall_s: f64,
    /// Lifetime seconds spent preempted (KV pages reclaimed, request
    /// parked host-side). Parking only hits requests that already
    /// emitted a first token, so it is NOT part of the TTFT identity.
    pub park_s: f64,
    /// per-generated-token intervals, seconds
    pub token_intervals: Vec<f64>,
    /// Deadline outcome: None = the request carried no deadline,
    /// Some(met) = it did and finished in/over budget. Set by the
    /// scheduler at finish time; drives goodput and SLO attainment.
    pub deadline_met: Option<bool>,
}

impl RequestTiming {
    /// Paper §4.1: prefill speed = context tokens / time-to-first-token.
    pub fn prefill_speed(&self) -> f64 {
        self.prompt_tokens as f64 / self.ttft_s.max(1e-12)
    }

    /// Paper §4.1: throughput = median tokens/s over intervals.
    pub fn decode_throughput(&self) -> f64 {
        if self.token_intervals.is_empty() {
            return 0.0;
        }
        let per: Vec<f64> = self
            .token_intervals
            .iter()
            .map(|&dt| 1.0 / dt.max(1e-12))
            .collect();
        median(&per)
    }
}

/// Per-request stopwatch used by the generation loop. Besides TTFT and
/// inter-token intervals it carries the phase-attribution accumulators:
/// the scheduler marks admission once (`mark_admitted`), charges prefill
/// compute as it happens (`add_prefill`), and brackets preemption
/// parking (`park_begin`/`park_end`); `finish` folds them into the
/// queue/prefill/stall breakdown.
pub struct Stopwatch {
    start: Instant,
    first_token: Option<f64>,
    last_mark: f64,
    intervals: Vec<f64>,
    /// seconds from submit to the scheduler picking the request up
    admitted: Option<f64>,
    /// accumulated prefill compute seconds (pre-first-token)
    prefill_s: f64,
    /// accumulated parked seconds
    park_s: f64,
    park_since: Option<f64>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        Stopwatch {
            start: Instant::now(),
            first_token: None,
            last_mark: 0.0,
            intervals: Vec::new(),
            admitted: None,
            prefill_s: 0.0,
            park_s: 0.0,
            park_since: None,
        }
    }

    /// Seconds since the request was submitted.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// The scheduler dequeued this request and admission work began.
    /// First call wins — the sync paths that never queue leave it unset
    /// and `finish` attributes zero queue time.
    pub fn mark_admitted(&mut self) {
        if self.admitted.is_none() {
            self.admitted = Some(self.elapsed_s());
        }
    }

    /// Queue wait so far (0.0 before `mark_admitted`).
    pub fn queue_s(&self) -> f64 {
        self.admitted.unwrap_or(0.0)
    }

    /// Charge `dt_s` seconds of prefill compute (whole-prompt call, a
    /// warm-prefix restore + suffix, or one chunk).
    pub fn add_prefill(&mut self, dt_s: f64) {
        self.prefill_s += dt_s.max(0.0);
    }

    /// The request was preempted: KV reclaimed, parked host-side.
    pub fn park_begin(&mut self) {
        if self.park_since.is_none() {
            self.park_since = Some(self.elapsed_s());
        }
    }

    /// The request was re-admitted; returns this episode's park seconds.
    pub fn park_end(&mut self) -> f64 {
        let Some(since) = self.park_since.take() else {
            return 0.0;
        };
        let dt = (self.elapsed_s() - since).max(0.0);
        self.park_s += dt;
        dt
    }

    pub fn mark_token(&mut self) {
        self.mark_tokens(1);
    }

    /// Mark `n` tokens emitted at this instant — one speculative verify
    /// pass commits up to W at once. The elapsed interval since the last
    /// mark is amortized over them: pushing n near-zero intervals instead
    /// would poison the median per-token throughput (§4.1) the summary
    /// reports.
    pub fn mark_tokens(&mut self, n: usize) {
        if n == 0 {
            return;
        }
        let now = self.start.elapsed().as_secs_f64();
        let mut n = n;
        if self.first_token.is_none() {
            self.first_token = Some(now);
            n -= 1;
        }
        if n > 0 {
            let dt = (now - self.last_mark) / n as f64;
            for _ in 0..n {
                self.intervals.push(dt);
            }
        }
        self.last_mark = now;
    }

    pub fn finish(mut self, prompt_tokens: usize, generated_tokens: usize) -> RequestTiming {
        let total = self.start.elapsed().as_secs_f64();
        let ttft = self.first_token.unwrap_or(total);
        // attribution identity: queue + prefill + stall == ttft. Queue
        // and prefill are measured sub-intervals of [0, ttft] (clamped
        // against clock jitter); stall is the remainder — iterations the
        // request sat admitted-but-not-prefilling behind other work.
        let queue = self.queue_s().min(ttft);
        let prefill = self.prefill_s.min(ttft - queue);
        let stall = (ttft - queue - prefill).max(0.0);
        if self.park_since.is_some() {
            self.park_end(); // request died while parked: close the episode
        }
        RequestTiming {
            prompt_tokens,
            generated_tokens,
            ttft_s: ttft,
            total_s: total,
            queue_s: queue,
            prefill_s: prefill,
            stall_s: stall,
            park_s: self.park_s,
            token_intervals: self.intervals,
            deadline_met: None,
        }
    }
}

/// Scheduler-level gauges and counters (continuous batching): queue
/// depth, per-iteration batch occupancy, KV-pool utilization, and slot
/// churn. Updated by the worker loop once per decode iteration.
#[derive(Debug, Clone, Default)]
pub struct SchedulerGauges {
    /// Decode iterations run.
    pub iterations: u64,
    /// Sum of occupied rows over iterations (occupancy numerator).
    // nbl-lint: gauge(mean_batch_occupancy, mean_rows_per_iteration)
    pub occupied_rows: u64,
    /// Sum of arena rows over iterations (occupancy denominator).
    // nbl-lint: gauge(mean_batch_occupancy)
    pub bucket_rows: u64,
    /// Max rows occupied simultaneously at any iteration — the
    /// concurrency number `serve_bench --paged-compare` compares
    /// between paged and contiguous admission under one KV budget.
    pub peak_rows: usize,
    /// Requests admitted into a KV slot.
    pub admissions: u64,
    /// Admissions into a row that a finished request freed earlier
    /// (slot reuse without restarting the batch).
    pub slot_reuses: u64,
    /// Waiting requests at the last observation.
    pub queue_depth: usize,
    /// KV-pool bytes reserved at the last observation.
    // nbl-lint: gauge(kv_in_use_bytes)
    pub kv_in_use: usize,
    /// KV-pool capacity in bytes.
    // nbl-lint: gauge(kv_capacity_bytes)
    pub kv_capacity: usize,
    /// Tokens committed by decode iterations (all rows, all widths).
    pub committed_tokens: u64,
    /// Prefill chunks executed by the chunked-admission state machine
    /// (DESIGN.md §Chunked prefill), including each machine's first and
    /// final chunk.
    pub prefill_chunks: u64,
    /// Admissions whose prompt was prefilled to completion through the
    /// multi-chunk state machine rather than one whole-prompt call
    /// (counted even when the request finishes on its prefill token and
    /// never occupies a decode row, e.g. a max-context prompt).
    pub chunked_admissions: u64,
    /// Chunks that ran while decode rows were live — each one stalls
    /// the whole decode group for its duration (the prefill/decode
    /// interference the chunk size bounds).
    pub chunk_stalls: u64,
    /// Seconds decode rows spent stalled behind prefill chunks (sum of
    /// the durations counted by `chunk_stalls`).
    // nbl-lint: gauge(chunk_stall_ms_total, chunk_stall_ms_mean)
    pub chunk_stall_s: f64,
    /// Speculative verify passes (target iterations with width > 1).
    pub spec_rounds: u64,
    /// Draft tokens that entered verification.
    pub spec_proposed: u64,
    /// Draft tokens the target accepted (greedy match).
    pub spec_accepted: u64,
    /// Prefix-cache probes that adopted a cached prompt prefix
    /// (DESIGN.md §Prefix cache).
    pub prefix_hits: u64,
    /// Prefix-cache probes that found nothing (cold prefill).
    pub prefix_misses: u64,
    /// Prompt tokens served from cached prefixes (prefill work skipped).
    pub prefix_hit_tokens: u64,
    /// Snapshots published into the radix tree (insert-on-miss).
    pub prefix_inserts: u64,
    /// Entries LRU-evicted under the prefix byte budget.
    pub prefix_evictions: u64,
    /// Live radix-tree entries at the last observation.
    pub prefix_entries: usize,
    /// Snapshot bytes resident at the last observation.
    pub prefix_bytes: usize,
    /// Prefix-cache byte budget (0 = cache off).
    pub prefix_capacity_bytes: usize,
    /// Publication rounds skipped because the covered prefix was
    /// already resident (no host copy built).
    pub prefix_publish_skips: u64,
    /// Per-layer KvSnapshot expansion copies performed by warm
    /// adoptions (legacy snapshot path; stays ZERO in paged mode — the
    /// counter `--paged-compare` verifies).
    pub prefix_expand_copies: u64,
    /// Paged block size in tokens (0 = paged mode off).
    pub paged_block_tokens: usize,
    /// KV budget in target-block units (paged mode).
    pub blocks_capacity: usize,
    /// Remaining budget in target-block units at the last observation.
    pub blocks_free: usize,
    /// Private (pool-charged) block frames resident.
    pub blocks_used: usize,
    /// Shared (zero-charge, prefix-cache-owned) block frames resident.
    pub blocks_shared: usize,
    /// Tokens actually cached across all block tables.
    pub blocks_live_tokens: usize,
    /// Private tail frames kept at adoption (copy-on-write count).
    pub cow_copies: u64,
    /// Slots evicted under block pressure for later re-admission.
    pub preemptions: u64,
    /// Warm adoptions that spliced a shared block run into a table.
    pub paged_splices: u64,
    /// Prompt tokens covered by spliced runs.
    pub paged_splice_tokens: u64,
    /// Requests aborted by the client (explicit cancel frame or
    /// writer-side disconnect), in any lifecycle state.
    pub cancelled: u64,
    /// Requests whose deadline expired mid-flight (active decode,
    /// chunked prefill, or parked) — terminated with a typed error.
    pub expired: u64,
    /// Requests shed from the intake queue because their deadline was
    /// already blown before admission (never touched the KV pool).
    pub shed: u64,
    /// Tenants with queued or running work at the last observation.
    pub tenants_active: usize,
    /// Cumulative worker-loop phase seconds (one sample per turn; the
    /// flight recorder's per-iteration spans are the zoomed-in view).
    /// Intake includes the idle block waiting for the next submission.
    // nbl-lint: gauge(phase_intake_ms)
    pub phase_intake_s: f64,
    /// Admission-phase seconds (probe + whole-prompt/warm prefills).
    // nbl-lint: gauge(phase_admission_ms)
    pub phase_admission_s: f64,
    /// Chunked-prefill-advance seconds (at most one chunk per turn).
    // nbl-lint: gauge(phase_chunked_ms)
    pub phase_chunked_s: f64,
    /// Gauge-refresh/observation seconds.
    // nbl-lint: gauge(phase_observe_ms)
    pub phase_observe_s: f64,
    /// Decode-iteration seconds (draft + verify in spec mode).
    // nbl-lint: gauge(phase_decode_ms)
    pub phase_decode_s: f64,
    /// Gauge lanes contributing to this snapshot: 1 for a single-worker
    /// server, N for a replicated one (set by the rollup, not by any
    /// mutator — a raw per-lane snapshot reports 0).
    pub replicas: usize,
}

impl SchedulerGauges {
    /// Mean occupied fraction of the decode batch per iteration.
    pub fn mean_occupancy(&self) -> f64 {
        if self.bucket_rows == 0 {
            return 0.0;
        }
        self.occupied_rows as f64 / self.bucket_rows as f64
    }

    /// Mean occupied ROWS per iteration (how many requests actually
    /// shared a decode call).
    pub fn mean_rows_per_iteration(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.occupied_rows as f64 / self.iterations as f64
    }

    pub fn kv_utilization(&self) -> f64 {
        if self.kv_capacity == 0 {
            return 0.0;
        }
        self.kv_in_use as f64 / self.kv_capacity as f64
    }

    /// Fraction of draft proposals the target accepted (paper §5: the
    /// driver of the speculative speed-up).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// Mean tokens committed per occupied row per target iteration —
    /// exactly 1.0 for plain continuous decoding, > 1.0 when speculative
    /// verification pays off.
    pub fn tokens_per_row_iteration(&self) -> f64 {
        if self.occupied_rows == 0 {
            return 0.0;
        }
        self.committed_tokens as f64 / self.occupied_rows as f64
    }

    /// Mean decode stall per interfering prefill chunk, in milliseconds
    /// — the per-iteration head-of-line cost chunking bounds (one grid
    /// width instead of a whole long prompt).
    pub fn mean_chunk_stall_ms(&self) -> f64 {
        if self.chunk_stalls == 0 {
            return 0.0;
        }
        self.chunk_stall_s * 1e3 / self.chunk_stalls as f64
    }

    /// Fraction of admission probes that adopted a cached prefix — the
    /// warm-traffic share the prefix cache converts from prefill compute
    /// into a host-side row copy.
    pub fn prefix_hit_rate(&self) -> f64 {
        let probes = self.prefix_hits + self.prefix_misses;
        if probes == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / probes as f64
    }

    /// Token slack trapped in allocated block frames: 1 - live/(frames
    /// * block). Contiguous rows waste `max_ctx - live` per request
    /// instead; the gap between the two is the capacity paging buys.
    pub fn paged_fragmentation(&self) -> f64 {
        let frames = self.blocks_used + self.blocks_shared;
        if frames == 0 || self.paged_block_tokens == 0 {
            return 0.0;
        }
        1.0 - self.blocks_live_tokens as f64 / (frames * self.paged_block_tokens) as f64
    }
}

/// Default raw-timing retention window (`ServerConfig.timing_retention`
/// overrides; 0 = unbounded for offline analysis runs).
pub const DEFAULT_TIMING_RETENTION: usize = 4096;

/// Bounded raw-timing window. Percentile aggregation no longer reads
/// this — it exists for the benches, which slice TTFT by prompt-length
/// class from `timings()`. Oldest entries drop first once the cap is
/// hit, with the drop count surfaced as a gauge.
struct TimingStore {
    items: VecDeque<RequestTiming>,
    cap: usize,
    dropped: u64,
}

/// Lifetime aggregates: O(1)-memory streaming histograms per latency
/// family plus running totals. Never dropped, so the stats endpoint's
/// percentiles cover every request the server ever finished, not just
/// the retained window. `Clone` so `summary()` can snapshot under the
/// lock and compute after releasing it.
#[derive(Clone, Default)]
struct Agg {
    requests: u64,
    generated_tokens: u64,
    wall_s: f64,
    prefill_speed_sum: f64,
    /// requests that carried a deadline (finished, expired, or shed)
    deadline_total: u64,
    /// deadline-carrying requests that finished within budget
    deadline_met: u64,
    /// generated tokens from requests that met (or carried no) deadline
    goodput_tokens: u64,
    ttft: StreamingHistogram,
    itl: StreamingHistogram,
    queue: StreamingHistogram,
    prefill: StreamingHistogram,
    stall: StreamingHistogram,
    park: StreamingHistogram,
    decode_tput: StreamingHistogram,
}

/// Aggregates request timings across the server lifetime.
///
/// Gauges live in per-replica LANES: a single-worker server only ever
/// touches lane 0 (every legacy `note_*` method is a lane-0 shorthand),
/// while a replicated server gives each worker its own lane via the
/// `*_at` variants so the replicas never contend on counter semantics.
/// `gauges()` rolls the lanes up into one [`SchedulerGauges`] — sums
/// for counters and per-replica residency, maxes for observations of
/// shared state (the KV pool is ONE pool observed by every lane).
/// Request timings (`record`) and the lifetime histograms stay
/// hub-global: a finished request is a finished request regardless of
/// which replica served it.
pub struct MetricsHub {
    timings: Mutex<TimingStore>,
    agg: Mutex<Agg>,
    gauges: Mutex<Vec<SchedulerGauges>>,
}

impl Default for MetricsHub {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::with_retention(DEFAULT_TIMING_RETENTION)
    }

    /// `cap` bounds the raw `RequestTiming` window (0 = unbounded).
    pub fn with_retention(cap: usize) -> MetricsHub {
        MetricsHub {
            timings: Mutex::new(TimingStore {
                items: VecDeque::new(),
                cap,
                dropped: 0,
            }),
            agg: Mutex::new(Agg::default()),
            gauges: Mutex::new(vec![SchedulerGauges::default()]),
        }
    }

    /// Run `f` over gauge lane `lane`, growing the lane vector on first
    /// touch (replica workers register themselves implicitly — there is
    /// no separate registration step to forget).
    fn with_lane<R>(&self, lane: usize, f: impl FnOnce(&mut SchedulerGauges) -> R) -> R {
        let mut lanes = lock_unpoisoned(&self.gauges);
        if lanes.len() <= lane {
            lanes.resize_with(lane + 1, SchedulerGauges::default);
        }
        f(&mut lanes[lane])
    }

    /// Pre-register `n` gauge lanes. The dispatcher calls this at spawn
    /// so the `replicas` gauge reports N from the very first stats
    /// scrape instead of growing lazily as lanes are first touched.
    pub fn ensure_lanes(&self, n: usize) {
        if n > 0 {
            self.with_lane(n - 1, |_| {});
        }
    }

    pub fn record(&self, t: RequestTiming) {
        {
            let mut a = lock_unpoisoned(&self.agg);
            a.requests += 1;
            a.generated_tokens += t.generated_tokens as u64;
            a.wall_s += t.total_s;
            a.prefill_speed_sum += t.prefill_speed();
            match t.deadline_met {
                None => a.goodput_tokens += t.generated_tokens as u64,
                Some(met) => {
                    a.deadline_total += 1;
                    if met {
                        a.deadline_met += 1;
                        a.goodput_tokens += t.generated_tokens as u64;
                    }
                }
            }
            a.ttft.record(t.ttft_s);
            a.queue.record(t.queue_s);
            a.prefill.record(t.prefill_s);
            a.stall.record(t.stall_s);
            a.park.record(t.park_s);
            if !t.token_intervals.is_empty() {
                a.decode_tput.record(t.decode_throughput());
            }
            for &dt in &t.token_intervals {
                a.itl.record(dt);
            }
        }
        let mut store = lock_unpoisoned(&self.timings);
        if store.cap > 0 && store.items.len() >= store.cap {
            store.items.pop_front();
            store.dropped += 1;
        }
        store.items.push_back(t);
    }

    /// One decode iteration ran with `occupied` of `bucket` rows live.
    pub fn note_iteration(&self, occupied: usize, bucket: usize) {
        self.note_iteration_at(0, occupied, bucket);
    }

    /// Lane-indexed [`Self::note_iteration`] (replicated workers).
    pub fn note_iteration_at(&self, lane: usize, occupied: usize, bucket: usize) {
        self.with_lane(lane, |g| {
            g.iterations += 1;
            g.occupied_rows += occupied as u64;
            g.bucket_rows += bucket as u64;
            g.peak_rows = g.peak_rows.max(occupied);
        });
    }

    /// `layers` per-layer KvSnapshot expansion copies ran for one warm
    /// adoption (the legacy snapshot restore path; paged splices never
    /// call this, which is exactly what the zero-copy bench asserts).
    pub fn note_prefix_expand(&self, layers: usize) {
        self.note_prefix_expand_at(0, layers);
    }

    /// Lane-indexed [`Self::note_prefix_expand`] (replicated workers).
    pub fn note_prefix_expand_at(&self, lane: usize, layers: usize) {
        self.with_lane(lane, |g| g.prefix_expand_copies += layers as u64);
    }

    /// Mirror the worker-local paged block-pool counters into the
    /// gauges (refreshed once per scheduler iteration, like
    /// `observe_prefix`).
    pub fn observe_paged(&self, s: &crate::kvcache::paged::PagedStats) {
        self.observe_paged_at(0, s);
    }

    /// Lane-indexed [`Self::observe_paged`] (replicated workers).
    pub fn observe_paged_at(&self, lane: usize, s: &crate::kvcache::paged::PagedStats) {
        self.with_lane(lane, |g| {
            g.paged_block_tokens = s.block_tokens;
            g.blocks_capacity = s.capacity_blocks;
            g.blocks_free = s.free_blocks;
            g.blocks_used = s.used_blocks;
            g.blocks_shared = s.shared_blocks;
            g.blocks_live_tokens = s.live_tokens;
            g.cow_copies = s.cow_copies;
            g.preemptions = s.preemptions;
            g.paged_splices = s.splices;
            g.paged_splice_tokens = s.splice_tokens;
        });
    }

    /// `committed` tokens were emitted by the iteration that just ran;
    /// with speculation a single iteration commits 1..=W per row.
    pub fn note_committed(&self, committed: usize) {
        self.note_committed_at(0, committed);
    }

    /// Lane-indexed [`Self::note_committed`] (replicated workers).
    pub fn note_committed_at(&self, lane: usize, committed: usize) {
        self.with_lane(lane, |g| g.committed_tokens += committed as u64);
    }

    /// One speculative verify pass ran: `proposed` draft tokens entered
    /// verification and `accepted` of them matched the target.
    pub fn note_spec_round(&self, proposed: usize, accepted: usize) {
        self.note_spec_round_at(0, proposed, accepted);
    }

    /// Lane-indexed [`Self::note_spec_round`] (replicated workers).
    pub fn note_spec_round_at(&self, lane: usize, proposed: usize, accepted: usize) {
        self.with_lane(lane, |g| {
            g.spec_rounds += 1;
            g.spec_proposed += proposed as u64;
            g.spec_accepted += accepted as u64;
        });
    }

    /// One prefill chunk ran; `stalled` = decode rows were live and
    /// waited `dt_s` seconds for it (the interference gauge).
    pub fn note_prefill_chunk(&self, stalled: bool, dt_s: f64) {
        self.note_prefill_chunk_at(0, stalled, dt_s);
    }

    /// Lane-indexed [`Self::note_prefill_chunk`] (replicated workers).
    pub fn note_prefill_chunk_at(&self, lane: usize, stalled: bool, dt_s: f64) {
        self.with_lane(lane, |g| {
            g.prefill_chunks += 1;
            if stalled {
                g.chunk_stalls += 1;
                g.chunk_stall_s += dt_s;
            }
        });
    }

    /// An admission completed through the multi-chunk prefill machine.
    pub fn note_chunked_admission(&self) {
        self.note_chunked_admission_at(0);
    }

    /// Lane-indexed [`Self::note_chunked_admission`] (replicated workers).
    pub fn note_chunked_admission_at(&self, lane: usize) {
        self.with_lane(lane, |g| g.chunked_admissions += 1);
    }

    /// A request was admitted into a slot (`reused` = the row had served
    /// an earlier, now-finished request).
    pub fn note_admission(&self, reused: bool) {
        self.note_admission_at(0, reused);
    }

    /// Lane-indexed [`Self::note_admission`] (replicated workers).
    pub fn note_admission_at(&self, lane: usize, reused: bool) {
        self.with_lane(lane, |g| {
            g.admissions += 1;
            if reused {
                g.slot_reuses += 1;
            }
        });
    }

    /// Mirror the worker-local prefix-cache counters into the gauges
    /// (refreshed once per scheduler iteration, like `observe` — the
    /// radix tree itself stays single-threaded on the worker).
    pub fn observe_prefix(&self, s: &crate::kvcache::prefix::PrefixStats) {
        self.observe_prefix_at(0, s);
    }

    /// Lane-indexed [`Self::observe_prefix`] (replicated workers — each
    /// replica owns its own radix tree, so the lanes SUM in the rollup).
    pub fn observe_prefix_at(&self, lane: usize, s: &crate::kvcache::prefix::PrefixStats) {
        self.with_lane(lane, |g| {
            g.prefix_hits = s.hits;
            g.prefix_misses = s.misses;
            g.prefix_hit_tokens = s.hit_tokens;
            g.prefix_inserts = s.inserts;
            g.prefix_evictions = s.evictions;
            g.prefix_entries = s.entries;
            g.prefix_bytes = s.bytes_in_use;
            g.prefix_capacity_bytes = s.capacity_bytes;
            g.prefix_publish_skips = s.publish_skips;
        });
    }

    /// A request was aborted by its client (cancel frame or writer-side
    /// disconnect). Cancellations are the client walking away, not an
    /// SLO miss, so they touch no deadline accounting.
    pub fn note_cancelled(&self) {
        self.note_cancelled_at(0);
    }

    /// Lane-indexed [`Self::note_cancelled`] (replicated workers).
    pub fn note_cancelled_at(&self, lane: usize) {
        self.with_lane(lane, |g| g.cancelled += 1);
    }

    /// A deadline-carrying request blew its budget mid-flight and was
    /// terminated; counts as an SLO miss.
    pub fn note_expired(&self) {
        self.note_expired_at(0);
    }

    /// Lane-indexed [`Self::note_expired`] (replicated workers). The
    /// deadline-SLO denominator stays hub-global like `record`.
    pub fn note_expired_at(&self, lane: usize) {
        self.with_lane(lane, |g| g.expired += 1);
        lock_unpoisoned(&self.agg).deadline_total += 1;
    }

    /// A deadline-carrying request was dropped from the intake queue
    /// with its budget already blown; counts as an SLO miss.
    pub fn note_shed(&self) {
        self.note_shed_at(0);
    }

    /// Lane-indexed [`Self::note_shed`] (replicated workers).
    pub fn note_shed_at(&self, lane: usize) {
        self.with_lane(lane, |g| g.shed += 1);
        lock_unpoisoned(&self.agg).deadline_total += 1;
    }

    /// Refresh the point-in-time gauges (queue depth, KV pool state,
    /// tenants with queued or running work).
    pub fn observe(
        &self,
        queue_depth: usize,
        kv_in_use: usize,
        kv_capacity: usize,
        tenants_active: usize,
    ) {
        self.observe_at(0, queue_depth, kv_in_use, kv_capacity, tenants_active);
    }

    /// Lane-indexed [`Self::observe`] (replicated workers). Queue depth
    /// is per-replica (the lanes SUM); the KV numbers observe the ONE
    /// shared pool, so the rollup takes the MAX instead of adding the
    /// same pool N times.
    pub fn observe_at(
        &self,
        lane: usize,
        queue_depth: usize,
        kv_in_use: usize,
        kv_capacity: usize,
        tenants_active: usize,
    ) {
        self.with_lane(lane, |g| {
            g.queue_depth = queue_depth;
            g.kv_in_use = kv_in_use;
            g.kv_capacity = kv_capacity;
            g.tenants_active = tenants_active;
        });
    }

    /// One worker-loop turn finished; charge its phase durations (one
    /// hub lock per turn, not one per phase).
    pub fn note_phases(
        &self,
        intake_s: f64,
        admission_s: f64,
        chunked_s: f64,
        observe_s: f64,
        decode_s: f64,
    ) {
        self.note_phases_at(0, intake_s, admission_s, chunked_s, observe_s, decode_s);
    }

    /// Lane-indexed [`Self::note_phases`] (replicated workers).
    pub fn note_phases_at(
        &self,
        lane: usize,
        intake_s: f64,
        admission_s: f64,
        chunked_s: f64,
        observe_s: f64,
        decode_s: f64,
    ) {
        self.with_lane(lane, |g| {
            g.phase_intake_s += intake_s;
            g.phase_admission_s += admission_s;
            g.phase_chunked_s += chunked_s;
            g.phase_observe_s += observe_s;
            g.phase_decode_s += decode_s;
        });
    }

    /// The aggregate gauge snapshot: lane 0 verbatim for a single-worker
    /// server, the cross-lane rollup for a replicated one. Counters and
    /// per-replica residency SUM; observations of shared or config-fixed
    /// state (the one KV pool, block sizes, per-lane peaks) take the
    /// MAX so N lanes observing the same pool cannot report it N times.
    pub fn gauges(&self) -> SchedulerGauges {
        let lanes = lock_unpoisoned(&self.gauges);
        rollup(&lanes)
    }

    /// Per-lane snapshots, lane index == replica id (replica-level
    /// introspection; `gauges()` is the aggregate the stats endpoint
    /// serves).
    pub fn lane_gauges(&self) -> Vec<SchedulerGauges> {
        lock_unpoisoned(&self.gauges).clone()
    }

    /// Snapshot of the retained request-timing window — benches slice
    /// TTFT by prompt-length class (e.g. p50 TTFT of short requests
    /// admitted behind a long prompt, the number chunked prefill exists
    /// to lower). Bounded by the retention cap; the summary percentiles
    /// come from the lifetime histograms instead.
    pub fn timings(&self) -> Vec<RequestTiming> {
        lock_unpoisoned(&self.timings).items.iter().cloned().collect()
    }

    /// Retained timing count (≤ the retention cap).
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.timings).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Summarize the lifetime aggregates. Percentiles come from the
    /// streaming histograms (±~3% bucket tolerance, exact at 0/1
    /// samples); the snapshot is cloned under the lock and the heavy
    /// quantile walks + JSON serialization happen after release
    /// (no-guard-across-blocking, nbl-lint pass `guard`).
    pub fn summary(&self) -> MetricsSummary {
        let a = { lock_unpoisoned(&self.agg).clone() };
        let (retained, dropped, cap) = {
            let store = lock_unpoisoned(&self.timings);
            (store.items.len(), store.dropped, store.cap)
        };
        MetricsSummary {
            requests: a.requests as usize,
            generated_tokens: a.generated_tokens as usize,
            mean_ttft_s: a.ttft.mean(),
            p50_ttft_s: a.ttft.quantile(50.0),
            p90_ttft_s: a.ttft.quantile(90.0),
            p95_ttft_s: a.ttft.quantile(95.0),
            p99_ttft_s: a.ttft.quantile(99.0),
            p50_itl_s: a.itl.quantile(50.0),
            p95_itl_s: a.itl.quantile(95.0),
            p99_itl_s: a.itl.quantile(99.0),
            mean_prefill_tok_s: if a.requests == 0 {
                0.0
            } else {
                a.prefill_speed_sum / a.requests as f64
            },
            median_decode_tok_s: a.decode_tput.quantile(50.0),
            aggregate_tok_s: a.generated_tokens as f64 / a.wall_s.max(1e-12),
            mean_queue_s: a.queue.mean(),
            p50_queue_s: a.queue.quantile(50.0),
            p95_queue_s: a.queue.quantile(95.0),
            p99_queue_s: a.queue.quantile(99.0),
            mean_prefill_s: a.prefill.mean(),
            p50_prefill_s: a.prefill.quantile(50.0),
            p95_prefill_s: a.prefill.quantile(95.0),
            p99_prefill_s: a.prefill.quantile(99.0),
            mean_stall_s: a.stall.mean(),
            p50_stall_s: a.stall.quantile(50.0),
            p95_stall_s: a.stall.quantile(95.0),
            p99_stall_s: a.stall.quantile(99.0),
            mean_park_s: a.park.mean(),
            p50_park_s: a.park.quantile(50.0),
            p95_park_s: a.park.quantile(95.0),
            p99_park_s: a.park.quantile(99.0),
            timings_retained: retained,
            timings_dropped: dropped,
            timings_capacity: cap,
            goodput_tok_s: a.goodput_tokens as f64 / a.wall_s.max(1e-12),
            slo_attainment: if a.deadline_total == 0 {
                1.0
            } else {
                a.deadline_met as f64 / a.deadline_total as f64
            },
        }
    }
}

/// Fold per-replica gauge lanes into one aggregate snapshot. The loop
/// destructures every field by name (no `..` rest pattern), so adding a
/// gauge without deciding its rollup rule is a compile error, not a
/// silently-zero dashboard column. Rules:
///
///   - counters and per-replica residency SUM (each lane's work is
///     disjoint: own iterations, own slots, own radix tree);
///   - observations of SHARED state take the MAX — every lane observes
///     the one KV pool, so summing would multiply it by N; per-lane
///     peaks also MAX (concurrent peaks across lanes are not sampled
///     at a common instant, so their sum would overclaim);
///   - `replicas` = the lane count itself.
fn rollup(lanes: &[SchedulerGauges]) -> SchedulerGauges {
    let mut out = SchedulerGauges {
        replicas: lanes.len(),
        ..Default::default()
    };
    for g in lanes {
        let SchedulerGauges {
            iterations,
            occupied_rows,
            bucket_rows,
            peak_rows,
            admissions,
            slot_reuses,
            queue_depth,
            kv_in_use,
            kv_capacity,
            committed_tokens,
            prefill_chunks,
            chunked_admissions,
            chunk_stalls,
            chunk_stall_s,
            spec_rounds,
            spec_proposed,
            spec_accepted,
            prefix_hits,
            prefix_misses,
            prefix_hit_tokens,
            prefix_inserts,
            prefix_evictions,
            prefix_entries,
            prefix_bytes,
            prefix_capacity_bytes,
            prefix_publish_skips,
            prefix_expand_copies,
            paged_block_tokens,
            blocks_capacity,
            blocks_free,
            blocks_used,
            blocks_shared,
            blocks_live_tokens,
            cow_copies,
            preemptions,
            paged_splices,
            paged_splice_tokens,
            cancelled,
            expired,
            shed,
            tenants_active,
            phase_intake_s,
            phase_admission_s,
            phase_chunked_s,
            phase_observe_s,
            phase_decode_s,
            replicas: _, // set to 0 on raw lanes; the rollup owns it
        } = g;
        // sums: monotone counters + per-replica residency
        out.iterations += iterations;
        out.occupied_rows += occupied_rows;
        out.bucket_rows += bucket_rows;
        out.admissions += admissions;
        out.slot_reuses += slot_reuses;
        out.queue_depth += queue_depth;
        out.committed_tokens += committed_tokens;
        out.prefill_chunks += prefill_chunks;
        out.chunked_admissions += chunked_admissions;
        out.chunk_stalls += chunk_stalls;
        out.chunk_stall_s += chunk_stall_s;
        out.spec_rounds += spec_rounds;
        out.spec_proposed += spec_proposed;
        out.spec_accepted += spec_accepted;
        out.prefix_hits += prefix_hits;
        out.prefix_misses += prefix_misses;
        out.prefix_hit_tokens += prefix_hit_tokens;
        out.prefix_inserts += prefix_inserts;
        out.prefix_evictions += prefix_evictions;
        out.prefix_entries += prefix_entries;
        out.prefix_bytes += prefix_bytes;
        out.prefix_capacity_bytes += prefix_capacity_bytes;
        out.prefix_publish_skips += prefix_publish_skips;
        out.prefix_expand_copies += prefix_expand_copies;
        out.blocks_used += blocks_used;
        out.blocks_shared += blocks_shared;
        out.blocks_live_tokens += blocks_live_tokens;
        out.cow_copies += cow_copies;
        out.preemptions += preemptions;
        out.paged_splices += paged_splices;
        out.paged_splice_tokens += paged_splice_tokens;
        out.cancelled += cancelled;
        out.expired += expired;
        out.shed += shed;
        out.phase_intake_s += phase_intake_s;
        out.phase_admission_s += phase_admission_s;
        out.phase_chunked_s += phase_chunked_s;
        out.phase_observe_s += phase_observe_s;
        out.phase_decode_s += phase_decode_s;
        // maxes: shared-pool observations and per-lane high-water marks
        out.peak_rows = out.peak_rows.max(*peak_rows);
        out.kv_in_use = out.kv_in_use.max(*kv_in_use);
        out.kv_capacity = out.kv_capacity.max(*kv_capacity);
        out.paged_block_tokens = out.paged_block_tokens.max(*paged_block_tokens);
        out.blocks_capacity = out.blocks_capacity.max(*blocks_capacity);
        out.blocks_free = out.blocks_free.max(*blocks_free);
        out.tenants_active = out.tenants_active.max(*tenants_active);
    }
    out
}

#[derive(Debug, Clone, Default)]
pub struct MetricsSummary {
    /// Lifetime finished-request count (a running counter — NOT bounded
    /// by the timing-retention window).
    pub requests: usize,
    pub generated_tokens: usize,
    pub mean_ttft_s: f64,
    pub p50_ttft_s: f64,
    pub p90_ttft_s: f64,
    pub p95_ttft_s: f64,
    pub p99_ttft_s: f64,
    /// Inter-token latency percentiles over every generated token.
    pub p50_itl_s: f64,
    pub p95_itl_s: f64,
    pub p99_itl_s: f64,
    pub mean_prefill_tok_s: f64,
    pub median_decode_tok_s: f64,
    pub aggregate_tok_s: f64,
    /// TTFT attribution aggregates (queue + prefill + stall == ttft
    /// per request; park is lifetime parking, outside the identity).
    pub mean_queue_s: f64,
    pub p50_queue_s: f64,
    pub p95_queue_s: f64,
    pub p99_queue_s: f64,
    pub mean_prefill_s: f64,
    pub p50_prefill_s: f64,
    pub p95_prefill_s: f64,
    pub p99_prefill_s: f64,
    pub mean_stall_s: f64,
    pub p50_stall_s: f64,
    pub p95_stall_s: f64,
    pub p99_stall_s: f64,
    pub mean_park_s: f64,
    pub p50_park_s: f64,
    pub p95_park_s: f64,
    pub p99_park_s: f64,
    /// Raw-timing window occupancy / overflow / configured cap.
    pub timings_retained: usize,
    pub timings_dropped: u64,
    pub timings_capacity: usize,
    /// Tokens/s from requests that met (or carried no) deadline — the
    /// throughput that actually counted toward client SLOs.
    pub goodput_tok_s: f64,
    /// Met / total over deadline-carrying requests (finished + expired
    /// + shed); 1.0 when no request carried a deadline.
    pub slo_attainment: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_math() {
        let t = RequestTiming {
            prompt_tokens: 100,
            generated_tokens: 3,
            ttft_s: 0.5,
            total_s: 1.0,
            token_intervals: vec![0.1, 0.2, 0.1],
            ..Default::default()
        };
        assert!((t.prefill_speed() - 200.0).abs() < 1e-9);
        assert!((t.decode_throughput() - 10.0).abs() < 1e-9); // median of 10,5,10
    }

    #[test]
    fn stopwatch_attribution_sums_to_ttft() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(3));
        sw.mark_admitted();
        let queued = sw.queue_s();
        assert!(queued >= 0.003);
        sw.mark_admitted(); // idempotent: first call wins
        assert_eq!(sw.queue_s(), queued);
        sw.add_prefill(0.001);
        sw.add_prefill(0.002);
        std::thread::sleep(std::time::Duration::from_millis(4));
        sw.mark_token();
        let t = sw.finish(10, 1);
        assert!((t.queue_s - queued).abs() < 1e-9);
        assert!((t.prefill_s - 0.003).abs() < 1e-9);
        // the identity the regression test in test_serving relies on
        let sum = t.queue_s + t.prefill_s + t.stall_s;
        assert!(
            (sum - t.ttft_s).abs() < 1e-9,
            "queue {} + prefill {} + stall {} != ttft {}",
            t.queue_s,
            t.prefill_s,
            t.stall_s,
            t.ttft_s
        );
        assert!(t.stall_s > 0.0);
        assert_eq!(t.park_s, 0.0);
    }

    #[test]
    fn stopwatch_clamps_degenerate_attribution() {
        // sync path: never admitted, prefill charged over-generously —
        // the identity still holds via clamping
        let mut sw = Stopwatch::new();
        sw.add_prefill(1e9);
        sw.mark_token();
        let t = sw.finish(4, 1);
        assert_eq!(t.queue_s, 0.0);
        assert!((t.queue_s + t.prefill_s + t.stall_s - t.ttft_s).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_tracks_park_episodes() {
        let mut sw = Stopwatch::new();
        sw.mark_token();
        sw.park_begin();
        sw.park_begin(); // nested begin is a no-op
        std::thread::sleep(std::time::Duration::from_millis(3));
        let episode = sw.park_end();
        assert!(episode >= 0.003);
        assert_eq!(sw.park_end(), 0.0, "no open episode");
        sw.park_begin(); // request dies while parked
        std::thread::sleep(std::time::Duration::from_millis(2));
        let t = sw.finish(4, 1);
        assert!(t.park_s >= episode + 0.002, "finish closes the open episode");
        // parking happens post-first-token: outside the TTFT identity
        assert!((t.queue_s + t.prefill_s + t.stall_s - t.ttft_s).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_tracks_first_token() {
        let mut sw = Stopwatch::new();
        sw.mark_token();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.mark_token();
        let t = sw.finish(10, 2);
        assert!(t.ttft_s >= 0.0);
        assert_eq!(t.token_intervals.len(), 1);
        assert!(t.token_intervals[0] >= 0.002);
    }

    #[test]
    fn gauges_track_iterations_and_churn() {
        let hub = MetricsHub::new();
        hub.note_iteration(2, 8);
        hub.note_iteration(6, 8);
        hub.note_admission(false);
        hub.note_admission(true);
        hub.observe(3, 500, 1000, 2);
        let g = hub.gauges();
        assert_eq!(g.iterations, 2);
        assert!((g.mean_occupancy() - 0.5).abs() < 1e-9);
        assert!((g.mean_rows_per_iteration() - 4.0).abs() < 1e-9);
        assert_eq!(g.admissions, 2);
        assert_eq!(g.slot_reuses, 1);
        assert_eq!(g.queue_depth, 3);
        assert_eq!(g.tenants_active, 2);
        assert!((g.kv_utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn lifecycle_counters_and_slo_accounting() {
        let hub = MetricsHub::new();
        // no deadline anywhere: attainment is trivially perfect and all
        // tokens are goodput
        hub.record(RequestTiming {
            generated_tokens: 10,
            total_s: 1.0,
            ..Default::default()
        });
        let s = hub.summary();
        assert!((s.slo_attainment - 1.0).abs() < 1e-12);
        assert!((s.goodput_tok_s - 10.0).abs() < 1e-9);

        // one met, one missed, one expired mid-flight, one shed from the
        // queue: attainment = 1 / 4; only met/no-deadline tokens count
        hub.record(RequestTiming {
            generated_tokens: 8,
            total_s: 1.0,
            deadline_met: Some(true),
            ..Default::default()
        });
        hub.record(RequestTiming {
            generated_tokens: 6,
            total_s: 1.0,
            deadline_met: Some(false),
            ..Default::default()
        });
        hub.note_expired();
        hub.note_shed();
        hub.note_cancelled();
        let s = hub.summary();
        assert!((s.slo_attainment - 0.25).abs() < 1e-12);
        // 10 (no deadline) + 8 (met) over 3s of wall; the missed 6 are
        // excluded from goodput
        assert!((s.goodput_tok_s - 6.0).abs() < 1e-9);
        let g = hub.gauges();
        assert_eq!(g.cancelled, 1);
        assert_eq!(g.expired, 1);
        assert_eq!(g.shed, 1);
    }

    #[test]
    fn mark_tokens_amortizes_the_interval() {
        let mut sw = Stopwatch::new();
        sw.mark_token(); // prefill token: sets TTFT, no interval
        std::thread::sleep(std::time::Duration::from_millis(4));
        sw.mark_tokens(4); // one verify pass committed 4 tokens
        let t = sw.finish(8, 5);
        assert_eq!(t.token_intervals.len(), 4);
        // equal shares of the elapsed window, not 3 near-zero intervals
        let first = t.token_intervals[0];
        assert!(first >= 0.0009);
        for dt in &t.token_intervals {
            assert!((dt - first).abs() < 1e-12);
        }
    }

    #[test]
    fn spec_gauges_track_acceptance_and_commit_rate() {
        let hub = MetricsHub::new();
        // two iterations over 2 occupied rows each; speculation commits
        // more than one token per row-iteration
        hub.note_iteration(2, 8);
        hub.note_spec_round(6, 4);
        hub.note_committed(6); // 4 accepted + 2 corrections
        hub.note_iteration(2, 8);
        hub.note_spec_round(6, 2);
        hub.note_committed(4);
        let g = hub.gauges();
        assert_eq!(g.spec_rounds, 2);
        assert_eq!(g.spec_proposed, 12);
        assert_eq!(g.spec_accepted, 6);
        assert_eq!(g.committed_tokens, 10);
        assert!((g.acceptance_rate() - 0.5).abs() < 1e-9);
        assert!((g.tokens_per_row_iteration() - 2.5).abs() < 1e-9);
        // plain decoding commits exactly one token per row-iteration
        let plain = MetricsHub::new();
        plain.note_iteration(3, 8);
        plain.note_committed(3);
        let p = plain.gauges();
        assert!((p.tokens_per_row_iteration() - 1.0).abs() < 1e-9);
        assert_eq!(p.acceptance_rate(), 0.0);
    }

    #[test]
    fn chunk_gauges_track_stall_time() {
        let hub = MetricsHub::new();
        hub.note_prefill_chunk(false, 0.050); // admission ramp: no decode live
        hub.note_prefill_chunk(true, 0.010);
        hub.note_prefill_chunk(true, 0.030);
        hub.note_chunked_admission();
        let g = hub.gauges();
        assert_eq!(g.prefill_chunks, 3);
        assert_eq!(g.chunked_admissions, 1);
        assert_eq!(g.chunk_stalls, 2);
        assert!((g.chunk_stall_s - 0.040).abs() < 1e-12);
        assert!((g.mean_chunk_stall_ms() - 20.0).abs() < 1e-9);
        // no interfering chunks -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().mean_chunk_stall_ms(), 0.0);
    }

    #[test]
    fn prefix_gauges_mirror_cache_stats() {
        let hub = MetricsHub::new();
        let s = crate::kvcache::prefix::PrefixStats {
            hits: 6,
            misses: 2,
            hit_tokens: 384,
            inserts: 5,
            evictions: 1,
            publish_skips: 3,
            entries: 4,
            bytes_in_use: 4096,
            capacity_bytes: 8192,
        };
        hub.observe_prefix(&s);
        let g = hub.gauges();
        assert_eq!(g.prefix_hits, 6);
        assert_eq!(g.prefix_misses, 2);
        assert_eq!(g.prefix_hit_tokens, 384);
        assert_eq!(g.prefix_inserts, 5);
        assert_eq!(g.prefix_evictions, 1);
        assert_eq!(g.prefix_publish_skips, 3);
        assert_eq!(g.prefix_entries, 4);
        assert_eq!(g.prefix_bytes, 4096);
        assert_eq!(g.prefix_capacity_bytes, 8192);
        assert!((g.prefix_hit_rate() - 0.75).abs() < 1e-9);
        // no probes -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().prefix_hit_rate(), 0.0);
    }

    #[test]
    fn paged_gauges_mirror_pool_stats() {
        let hub = MetricsHub::new();
        let s = crate::kvcache::paged::PagedStats {
            block_tokens: 64,
            capacity_blocks: 32,
            free_blocks: 20,
            used_blocks: 8,
            shared_blocks: 4,
            live_tokens: 576,
            cow_copies: 2,
            preemptions: 1,
            splices: 4,
            splice_tokens: 512,
        };
        hub.observe_paged(&s);
        hub.note_prefix_expand(6);
        hub.note_prefix_expand(6);
        let g = hub.gauges();
        assert_eq!(g.paged_block_tokens, 64);
        assert_eq!(g.blocks_capacity, 32);
        assert_eq!(g.blocks_free, 20);
        assert_eq!(g.blocks_used, 8);
        assert_eq!(g.blocks_shared, 4);
        assert_eq!(g.blocks_live_tokens, 576);
        assert_eq!(g.cow_copies, 2);
        assert_eq!(g.preemptions, 1);
        assert_eq!(g.paged_splices, 4);
        assert_eq!(g.paged_splice_tokens, 512);
        assert_eq!(g.prefix_expand_copies, 12);
        // 576 live of 12 frames * 64 tokens -> 25% slack
        assert!((g.paged_fragmentation() - 0.25).abs() < 1e-9);
        // no frames -> a well-defined zero, not NaN
        assert_eq!(MetricsHub::new().gauges().paged_fragmentation(), 0.0);
    }

    #[test]
    fn peak_rows_tracks_the_high_water_mark() {
        let hub = MetricsHub::new();
        hub.note_iteration(2, 8);
        hub.note_iteration(6, 8);
        hub.note_iteration(3, 8);
        assert_eq!(hub.gauges().peak_rows, 6);
    }

    #[test]
    fn summary_percentiles_cover_ttft_and_itl() {
        let hub = MetricsHub::new();
        for i in 0..10 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 3,
                ttft_s: 0.01 * (i + 1) as f64,
                total_s: 0.5,
                token_intervals: vec![0.01, 0.02],
                ..Default::default()
            });
        }
        let s = hub.summary();
        // histogram-backed percentiles report a bucket representative
        // (±~3.3%) of a sample at the rank, without the raw path's
        // between-sample interpolation: the p50 of 0.01..=0.10 lands on
        // the 0.05 or 0.06 sample rather than exactly 0.055
        assert!((0.045..=0.066).contains(&s.p50_ttft_s), "p50 {}", s.p50_ttft_s);
        assert!(s.p95_ttft_s > s.p50_ttft_s);
        assert!(s.p99_ttft_s >= s.p95_ttft_s);
        assert!(s.p99_ttft_s <= 0.1 + 1e-9, "max clamp bounds p99");
        // ITL is flattened over tokens: half 0.01, half 0.02 — the
        // median sits on either mode depending on rank convention
        assert!((0.0095..=0.021).contains(&s.p50_itl_s), "p50 itl {}", s.p50_itl_s);
        assert!((s.p99_itl_s - 0.02).abs() / 0.02 < 0.05);
    }

    #[test]
    fn hub_aggregates() {
        let hub = MetricsHub::new();
        for _ in 0..3 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 5,
                ttft_s: 0.1,
                total_s: 0.6,
                token_intervals: vec![0.1; 4],
                ..Default::default()
            });
        }
        let s = hub.summary();
        assert_eq!(s.requests, 3);
        assert_eq!(s.generated_tokens, 15);
        // means and totals stay exact (sums, not histograms)
        assert!((s.mean_prefill_tok_s - 100.0).abs() < 1e-9);
        // the histogram-backed median is within bucket tolerance
        assert!((s.median_decode_tok_s - 10.0).abs() / 10.0 < 0.05);
        assert!((s.aggregate_tok_s - 15.0 / 1.8).abs() < 1e-9);
    }

    #[test]
    fn attribution_percentiles_surface_in_summary() {
        let hub = MetricsHub::new();
        for i in 0..8 {
            hub.record(RequestTiming {
                prompt_tokens: 10,
                generated_tokens: 2,
                ttft_s: 0.1,
                total_s: 0.2,
                queue_s: 0.04,
                prefill_s: 0.05,
                stall_s: 0.01,
                park_s: if i % 2 == 0 { 0.02 } else { 0.0 },
                token_intervals: vec![0.05],
                ..Default::default()
            });
        }
        let s = hub.summary();
        assert!((s.mean_queue_s - 0.04).abs() < 1e-9, "means are exact");
        assert!((s.p50_queue_s - 0.04).abs() / 0.04 < 0.05);
        assert!((s.p95_prefill_s - 0.05).abs() / 0.05 < 0.05);
        assert!((s.mean_stall_s - 0.01).abs() < 1e-9);
        assert!((s.mean_park_s - 0.01).abs() < 1e-9);
        assert!(s.p99_park_s > 0.0);
    }

    #[test]
    fn timing_retention_is_bounded_and_counted() {
        let hub = MetricsHub::with_retention(4);
        for i in 0..10 {
            hub.record(RequestTiming {
                prompt_tokens: i,
                generated_tokens: 1,
                ttft_s: 0.01,
                total_s: 0.02,
                token_intervals: vec![0.01],
                ..Default::default()
            });
        }
        assert_eq!(hub.len(), 4);
        let kept: Vec<usize> = hub.timings().iter().map(|t| t.prompt_tokens).collect();
        assert_eq!(kept, vec![6, 7, 8, 9], "oldest entries drop first");
        let s = hub.summary();
        // the lifetime aggregates are NOT bounded by the window
        assert_eq!(s.requests, 10);
        assert_eq!(s.generated_tokens, 10);
        assert_eq!(s.timings_retained, 4);
        assert_eq!(s.timings_dropped, 6);
        assert_eq!(s.timings_capacity, 4);
        // cap 0 = unbounded
        let unbounded = MetricsHub::with_retention(0);
        for _ in 0..10 {
            unbounded.record(RequestTiming::default());
        }
        assert_eq!(unbounded.len(), 10);
        assert_eq!(unbounded.summary().timings_dropped, 0);
    }

    #[test]
    fn gauge_lanes_roll_up_sums_and_maxes() {
        let hub = MetricsHub::new();
        // two replica lanes: counters sum, shared-pool observations max
        hub.note_iteration_at(0, 2, 8);
        hub.note_iteration_at(1, 6, 8);
        hub.note_committed_at(0, 2);
        hub.note_committed_at(1, 6);
        hub.note_admission_at(0, false);
        hub.note_admission_at(1, true);
        hub.note_cancelled_at(1);
        hub.note_phases_at(0, 0.1, 0.0, 0.0, 0.0, 0.2);
        hub.note_phases_at(1, 0.3, 0.0, 0.0, 0.0, 0.4);
        // both lanes observe the SAME shared pool; their own queues differ
        hub.observe_at(0, 3, 500, 1000, 1);
        hub.observe_at(1, 2, 700, 1000, 2);
        let g = hub.gauges();
        assert_eq!(g.replicas, 2);
        assert_eq!(g.iterations, 2);
        assert_eq!(g.occupied_rows, 8);
        assert_eq!(g.committed_tokens, 8);
        assert_eq!(g.admissions, 2);
        assert_eq!(g.slot_reuses, 1);
        assert_eq!(g.cancelled, 1);
        assert_eq!(g.queue_depth, 5, "per-replica queues sum");
        assert_eq!(g.peak_rows, 6, "per-lane peaks max");
        assert_eq!(g.kv_in_use, 700, "shared pool maxes, never doubles");
        assert_eq!(g.kv_capacity, 1000);
        assert_eq!(g.tenants_active, 2);
        assert!((g.phase_intake_s - 0.4).abs() < 1e-12);
        assert!((g.phase_decode_s - 0.6).abs() < 1e-12);
        // per-lane snapshots stay raw (replicas unset, own counters only)
        let lanes = hub.lane_gauges();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].replicas, 0);
        assert_eq!(lanes[0].occupied_rows, 2);
        assert_eq!(lanes[1].occupied_rows, 6);
    }

    #[test]
    fn single_lane_rollup_is_identity() {
        // a single-worker hub reports exactly what lane 0 holds, plus
        // replicas == 1 — the N=1 path is byte-identical in every gauge
        let hub = MetricsHub::new();
        hub.note_iteration(4, 8);
        hub.note_spec_round(6, 3);
        hub.observe(1, 256, 1024, 1);
        let g = hub.gauges();
        let lane0 = &hub.lane_gauges()[0];
        assert_eq!(g.replicas, 1);
        assert_eq!(g.iterations, lane0.iterations);
        assert_eq!(g.occupied_rows, lane0.occupied_rows);
        assert_eq!(g.spec_proposed, lane0.spec_proposed);
        assert_eq!(g.kv_in_use, lane0.kv_in_use);
        assert_eq!(g.queue_depth, lane0.queue_depth);
    }

    #[test]
    fn phase_gauges_accumulate_per_turn() {
        let hub = MetricsHub::new();
        hub.note_phases(0.5, 0.01, 0.002, 0.001, 0.08);
        hub.note_phases(0.1, 0.0, 0.0, 0.001, 0.07);
        let g = hub.gauges();
        assert!((g.phase_intake_s - 0.6).abs() < 1e-12);
        assert!((g.phase_admission_s - 0.01).abs() < 1e-12);
        assert!((g.phase_chunked_s - 0.002).abs() < 1e-12);
        assert!((g.phase_observe_s - 0.002).abs() < 1e-12);
        assert!((g.phase_decode_s - 0.15).abs() < 1e-12);
    }
}
