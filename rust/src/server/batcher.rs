//! Request admission: the iteration-level [`Scheduler`] (continuous
//! batching with deficit-round-robin tenant fairness, DESIGN.md
//! §Serving and §Streaming front end) and the legacy exact-length
//! [`Batcher`] (the lockstep run-to-completion baseline the benches
//! compare against).
//!
//! The scheduler is FIFO *within* a tenant and weighted-round-robin
//! *across* tenants: each non-empty tenant queue gets up to `weight`
//! admissions per rotation, so a bulk tenant flooding the intake cannot
//! starve an interactive one, and every non-empty queue advances at
//! least once per round (no starvation by construction; see the
//! property test in tests/test_serving.rs). With a single tenant the
//! policy degenerates to the original strict FIFO. The batcher is
//! strictly FIFO at the head and forms whole same-length groups.

use std::collections::VecDeque;

use crate::kvcache::KvPool;
use crate::server::api::GenRequest;

/// One tenant's FIFO lane inside the DRR rotation.
struct TenantQueue {
    name: String,
    /// Admissions this tenant may take per rotation (DRR quantum with a
    /// unit cost per request). Refreshed from the most recent request so
    /// clients can re-weight a tenant without restarting the server.
    weight: u64,
    queue: VecDeque<GenRequest>,
}

/// Iteration-level admission queue for continuous batching.
///
/// Head-of-queue discipline per tenant: `next_admission` only ever pops
/// the front of the *currently selected* tenant queue, and only when a
/// decode slot is free AND the request's KV-slot bytes fit the pool
/// budget. `head()` always names the one request `next_admission` would
/// pop, so the worker's peek-then-pop pattern (chunked-prefill slip
/// test, starvation drain) stays race-free.
pub struct Scheduler {
    tenants: Vec<TenantQueue>,
    /// Rotation position: index of the tenant currently being served.
    current: usize,
    /// Admissions the current tenant may still take this rotation.
    credits: u64,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler { tenants: Vec::new(), current: 0, credits: 0 }
    }

    fn tenant_index(&mut self, name: &str, weight: u64) -> usize {
        if let Some(i) = self.tenants.iter().position(|t| t.name == name) {
            self.tenants[i].weight = weight.max(1);
            return i;
        }
        self.tenants.push(TenantQueue {
            name: name.to_string(),
            weight: weight.max(1),
            queue: VecDeque::new(),
        });
        self.tenants.len() - 1
    }

    /// Re-establish the invariant behind `head()`: whenever any request
    /// is queued, `current` points at a non-empty tenant queue with at
    /// least one credit left. Advances the rotation (refreshing credits
    /// from the tenant's weight) when the current lane is empty or out
    /// of credits.
    fn fix_current(&mut self) {
        let n = self.tenants.len();
        if n == 0 || self.waiting() == 0 {
            self.credits = 0;
            return;
        }
        if self.current < n && !self.tenants[self.current].queue.is_empty() && self.credits > 0 {
            return;
        }
        let start = if self.current < n { self.current } else { 0 };
        for step in 1..=n {
            let i = (start + step) % n;
            if !self.tenants[i].queue.is_empty() {
                self.current = i;
                self.credits = self.tenants[i].weight.max(1);
                return;
            }
        }
    }

    pub fn push(&mut self, req: GenRequest) {
        let i = self.tenant_index(&req.tenant.clone(), req.weight);
        self.tenants[i].queue.push_back(req);
        self.fix_current();
    }

    /// Put a request back at the head of its tenant's lane (admission
    /// raced with another pool user and lost — retry next iteration,
    /// still oldest-first). The rotation snaps back to that tenant and
    /// the spent credit is refunded, so a lost race costs no fairness.
    pub fn push_front(&mut self, req: GenRequest) {
        let i = self.tenant_index(&req.tenant.clone(), req.weight);
        self.tenants[i].queue.push_front(req);
        self.current = i;
        self.credits = self.credits.saturating_add(1);
    }

    /// The request `next_admission` would pop right now — the front of
    /// the DRR-selected tenant queue. The worker peeks it to decide
    /// whether the head must wait for the in-flight chunked prefill
    /// (multi-chunk prompts run one machine at a time) before popping
    /// anything.
    pub fn head(&self) -> Option<&GenRequest> {
        self.tenants.get(self.current).and_then(|t| t.queue.front())
    }

    pub fn waiting(&self) -> usize {
        self.tenants.iter().map(|t| t.queue.len()).sum()
    }

    /// Number of tenants with at least one queued request.
    pub fn waiting_tenants(&self) -> usize {
        self.tenants.iter().filter(|t| !t.queue.is_empty()).count()
    }

    /// Names of tenants with queued work (for the tenants_active gauge,
    /// unioned with the tenants of running slots by the caller).
    pub fn tenant_names(&self) -> impl Iterator<Item = &str> {
        self.tenants
            .iter()
            .filter(|t| !t.queue.is_empty())
            .map(|t| t.name.as_str())
    }

    /// The DRR-selected request, if one can be admitted right now.
    pub fn next_admission(
        &mut self,
        free_slots: usize,
        pool: &KvPool,
        slot_bytes: usize,
    ) -> Option<GenRequest> {
        if free_slots == 0 || self.waiting() == 0 || !pool.would_fit(slot_bytes) {
            return None;
        }
        let req = self.tenants.get_mut(self.current)?.queue.pop_front()?;
        self.credits = self.credits.saturating_sub(1);
        self.fix_current();
        Some(req)
    }

    /// Remove a queued request by id (client cancelled before
    /// admission). Returns it so the caller can respond.
    pub fn remove(&mut self, id: u64) -> Option<GenRequest> {
        for t in &mut self.tenants {
            if let Some(pos) = t.queue.iter().position(|r| r.id == id) {
                let req = t.queue.remove(pos);
                self.fix_current();
                return req;
            }
        }
        None
    }

    /// Remove every queued request matching `expired` (deadline already
    /// blown pre-admission — the shed path). Returns them oldest-first
    /// per tenant so each still gets its typed error response.
    pub fn shed_expired(&mut self, expired: impl Fn(&GenRequest) -> bool) -> Vec<GenRequest> {
        let mut out = Vec::new();
        for t in &mut self.tenants {
            let mut kept = VecDeque::with_capacity(t.queue.len());
            for r in t.queue.drain(..) {
                if expired(&r) {
                    out.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            t.queue = kept;
        }
        if !out.is_empty() {
            self.fix_current();
        }
        out
    }

    /// Drain every queued request (shutdown path: each one still gets a
    /// response).
    pub fn drain(&mut self) -> Vec<GenRequest> {
        let mut out = Vec::new();
        for t in &mut self.tenants {
            out.extend(t.queue.drain(..));
        }
        self.credits = 0;
        out
    }
}

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1) }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Form the next group: the queue head plus all same-length requests
    /// behind it (up to max_batch), preserving FIFO among the rest.
    pub fn next_group(&mut self) -> Option<Vec<GenRequest>> {
        let head = self.queue.pop_front()?;
        let len = head.prompt.len();
        let mut group = vec![head];
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop_front() {
            if group.len() < self.max_batch && r.prompt.len() == len {
                group.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(group)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SamplingParams;

    fn req(id: u64, len: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: vec![1; len],
            max_new_tokens: 4,
            params: SamplingParams::greedy(),
            tenant: String::new(),
            weight: 1,
            deadline_ms: None,
            stream: false,
        }
    }

    fn tenant_req(id: u64, tenant: &str, weight: u64) -> GenRequest {
        GenRequest {
            tenant: tenant.into(),
            weight,
            ..req(id, 8)
        }
    }

    #[test]
    fn groups_same_length_fifo() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 16), (3, 8), (4, 8), (5, 16)] {
            b.push(req(id, len));
        }
        let g1 = b.next_group().unwrap();
        assert_eq!(g1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let g2 = b.next_group().unwrap();
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 5]);
        assert!(b.next_group().is_none());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.push(req(id, 8));
        }
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 1);
    }

    #[test]
    fn head_is_never_starved() {
        let mut b = Batcher::new(8);
        b.push(req(1, 10)); // lonely length
        for id in 2..10 {
            b.push(req(id, 32));
        }
        // head defines the group even though length-32 is more popular
        let g = b.next_group().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 1);
    }

    #[test]
    fn scheduler_admits_head_only_when_slot_and_budget_allow() {
        let pool = KvPool::new(100);
        let mut s = Scheduler::new();
        s.push(req(1, 8));
        s.push(req(2, 16));
        assert!(s.next_admission(0, &pool, 10).is_none(), "no free slot");
        assert!(s.next_admission(1, &pool, 200).is_none(), "over budget");
        assert_eq!(s.waiting(), 2);
        let a = s.next_admission(1, &pool, 60).unwrap();
        assert_eq!(a.id, 1, "strict FIFO: head first");
        let _lease = pool.reserve(60).unwrap();
        assert!(s.next_admission(4, &pool, 60).is_none(), "budget consumed");
        // losing a race puts the request back at the head
        s.push_front(a);
        assert_eq!(s.waiting(), 2);
        drop(_lease);
        assert_eq!(s.next_admission(1, &pool, 60).unwrap().id, 1);
    }

    #[test]
    fn drr_interleaves_tenants_by_weight() {
        let pool = KvPool::new(1 << 30);
        let mut s = Scheduler::new();
        for id in 0..6 {
            s.push(tenant_req(id, "bulk", 1));
        }
        for id in 10..13 {
            s.push(tenant_req(id, "live", 2));
        }
        // rotation: 1 bulk admission, then 2 live, repeating while both
        // lanes are non-empty; bulk drains its backlog only after live
        // is idle — live is never stuck behind the bulk flood
        let mut order = Vec::new();
        while let Some(r) = s.next_admission(1, &pool, 0) {
            order.push(r.id);
        }
        assert_eq!(order, vec![0, 10, 11, 1, 12, 2, 3, 4, 5]);
        assert_eq!(s.waiting(), 0);
    }

    #[test]
    fn head_always_names_the_next_admission() {
        let pool = KvPool::new(1 << 30);
        let mut s = Scheduler::new();
        for id in 0..4 {
            s.push(tenant_req(id, "bulk", 1));
        }
        for id in 10..12 {
            s.push(tenant_req(id, "live", 3));
        }
        while s.waiting() > 0 {
            let peeked = s.head().map(|r| r.id);
            let popped = s.next_admission(1, &pool, 0).map(|r| r.id);
            assert_eq!(peeked, popped, "peek-then-pop must agree");
        }
        assert!(s.head().is_none());
    }

    #[test]
    fn push_front_refunds_the_lost_race() {
        let pool = KvPool::new(1 << 30);
        let mut s = Scheduler::new();
        s.push(tenant_req(1, "bulk", 1));
        s.push(tenant_req(2, "live", 1));
        let a = s.next_admission(1, &pool, 0).unwrap();
        assert_eq!(a.id, 1);
        // the admission lost a pool race: the request goes back to the
        // head of its own lane and is the next head again
        s.push_front(a);
        assert_eq!(s.head().unwrap().id, 1);
        assert_eq!(s.next_admission(1, &pool, 0).unwrap().id, 1);
        assert_eq!(s.next_admission(1, &pool, 0).unwrap().id, 2);
    }

    #[test]
    fn remove_and_shed_drop_queued_requests() {
        let mut s = Scheduler::new();
        for id in 0..3 {
            s.push(tenant_req(id, "bulk", 1));
        }
        s.push({
            let mut r = tenant_req(7, "live", 1);
            r.deadline_ms = Some(5);
            r
        });
        assert_eq!(s.waiting_tenants(), 2);
        assert_eq!(s.remove(1).unwrap().id, 1);
        assert!(s.remove(99).is_none());
        let shed = s.shed_expired(|r| r.deadline_ms.is_some());
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![7]);
        assert_eq!(s.waiting(), 2);
        assert_eq!(s.waiting_tenants(), 1);
        assert_eq!(s.tenant_names().collect::<Vec<_>>(), vec!["bulk"]);
    }

    #[test]
    fn scheduler_drain_empties_queue() {
        let mut s = Scheduler::new();
        for id in 0..4 {
            s.push(req(id, 8));
        }
        let drained = s.drain();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(s.waiting(), 0);
    }

    #[test]
    fn preserves_order_of_leftovers() {
        let mut b = Batcher::new(8);
        b.push(req(1, 8));
        b.push(req(2, 16));
        b.push(req(3, 24));
        let _ = b.next_group();
        let g2 = b.next_group().unwrap();
        assert_eq!(g2[0].id, 2);
    }
}
