//! Batch-group formation: FIFO admission with exact-length grouping.
//!
//! Requests in a group share the prefill bucket and decode position
//! (DESIGN.md), so a group = requests with identical prompt length, up to
//! `max_batch`. The batcher favours the oldest waiting request (no
//! starvation: groups are seeded by the queue head, never by popularity).

use std::collections::VecDeque;

use crate::server::api::GenRequest;

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1) }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Form the next group: the queue head plus all same-length requests
    /// behind it (up to max_batch), preserving FIFO among the rest.
    pub fn next_group(&mut self) -> Option<Vec<GenRequest>> {
        let head = self.queue.pop_front()?;
        let len = head.prompt.len();
        let mut group = vec![head];
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop_front() {
            if group.len() < self.max_batch && r.prompt.len() == len {
                group.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(group)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SamplingParams;

    fn req(id: u64, len: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: vec![1; len],
            max_new_tokens: 4,
            params: SamplingParams::greedy(),
        }
    }

    #[test]
    fn groups_same_length_fifo() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 16), (3, 8), (4, 8), (5, 16)] {
            b.push(req(id, len));
        }
        let g1 = b.next_group().unwrap();
        assert_eq!(g1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let g2 = b.next_group().unwrap();
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 5]);
        assert!(b.next_group().is_none());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.push(req(id, 8));
        }
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 1);
    }

    #[test]
    fn head_is_never_starved() {
        let mut b = Batcher::new(8);
        b.push(req(1, 10)); // lonely length
        for id in 2..10 {
            b.push(req(id, 32));
        }
        // head defines the group even though length-32 is more popular
        let g = b.next_group().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 1);
    }

    #[test]
    fn preserves_order_of_leftovers() {
        let mut b = Batcher::new(8);
        b.push(req(1, 8));
        b.push(req(2, 16));
        b.push(req(3, 24));
        let _ = b.next_group();
        let g2 = b.next_group().unwrap();
        assert_eq!(g2[0].id, 2);
    }
}
