//! Request admission: the iteration-level [`Scheduler`] (continuous
//! batching, DESIGN.md §Serving) and the legacy exact-length [`Batcher`]
//! (the lockstep run-to-completion baseline the benches compare against).
//!
//! Both are strictly FIFO at the head — the oldest waiting request is
//! always served first, so neither can starve a request. The scheduler
//! admits one request at a time into a free KV *slot* whenever the pool
//! budget allows; the batcher forms whole same-length groups.

use std::collections::VecDeque;

use crate::kvcache::KvPool;
use crate::server::api::GenRequest;

/// Iteration-level admission queue for continuous batching.
///
/// Head-of-queue discipline: `next_admission` only ever pops the front,
/// and only when a decode slot is free AND the request's KV-slot bytes
/// fit the pool budget. A head that does not fit blocks younger requests
/// (FIFO fairness — no starvation by construction; see the property test
/// in tests/test_serving.rs).
pub struct Scheduler {
    queue: VecDeque<GenRequest>,
}

impl Default for Scheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler { queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    /// Put a request back at the head (admission raced with another pool
    /// user and lost — retry next iteration, still oldest-first).
    pub fn push_front(&mut self, req: GenRequest) {
        self.queue.push_front(req);
    }

    /// The oldest waiting request — the only admissible one under the
    /// head-of-queue discipline. The worker peeks it to decide whether
    /// the head must wait for the in-flight chunked prefill (multi-chunk
    /// prompts run one machine at a time) before popping anything.
    pub fn head(&self) -> Option<&GenRequest> {
        self.queue.front()
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Oldest waiting request, if one can be admitted right now.
    pub fn next_admission(
        &mut self,
        free_slots: usize,
        pool: &KvPool,
        slot_bytes: usize,
    ) -> Option<GenRequest> {
        if free_slots == 0 || self.queue.is_empty() || !pool.would_fit(slot_bytes) {
            return None;
        }
        self.queue.pop_front()
    }

    /// Drain every queued request (shutdown path: each one still gets a
    /// response).
    pub fn drain(&mut self) -> Vec<GenRequest> {
        self.queue.drain(..).collect()
    }
}

pub struct Batcher {
    queue: VecDeque<GenRequest>,
    max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { queue: VecDeque::new(), max_batch: max_batch.max(1) }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Form the next group: the queue head plus all same-length requests
    /// behind it (up to max_batch), preserving FIFO among the rest.
    pub fn next_group(&mut self) -> Option<Vec<GenRequest>> {
        let head = self.queue.pop_front()?;
        let len = head.prompt.len();
        let mut group = vec![head];
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop_front() {
            if group.len() < self.max_batch && r.prompt.len() == len {
                group.push(r);
            } else {
                rest.push_back(r);
            }
        }
        self.queue = rest;
        Some(group)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::SamplingParams;

    fn req(id: u64, len: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: vec![1; len],
            max_new_tokens: 4,
            params: SamplingParams::greedy(),
        }
    }

    #[test]
    fn groups_same_length_fifo() {
        let mut b = Batcher::new(4);
        for (id, len) in [(1, 8), (2, 16), (3, 8), (4, 8), (5, 16)] {
            b.push(req(id, len));
        }
        let g1 = b.next_group().unwrap();
        assert_eq!(g1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let g2 = b.next_group().unwrap();
        assert_eq!(g2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 5]);
        assert!(b.next_group().is_none());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for id in 0..5 {
            b.push(req(id, 8));
        }
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 2);
        assert_eq!(b.next_group().unwrap().len(), 1);
    }

    #[test]
    fn head_is_never_starved() {
        let mut b = Batcher::new(8);
        b.push(req(1, 10)); // lonely length
        for id in 2..10 {
            b.push(req(id, 32));
        }
        // head defines the group even though length-32 is more popular
        let g = b.next_group().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].id, 1);
    }

    #[test]
    fn scheduler_admits_head_only_when_slot_and_budget_allow() {
        let pool = KvPool::new(100);
        let mut s = Scheduler::new();
        s.push(req(1, 8));
        s.push(req(2, 16));
        assert!(s.next_admission(0, &pool, 10).is_none(), "no free slot");
        assert!(s.next_admission(1, &pool, 200).is_none(), "over budget");
        assert_eq!(s.waiting(), 2);
        let a = s.next_admission(1, &pool, 60).unwrap();
        assert_eq!(a.id, 1, "strict FIFO: head first");
        let _lease = pool.reserve(60).unwrap();
        assert!(s.next_admission(4, &pool, 60).is_none(), "budget consumed");
        // losing a race puts the request back at the head
        s.push_front(a);
        assert_eq!(s.waiting(), 2);
        drop(_lease);
        assert_eq!(s.next_admission(1, &pool, 60).unwrap().id, 1);
    }

    #[test]
    fn scheduler_drain_empties_queue() {
        let mut s = Scheduler::new();
        for id in 0..4 {
            s.push(req(id, 8));
        }
        let drained = s.drain();
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(s.waiting(), 0);
    }

    #[test]
    fn preserves_order_of_leftovers() {
        let mut b = Batcher::new(8);
        b.push(req(1, 8));
        b.push(req(2, 16));
        b.push(req(3, 24));
        let _ = b.next_group();
        let g2 = b.next_group().unwrap();
        assert_eq!(g2[0].id, 2);
    }
}
