//! The serving engine (L3 coordinator proper): request router,
//! iteration-level scheduler, generation loop, TCP front-end and metrics.
//!
//! Shape: a vLLM-style engine scaled to this paper's evaluation protocol
//! (§4.1: prefill speed = context tokens / TTFT; throughput = median
//! generated tokens/s; batch size 1 for the headline numbers, batched
//! load for the serving benches). The default worker runs continuous
//! batching (DESIGN.md §Serving): each request owns a KV *slot* in a
//! fixed decode arena, the scheduler admits the oldest waiting request
//! whenever a slot and the KV budget allow, and every decode iteration
//! advances whatever mix of requests is resident — any prompt lengths,
//! joining and leaving mid-flight. With `ServerConfig.spec` set the
//! iterations are self-speculative draft-and-verify (paper §5: NBL
//! composes with speculative decoding), committing up to W tokens per
//! row per target pass. The legacy exact-length lockstep protocol
//! (`run_group` + `Batcher`) is kept as the benches' baseline.

pub mod api;
pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod service;
pub mod tcp;
pub mod trace;

pub use api::{GenRequest, GenResponse};
pub use batcher::{Batcher, Scheduler};
pub use metrics::{MetricsHub, RequestTiming, SchedulerGauges};
pub use service::{BatchMode, Server, ServerConfig, SpecConfig};
pub use trace::{SpanKind, TraceRecorder, TraceStats};
