//! The serving engine (L3 coordinator proper): request router,
//! batch-group scheduler, generation loop, TCP front-end and metrics.
//!
//! Shape: a vLLM-style engine scaled to this paper's evaluation protocol
//! (§4.1: prefill speed = context tokens / TTFT; throughput = median
//! generated tokens/s; batch size 1 for the headline numbers, batched
//! groups for the load benches). Requests are grouped by exact prompt
//! length (groups share the decode position — see DESIGN.md), prefilled
//! once, then decoded in lockstep until every member finishes.

pub mod api;
pub mod batcher;
pub mod metrics;
pub mod service;
pub mod tcp;

pub use api::{GenRequest, GenResponse};
pub use batcher::Batcher;
pub use metrics::{MetricsHub, RequestTiming};
pub use service::{Server, ServerConfig};
