//! TCP front-end: newline-delimited JSON requests/responses, with
//! opt-in per-token streaming (DESIGN.md §Streaming front end).
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": "the small robot ", "max_tokens": 32}
//!   <- {"id": 1, "text": "...", "tokens": [...], "ttft_ms": ..., ...}
//!   -> {"id": 2, "prompt": "...", "max_tokens": 32, "stream": true}
//!   <- {"frame": "token", "id": 2, "index": 0, "token": ..., "text": ...}
//!   <- ... one line per committed token, `index` strictly increasing ...
//!   <- {"frame": "done", "id": 2, "text": "...", "tokens": [...], ...}
//!   -> {"cancel": 2}       (mid-stream: abort; terminal frame becomes
//!                           {"frame": "error", ..., "error": "request
//!                           cancelled"}. No token frames follow the
//!                           terminal frame.)
//!   -> {"stats": true}
//!   <- {"requests": ..., "queue_depth": ..., "mean_batch_occupancy":
//!      ..., "kv_utilization": ..., "spec_acceptance_rate": ...,
//!      "tokens_per_row_iteration", "slo_attainment", ...}  (see
//!      api::stats_to_json; spec_* gauges stay 0 unless
//!      ServerConfig.spec is set)
//!
//! Legacy one-shot requests (no "stream" key) are answered exactly as
//! before — a single response line with no "frame" key — so existing
//! clients never see a frame they do not expect. Closing the socket
//! mid-stream cancels the in-flight request: the scheduler frees its
//! slot(s) through the normal release path within one iteration.
//!
//! One OS thread per connection (connection counts here are benchmark-
//! scale); generation itself is funneled through the server worker, so
//! batching happens across connections — the continuous scheduler mixes
//! prompts of any length into one decode group.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::Arc;
use std::time::Duration;

use crate::data::tokenizer::ByteTokenizer;
use crate::error::Result;
use crate::server::api::{
    cancel_request_id, terminal_frame, token_frame, GenRequest, GenResponse,
};
use crate::server::service::{Server, ServerHandle};
use crate::util::json::Json;

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpFrontend {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(server: Arc<Server>, addr: &str) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = Arc::new(server.clone().spawn());

        let accept_thread = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let h = handle.clone();
                        let srv = server.clone();
                        let s = stop2.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, &srv, &h, &s);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        Ok(TcpFrontend { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    server: &Server,
    handle: &ServerHandle,
    stop: &AtomicBool,
) -> Result<()> {
    // short read timeout so the thread notices server shutdown even while
    // the peer keeps the connection open
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line);
        // stats endpoint: answered from the hub, never enters the queue.
        // Snapshots are cloned out of the hub FIRST so no hub lock is
        // held across JSON serialization or the socket write (a slow
        // reader must never stall the worker's metric updates).
        if let Ok(j) = &parsed {
            if crate::server::api::is_stats_request(j) {
                let summary = server.metrics.summary();
                let gauges = server.metrics.gauges();
                let trace = server.trace.stats();
                let stats = crate::server::api::stats_to_json(
                    &summary,
                    &gauges,
                    server.pool.in_use(),
                    server.pool.capacity(),
                    &trace,
                );
                writeln!(writer, "{stats}")?;
                continue;
            }
            // flight-recorder export: one Chrome-trace JSON object per
            // line, same snapshot-then-serialize discipline
            if crate::server::api::is_trace_request(j) {
                let trace = server.trace.export_chrome();
                writeln!(writer, "{trace}")?;
                continue;
            }
        }
        // a stale cancel frame between requests: the stream it aimed at
        // already emitted its terminal frame, so forwarding is at most
        // a no-op in the scheduler — consume the line silently (a reply
        // here would interleave with the next request's frames)
        if let Ok(j) = &parsed {
            if let Some(id) = cancel_request_id(j) {
                handle.cancel(id);
                continue;
            }
        }
        match parsed.and_then(|j| GenRequest::from_json(&j)) {
            Ok(req) if req.stream => {
                stream_request(&mut reader, &mut writer, handle, req, stop)?;
            }
            Ok(req) => {
                let resp = handle
                    .submit_blocking(req)
                    .unwrap_or_else(|e| err_resp(0, &e.to_string()));
                writeln!(writer, "{}", resp.to_json())?;
            }
            Err(e) => {
                writeln!(writer, "{}", err_resp(0, &e.to_string()).to_json())?;
            }
        }
    }
}

/// Serve one streamed request: forward committed tokens as JSONL
/// `token` frames the moment the scheduler commits them, watch the
/// socket for a `{"cancel": id}` frame or a disconnect while the
/// stream runs, and close with exactly one terminal frame (`done` or
/// `error`). Tokens the scheduler never streamed — the legacy
/// exact-length worker answers one-shot — are framed from the final
/// response before the terminal frame, so concatenated token frames
/// equal the one-shot reply in EVERY mode (the fallback ladder's
/// parity rung).
fn stream_request(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    handle: &ServerHandle,
    req: GenRequest,
    stop: &AtomicBool,
) -> Result<()> {
    let id = req.id;
    let tok = ByteTokenizer::new();
    let (sink_tx, sink_rx) = std::sync::mpsc::channel();
    let reply = handle.submit_streaming(req, sink_tx);
    // tight poll while streaming so token frames flush promptly; the
    // caller's 100ms idle cadence is restored before returning
    reader.get_ref().set_read_timeout(Some(Duration::from_millis(5)))?;
    let mut streamed = 0usize;
    let mut cancelled = false;
    let mut line = String::new();
    let resp: GenResponse = loop {
        // server shutting down: ask the worker to abort so the terminal
        // error arrives promptly instead of after a full generation
        if stop.load(Ordering::Relaxed) && !cancelled {
            cancelled = true;
            handle.cancel(id);
        }
        while let Ok(t) = sink_rx.try_recv() {
            let piece = tok.decode(&[t.token]);
            writeln!(writer, "{}", token_frame(t.id, t.index, t.token, &piece))?;
            streamed = t.index + 1;
        }
        match reply.try_recv() {
            Ok(r) => break r,
            Err(TryRecvError::Disconnected) => break err_resp(id, "server shut down"),
            Err(TryRecvError::Empty) => {}
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                // peer hung up mid-stream: free the slot(s), then drain
                // the terminal so the worker never blocks — there is no
                // one left to write frames to
                handle.cancel(id);
                let _ = reply.recv_timeout(Duration::from_secs(5));
                reader.get_ref().set_read_timeout(Some(Duration::from_millis(100)))?;
                return Ok(());
            }
            Ok(_) => {
                if let Ok(j) = Json::parse(&line) {
                    if cancel_request_id(&j) == Some(id) && !cancelled {
                        cancelled = true;
                        handle.cancel(id);
                    }
                    // anything else mid-stream is out of protocol for
                    // this sequential front end; the line is dropped
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => {
                handle.cancel(id);
                let _ = reply.recv_timeout(Duration::from_secs(5));
                return Err(e.into());
            }
        }
    };
    // stragglers that raced the terminal response (mpsc preserves send
    // order, so indices can only move forward)
    while let Ok(t) = sink_rx.try_recv() {
        let piece = tok.decode(&[t.token]);
        writeln!(writer, "{}", token_frame(t.id, t.index, t.token, &piece))?;
        streamed = t.index + 1;
    }
    // top-up: tokens committed but never streamed (the exact-length
    // worker, or a race between the last commit and the terminal)
    if resp.error.is_none() {
        for (i, &t) in resp.tokens.iter().enumerate().skip(streamed) {
            let piece = tok.decode(&[t]);
            writeln!(writer, "{}", token_frame(id, i, t, &piece))?;
        }
    }
    writeln!(writer, "{}", terminal_frame(&resp))?;
    reader.get_ref().set_read_timeout(Some(Duration::from_millis(100)))?;
    Ok(())
}

fn err_resp(id: u64, msg: &str) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        text: String::new(),
        ttft_ms: 0.0,
        total_ms: 0.0,
        error: Some(msg.to_string()),
    }
}
