//! TCP front-end: newline-delimited JSON requests/responses.
//!
//! Protocol (one JSON object per line):
//!   -> {"id": 1, "prompt": "the small robot ", "max_tokens": 32}
//!   <- {"id": 1, "text": "...", "tokens": [...], "ttft_ms": ..., ...}
//!   -> {"stats": true}
//!   <- {"requests": ..., "queue_depth": ..., "mean_batch_occupancy":
//!      ..., "kv_utilization": ..., "spec_acceptance_rate": ...,
//!      "tokens_per_row_iteration": ..., ...}  (see api::stats_to_json;
//!      the spec_* gauges stay 0 unless ServerConfig.spec is set)
//!
//! One OS thread per connection (connection counts here are benchmark-
//! scale); generation itself is funneled through the server worker, so
//! batching happens across connections — the continuous scheduler mixes
//! prompts of any length into one decode group.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::Result;
use crate::server::api::{GenRequest, GenResponse};
use crate::server::service::{Server, ServerHandle};
use crate::util::json::Json;

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpFrontend {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn start(server: Arc<Server>, addr: &str) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = Arc::new(server.clone().spawn());

        let accept_thread = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let h = handle.clone();
                        let srv = server.clone();
                        let s = stop2.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, &srv, &h, &s);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        Ok(TcpFrontend { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    server: &Server,
    handle: &ServerHandle,
    stop: &AtomicBool,
) -> Result<()> {
    // short read timeout so the thread notices server shutdown even while
    // the peer keeps the connection open
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // peer closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line);
        // stats endpoint: answered from the hub, never enters the queue.
        // Snapshots are cloned out of the hub FIRST so no hub lock is
        // held across JSON serialization or the socket write (a slow
        // reader must never stall the worker's metric updates).
        if let Ok(j) = &parsed {
            if crate::server::api::is_stats_request(j) {
                let summary = server.metrics.summary();
                let gauges = server.metrics.gauges();
                let trace = server.trace.stats();
                let stats = crate::server::api::stats_to_json(
                    &summary,
                    &gauges,
                    server.pool.in_use(),
                    server.pool.capacity(),
                    &trace,
                );
                writeln!(writer, "{stats}")?;
                continue;
            }
            // flight-recorder export: one Chrome-trace JSON object per
            // line, same snapshot-then-serialize discipline
            if crate::server::api::is_trace_request(j) {
                let trace = server.trace.export_chrome();
                writeln!(writer, "{trace}")?;
                continue;
            }
        }
        let resp = match parsed.and_then(|j| GenRequest::from_json(&j)) {
            Ok(req) => handle
                .submit_blocking(req)
                .unwrap_or_else(|e| err_resp(0, &e.to_string())),
            Err(e) => err_resp(0, &e.to_string()),
        };
        writeln!(writer, "{}", resp.to_json())?;
    }
}

fn err_resp(id: u64, msg: &str) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        text: String::new(),
        ttft_ms: 0.0,
        total_ms: 0.0,
        error: Some(msg.to_string()),
    }
}
